"""Regenerate the EXPERIMENTS.md roofline tables from dry-run artifacts.

    PYTHONPATH=src python benchmarks/make_tables.py
"""
import json
import os

PEAK, HBM = 197e12, 819e9
HERE = os.path.join(os.path.dirname(__file__), "results")


def frac(r):
    bound = r["roofline"]["step_s_lower_bound"]
    if not bound:
        return 0.0
    if r["kind"] in ("train", "prefill"):
        ideal = r["model_flops_per_chip"] / PEAK
    else:
        ideal = r["hbm_state_bytes_per_device"] / HBM
    return ideal / bound


def table(path, mesh):
    rows = []
    for r in json.load(open(path)):
        if r["mesh"] != mesh:
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['dominant']} "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {r['useful_flop_ratio'] or 0:.3f} "
            f"| {100 * frac(r):.2f}% |")
    rows.sort()
    head = ("| arch | shape | dominant | compute s | memory s "
            "| collective s | useful | roofline |\n"
            "|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def compile_stats(path):
    rs = json.load(open(path))
    n1 = sum(1 for r in rs if r["mesh"] == [16, 16])
    n2 = sum(1 for r in rs if r["mesh"] == [2, 16, 16])
    tmax = max(r["compile_s"] for r in rs)
    return n1, n2, tmax


if __name__ == "__main__":
    base = os.path.join(HERE, "dryrun_baseline.json")
    opt = os.path.join(HERE, "dryrun.json")
    print("## baseline single-pod (16x16)\n")
    print(table(base, [16, 16]))
    if os.path.exists(opt):
        print("\n## optimized single-pod (16x16)\n")
        print(table(opt, [16, 16]))
    print("\nbaseline cells:", compile_stats(base))
    if os.path.exists(opt):
        print("optimized cells:", compile_stats(opt))
