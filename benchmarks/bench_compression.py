"""§3 claims: per-element compression keeps parallel/selective access while
paying bounded overhead vs monolithic deflate."""
import os
import tempfile
import time
import zlib

import numpy as np

from repro.core import codec, fopen_read, fopen_write


def _mixed_payload(n):
    # half structured (compressible), half random — checkpoint-like
    rng = np.random.default_rng(0)
    a = np.arange(n // 8, dtype=np.int64).tobytes()
    b = rng.bytes(n - len(a))
    return a + b


def run(quick=False):
    rows = []
    total = (4 if quick else 16) << 20
    data = _mixed_payload(total)
    for esize_kb in (64, 1024):
        E = esize_kb << 10
        elements = [data[i:i + E] for i in range(0, len(data), E)]
        t0 = time.perf_counter()
        streams = [codec.compress(e) for e in elements]
        dt = time.perf_counter() - t0
        csize = sum(len(s) for s in streams)
        mono = len(zlib.compress(data, 9))
        rows.append((f"compression.per_element_{esize_kb}KB", dt * 1e6,
                     f"ratio={len(data) / csize:.2f}x;"
                     f"vs_monolithic={csize / (mono * 4 / 3):.2f}x;"
                     f"{total / dt / 1e6:.0f}MB/s"))
        t0 = time.perf_counter()
        for s in streams:
            codec.decompress(s)
        rows.append((f"compression.inflate_{esize_kb}KB",
                     (time.perf_counter() - t0) * 1e6,
                     f"{total / (time.perf_counter() - t0) / 1e6:.0f}MB/s"))

    # selective access: read ONE element of a compressed 256-element varray
    E = total // 256
    elements = [data[i * E:(i + 1) * E] for i in range(256)]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "f.scda")
        with fopen_write(None, path) as f:
            f.write_varray(b"v", elements, [256], [E] * 256, encode=True)
        t0 = time.perf_counter()
        with fopen_read(None, path) as r:
            r.read_section_header(decode=True)
            one = r.read_varray_elements([137])[0]
        dt = time.perf_counter() - t0
        assert one == elements[137]
        rows.append(("compression.selective_1_of_256", dt * 1e6,
                     f"read={E}B_of_{total}B"))
    return rows
