"""Overlapped vs serial restore bandwidth — the PR-3 read-pipeline claim.

Restores a multi-leaf checkpoint twice per variant: ``prefetch_bytes=0``
(the serial oracle: pread → inflate → copy, one chunk at a time) and the
default overlapped engine (background prefetch + pooled inflation).  Raw
leaves measure the scatter-read/prefetch path; compressed leaves measure
read/inflate overlap on the codec pool (``REPRO_CODEC_THREADS``).

Methodology mirrors bench_parallel_io: ``os.sync()`` quiesces writeback
between timed regions and each region is best-of-N.  The page cache
cannot be dropped without privileges, so numbers are cold-ish, not
cold-disk — they quantify the pipeline's overlap win, which is also what
the byte-identity tests pin down for correctness.
"""
import os
import tempfile
import time

import numpy as np

from repro.checkpoint import pytree_io


def _best_of(fn, reps=2):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
        os.sync()
    return best


def _make_tree(total_mb, nleaves):
    """Checkpoint-like leaves: structured float payloads (real-but-finite
    deflate ratio), identical across serial/pipelined runs."""
    per_elems = total_mb * (1 << 20) // nleaves // 4
    return {f"leaf{i:02d}": (np.arange(per_elems, dtype=np.float32)
                             * 0.5 + i)
            for i in range(nleaves)}


def run(quick=False):
    rows = []
    total_mb = 16 if quick else 64
    nleaves = 8
    reps = 1 if quick else 2
    # 256 KiB deflate chunks: finer pipeline granularity than the 1 MiB
    # default, and small enough that pooled inflates stay cache-resident.
    chunk_bytes = 256 << 10
    for tag, compressed in (("raw", False), ("zlib", True)):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, f"{tag}.scda")
            pytree_io.save(path, _make_tree(total_mb, nleaves),
                           compressed=compressed, chunk_bytes=chunk_bytes)
            os.sync()
            times = {}
            for mode, pf in (("serial", 0), ("pipelined", None)):
                times[mode] = _best_of(
                    lambda: pytree_io.restore(path, prefetch_bytes=pf),
                    reps)
                derived = f"{total_mb / times[mode]:.0f}MB/s"
                if mode == "pipelined":
                    derived += (f" speedup="
                                f"{times['serial'] / times[mode]:.1f}x")
                rows.append((f"restore.{mode}_{tag}",
                             times[mode] * 1e6, derived))
    return rows
