"""Full vs incremental (delta) checkpoint saves — the PR-6 claim that
save cost is proportional to CHANGED bytes, not total bytes.

A base checkpoint is saved with chunk digests recorded, then trees with
1% / 10% / 50% of their chunks dirtied are saved as deltas against it
and compared to a full (equally hash-recording) save of the same state:

* the full save rewrites every byte to disk (and digests every byte:
  CRC32 + the 128-bit SHA-256 prefix);
* the delta save hashes every byte (the content-addressing floor — one
  hardware-SHA pass) but checksums and writes only the dirty chunks,
  so its advantage grows as the changed fraction shrinks.

The second half quantifies the read-side cost of chaining: restoring
the head of a depth-3 chain (chunks gathered from four archives via one
overlapped pipeline per source) vs restoring the equivalent flat
archive.  Byte-identity of the two is pinned by tests/test_delta.py;
this file only measures the cost.

Methodology mirrors bench_save: random float32 leaves (checkpoint-like
payloads), ``os.sync()`` between timed regions; full and delta legs are
interleaved within each rep and reported as per-leg medians so both
sides of every ratio see the same disk conditions.
"""
import os
import tempfile
import time

import numpy as np

from repro.checkpoint import pytree_io


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    dt = time.perf_counter() - t0
    os.sync()
    return dt


def _median(xs):
    s = sorted(xs)
    return s[len(s) // 2]


def _make_tree(total_mb, nleaves=8):
    rng = np.random.default_rng(42)
    per_elems = total_mb * (1 << 20) // nleaves // 4
    return {f"leaf{i:02d}": rng.standard_normal(per_elems)
            .astype(np.float32) for i in range(nleaves)}


def _dirty_fraction(tree, frac, chunk_bytes, seed=7):
    """Copy ``tree`` with ~``frac`` of every leaf's chunks changed (one
    element per dirty chunk — content-addressing cares about which
    chunks changed, not how much inside each)."""
    rng = np.random.default_rng(seed)
    per_chunk = chunk_bytes // 4
    out = {}
    for k, v in tree.items():
        a = v.copy()
        flat = a.reshape(-1)
        nchunks = max(1, -(-flat.size * 4 // chunk_bytes))
        dirty = max(1, int(round(frac * nchunks))) if frac else 0
        for c in rng.choice(nchunks, size=dirty, replace=False):
            flat[int(c) * per_chunk] += 1.0
        out[k] = a
    return out


def run(quick=False):
    rows = []
    total_mb = 16 if quick else 64
    # keep >= 32 chunks per leaf so the 1%/10% dirty fractions do not
    # both round up to the same single chunk at the quick size
    chunk_bytes = (64 if quick else 256) << 10
    # the fsync'd write legs ride shared-host disk weather that varies
    # several-fold minute to minute, so every rep times the full save
    # AND every delta save back to back (same conditions for both sides
    # of the ratio) and the reported figure is the per-leg median
    reps = 3 if quick else 5
    tree = _make_tree(total_mb)
    muts = {pct: _dirty_fraction(tree, pct / 100, chunk_bytes)
            for pct in (1, 10, 50)}
    with tempfile.TemporaryDirectory() as d:
        base = os.path.join(d, "base.scda")
        base_doc = pytree_io.save(base, tree, step=0,
                                  chunk_bytes=chunk_bytes,
                                  record_hashes=True)

        full = os.path.join(d, "full.scda")
        t_full, t_pct = [], {1: [], 10: [], 50: []}
        for _ in range(reps):
            t_full.append(_timed(
                lambda: pytree_io.save(full, tree, step=1,
                                       chunk_bytes=chunk_bytes,
                                       record_hashes=True)))
            for pct, mut in muts.items():
                path = os.path.join(d, f"delta_{pct}.scda")
                t_pct[pct].append(_timed(
                    lambda: pytree_io.save(path, mut, step=1,
                                           chunk_bytes=chunk_bytes,
                                           record_hashes=True,
                                           delta_base=(base_doc,
                                                       "base.scda"))))
        tf = _median(t_full)
        rows.append(("delta.save_full", tf * 1e6,
                     f"{total_mb / tf:.0f}MB/s"))
        for pct in (1, 10, 50):
            t = _median(t_pct[pct])
            size_mb = os.path.getsize(
                os.path.join(d, f"delta_{pct}.scda")) / (1 << 20)
            rows.append((f"delta.save_{pct}pct", t * 1e6,
                         f"{total_mb / t:.0f}MB/s "
                         f"speedup={tf / t:.1f}x "
                         f"wrote={size_mb:.1f}MB"))

        # depth-3 chain restore vs the equivalent flat restore
        cur, doc, prev = tree, base_doc, "base.scda"
        head = base
        for k in range(3):
            cur = _dirty_fraction(cur, 0.10, chunk_bytes, seed=k)
            head = os.path.join(d, f"chain_{k}.scda")
            doc = pytree_io.save(head, cur, step=k + 1,
                                 chunk_bytes=chunk_bytes,
                                 record_hashes=True,
                                 delta_base=(doc, prev))
            prev = os.path.basename(head)
        flat = os.path.join(d, "flat.scda")
        pytree_io.save(flat, cur, step=3, chunk_bytes=chunk_bytes,
                       record_hashes=True)
        t_flat, t_chain = [], []
        for _ in range(reps):
            t_flat.append(_timed(lambda: pytree_io.restore(flat)))
            t_chain.append(_timed(lambda: pytree_io.restore(head)))
        tr, tc = _median(t_flat), _median(t_chain)
        rows.append(("delta.restore_flat", tr * 1e6,
                     f"{total_mb / tr:.0f}MB/s"))
        rows.append(("delta.restore_chain3", tc * 1e6,
                     f"{total_mb / tc:.0f}MB/s "
                     f"cost={tc / tr:.1f}x"))
    return rows
