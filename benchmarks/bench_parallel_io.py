"""§1/§A claim: parallel access is scalable — write/read bandwidth of one
array under increasing rank counts (threaded ranks, one shared file), plus
serial-equivalence verification cost.

Methodology: closing an scda file no longer implies fsync (MPI-IO
semantics — durability is an explicit ``sync=True``), so the harness
quiesces the page cache with ``os.sync()`` *between* timed regions; each
region is best-of-2 to keep background writeback out of the numbers.
"""
import os
import tempfile
import time

from repro.core import ThreadComm, fopen_read, fopen_write, partition, run_ranks


def _best_of(fn, reps=2):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
        os.sync()  # keep deferred writeback out of the next timed region
    return best


def run(quick=False):
    rows = []
    total_mb = 16 if quick else 64
    E = 1 << 16
    N = total_mb * (1 << 20) // E
    data = os.urandom(N * E)
    reps = 1 if quick else 2

    for P in (1, 2, 4, 8):
        counts = partition.uniform(N, P)
        offs = partition.offsets(counts)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "f.scda")

            def write(comm):
                lo, hi = offs[comm.rank] * E, offs[comm.rank + 1] * E
                with fopen_write(comm, path, b"bench") as f:
                    f.write_array(b"a", data[lo:hi], counts, E)

            os.sync()
            dt = _best_of(lambda: run_ranks(ThreadComm.group(P), write),
                          reps)
            rows.append((f"parallel_io.write_p{P}", dt * 1e6,
                         f"{total_mb / dt:.0f}MB/s"))

            def read(comm):
                with fopen_read(comm, path) as r:
                    r.read_section_header()
                    return r.read_array_data(counts)

            dt = _best_of(lambda: run_ranks(ThreadComm.group(P), read),
                          reps)
            rows.append((f"parallel_io.read_p{P}", dt * 1e6,
                         f"{total_mb / dt:.0f}MB/s"))

    # Durable-write datapoint (sync=True: every rank fsyncs at close, the
    # seed's always-on behavior) — apples-to-apples against seed timings.
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "sync.scda")
        P = 8
        counts = partition.uniform(N, P)
        offs = partition.offsets(counts)

        def write_sync(comm):
            lo, hi = offs[comm.rank] * E, offs[comm.rank + 1] * E
            with fopen_write(comm, path, b"bench", sync=True) as f:
                f.write_array(b"a", data[lo:hi], counts, E)

        os.sync()
        dt = _best_of(lambda: run_ranks(ThreadComm.group(P), write_sync),
                      reps)
        rows.append((f"parallel_io.write_sync_p{P}", dt * 1e6,
                     f"{total_mb / dt:.0f}MB/s"))
    return rows
