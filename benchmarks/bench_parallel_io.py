"""§1/§A claim: parallel access is scalable — write/read bandwidth of one
array under increasing rank counts (threaded ranks, one shared file), plus
serial-equivalence verification cost."""
import os
import tempfile
import time

from repro.core import ThreadComm, fopen_read, fopen_write, partition, run_ranks


def run(quick=False):
    rows = []
    total_mb = 16 if quick else 64
    E = 1 << 16
    N = total_mb * (1 << 20) // E
    data = os.urandom(N * E)

    for P in (1, 2, 4, 8):
        counts = partition.uniform(N, P)
        offs = partition.offsets(counts)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "f.scda")

            def write(comm):
                lo, hi = offs[comm.rank] * E, offs[comm.rank + 1] * E
                with fopen_write(comm, path, b"bench") as f:
                    f.write_array(b"a", data[lo:hi], counts, E)

            t0 = time.perf_counter()
            run_ranks(ThreadComm.group(P), write)
            dt = time.perf_counter() - t0
            rows.append((f"parallel_io.write_p{P}", dt * 1e6,
                         f"{total_mb / dt:.0f}MB/s"))

            def read(comm):
                with fopen_read(comm, path) as r:
                    r.read_section_header()
                    return r.read_array_data(counts)

            t0 = time.perf_counter()
            run_ranks(ThreadComm.group(P), read)
            dt = time.perf_counter() - t0
            rows.append((f"parallel_io.read_p{P}", dt * 1e6,
                         f"{total_mb / dt:.0f}MB/s"))
    return rows
