"""Random-access claim: with a section index, reaching any one section of
a large archive is O(1)-ish instead of a forward walk over all of its
predecessors (cf. "Parallel Data Object Creation", 2025: metadata scans
must not scale with archive size).

Builds a 1k-section file (200 quick) and measures

  * the forward header-only scan (the pre-index baseline for ANY query),
  * the one-time index build and ``.scdax`` sidecar write/load,
  * reading the LAST section: forward walk + read  vs  sidecar + seek + read.
"""
import os
import statistics
import tempfile
import time

from repro.core import ScdaIndex, fopen_read, fopen_write, scan_sections


def _time(fn, n=10):
    fn()  # warmup
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples) * 1e6


def _build_archive(path, nsec):
    payload = b"payload." * 64  # 512 B per section
    with fopen_write(None, path, user_string=b"bench index") as f:
        for i in range(nsec):
            f.write_block(b"sec %06d" % i, payload)
    return payload


def run(quick=False):
    rows = []
    nsec = 200 if quick else 1000
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "big.scda")
        payload = _build_archive(path, nsec)

        rows.append((f"index.forward_scan_{nsec}",
                     _time(lambda: scan_sections(path)),
                     f"sections={nsec}"))
        rows.append((f"index.build_{nsec}",
                     _time(lambda: ScdaIndex.build(path)),
                     "one header-only scan"))

        idx = ScdaIndex.build(path)
        idx.write_sidecar()
        rows.append(("index.sidecar_load",
                     _time(lambda: ScdaIndex.load_sidecar(path)),
                     f"bytes={os.path.getsize(path + '.scdax')}"))

        target = nsec - 1

        def walk_last():
            with fopen_read(None, path) as r:
                for _ in range(target):
                    r.read_section_header()
                    r.skip_data()
                r.read_section_header()
                return r.read_block_data()

        def seek_last():
            with fopen_read(None, path) as r:
                r.set_index(idx)
                r.seek_section(target)
                return r.read_block_data()

        assert walk_last() == seek_last() == payload
        walk_us = _time(walk_last)
        seek_us = _time(seek_last)
        rows.append((f"index.read_last_forward_{nsec}", walk_us,
                     "walk+read"))
        rows.append(("index.read_last_seek", seek_us,
                     f"speedup={walk_us / max(seek_us, 1e-9):.1f}x"))
    return rows
