"""Append-path costs (the mode-'a' subsystem):

  * journal append rate — records/s end-to-end through ``ScdaJournal``:
    buffered ``log`` → framed-varray flush via ``fopen_append`` (tail
    validation included), with and without the incremental atomic
    ``.scdax`` refresh each flush performs;
  * reopen-validate latency — what ``fopen_append`` pays before the first
    appended byte, full header walk vs the sidecar fast path (which
    re-validates only the last section).
"""
import os
import statistics
import tempfile
import time

from repro.core import ScdaIndex, fopen_append, fopen_write
from repro.journal import ScdaJournal


def _time(fn, n=10):
    fn()  # warmup
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples) * 1e6


def _journal_rate(path, nrec, flush, update_sidecar):
    j = ScdaJournal(path, flush_records=flush,
                    update_sidecar=update_sidecar)
    rec = {"loss": 1.0, "lr": 1e-3, "step_time": 0.123, "tokens": 4096}
    t0 = time.perf_counter()
    for s in range(nrec):
        j.log(s, rec)
    j.flush()
    dt = time.perf_counter() - t0
    return dt / nrec * 1e6, nrec / dt


def run(quick=False):
    rows = []
    nsec = 50 if quick else 300
    nrec = 200 if quick else 2000
    flush = 50
    with tempfile.TemporaryDirectory() as d:
        # -- journal append rate ------------------------------------------
        path = os.path.join(d, "journal.scda")
        with fopen_write(None, path, user_string=b"bench append") as f:
            f.write_block(b"base", b"x" * 1024)
        us, rate = _journal_rate(path, nrec, flush, update_sidecar=False)
        rows.append(("append.journal_log_flush", us,
                     f"{rate:.0f}records/s flush_every={flush}"))
        ScdaIndex.build(path).write_sidecar()
        us, rate = _journal_rate(path, nrec, flush, update_sidecar=True)
        rows.append(("append.journal_log_flush_sidecar", us,
                     f"{rate:.0f}records/s incl. incremental .scdax "
                     f"refresh"))

        # -- reopen-validate latency --------------------------------------
        many = os.path.join(d, "many.scda")
        with fopen_write(None, many, user_string=b"bench append") as f:
            for i in range(nsec):
                f.write_block(b"sec %06d" % i, b"y" * 256)

        def reopen():
            fopen_append(None, many).close()

        scan_us = _time(reopen)
        rows.append((f"append.reopen_scan_{nsec}", scan_us,
                     "full header walk"))
        ScdaIndex.build(many).write_sidecar()
        sidecar_us = _time(reopen)
        rows.append((f"append.reopen_sidecar_{nsec}", sidecar_us,
                     f"last-section check only, speedup="
                     f"{scan_us / max(sidecar_us, 1e-9):.1f}x"))
    return rows
