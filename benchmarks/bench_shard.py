"""Sharded vs single-file checkpoint bandwidth — the PR-7 claim that a
multi-file sharded set costs little over the flat archive it replaces.

One checkpoint tree is saved at shard counts N ∈ {1, 2, 4, 8} (each
shard an independent scda archive written through the overlapped save
engine, plus the manifest) and restored back through the manifest; a
flat (``shards=0``) save/restore pair anchors each side's baseline.

What the numbers mean:

* **save** — sharding re-plans the leaf placement and pays one extra
  ``fsync``'d manifest write plus per-shard file open/close; the leaf
  bytes themselves go through the identical pipelined write path, so
  the gap vs flat is pure set-bookkeeping overhead.
* **restore** — the reader resolves the manifest, then runs one
  overlapped read pipeline per shard; small N should track the flat
  archive closely.

Byte-identity of every shard to a serial write of its leaf subset is
pinned by tests/test_sharding.py; this file only measures the cost.

Methodology mirrors bench_save: random float32 leaves, ``os.sync()``
between timed regions, best-of-N per leg.
"""
import os
import tempfile
import time

import numpy as np

from repro.checkpoint import pytree_io

SHARD_COUNTS = (1, 2, 4, 8)


def _best_of(fn, reps=2):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
        os.sync()
    return best


def _make_tree(total_mb, nleaves=8):
    """Checkpoint-like leaves: random float32 weights, one leaf per
    potential shard so every shard count divides the set evenly."""
    rng = np.random.default_rng(42)
    per_elems = total_mb * (1 << 20) // nleaves // 4
    return {f"leaf{i:02d}": rng.standard_normal(per_elems)
            .astype(np.float32) for i in range(nleaves)}


def run(quick=False):
    rows = []
    total_mb = 16 if quick else 64
    reps = 2 if quick else 3
    tree = _make_tree(total_mb)
    # Warm the codec/writeback pools once (as in bench_save) so every
    # leg measures steady state rather than thread spawn.
    with tempfile.TemporaryDirectory() as d:
        pytree_io.save(os.path.join(d, "warm.scda"),
                       {"w": np.zeros(1 << 20, np.uint8)})
    variants = [("flat", 0)] + [(f"n{n}", n) for n in SHARD_COUNTS]
    save_t = {}
    with tempfile.TemporaryDirectory() as d:
        for tag, shards in variants:
            path = os.path.join(d, f"{tag}.scda")
            save_t[tag] = _best_of(
                lambda p=path, s=shards: pytree_io.save(p, tree, step=1,
                                                        shards=s), reps)
            derived = f"{total_mb / save_t[tag]:.0f}MB/s"
            if tag != "flat":
                derived += f" cost={save_t[tag] / save_t['flat']:.2f}x"
            rows.append((f"shard.save_{tag}", save_t[tag] * 1e6, derived))
        restore_t = {}
        for tag, _ in variants:
            path = os.path.join(d, f"{tag}.scda")
            restore_t[tag] = _best_of(
                lambda p=path: pytree_io.restore(p), reps)
            derived = f"{total_mb / restore_t[tag]:.0f}MB/s"
            if tag != "flat":
                derived += (f" cost="
                            f"{restore_t[tag] / restore_t['flat']:.2f}x")
            rows.append((f"shard.restore_{tag}",
                         restore_t[tag] * 1e6, derived))
    # Erasure coding: parity save overhead (XOR / RS8 passes over the
    # shard streams) and the degraded-restore penalty (reconstructing a
    # lost shard's byte ranges from survivors + parity on the fly).
    with tempfile.TemporaryDirectory() as d:
        for m in (1, 2):
            path = os.path.join(d, f"par{m}.scda")
            t = _best_of(
                lambda p=path: pytree_io.save(p, tree, step=1, shards=4,
                                              parity=m), reps)
            rows.append((f"shard.save_n4_parity{m}", t * 1e6,
                         f"{total_mb / t:.0f}MB/s "
                         f"cost={t / save_t['n4']:.2f}x"))
        from repro.checkpoint import sharding
        path = os.path.join(d, "par1.scda")
        t = _best_of(lambda: pytree_io.restore(path), reps)
        rows.append(("shard.restore_n4_parity1", t * 1e6,
                     f"{total_mb / t:.0f}MB/s"))
        doc = sharding.read_sharded_manifest(path)
        lost = os.path.join(d, doc["shards"][0]["file"])
        lost_bytes = open(lost, "rb").read()

        def degraded():
            os.path.exists(lost) and os.remove(lost)
            return pytree_io.restore(path)
        t = _best_of(degraded, reps)
        rows.append(("shard.restore_n4_degraded1", t * 1e6,
                     f"{total_mb / t:.0f}MB/s "
                     f"cost={t / restore_t['n4']:.2f}x vs healthy n4"))
        with open(lost, "wb") as f:
            f.write(lost_bytes)
    return rows
