"""Regression gate over BENCH_io.json trajectory files (CI smoke stage).

    python -m benchmarks.compare BASELINE.json CURRENT.json... [--threshold 0.30]

Every numeric metric present in BOTH files is compared: throughputs
(``*MBps*``, ``*speedup_x`` — higher is better) must not drop by more
than the threshold; latencies (``*_us`` — lower is better) must not grow
by more than it.  Exit status 1 on any regression.

Benchmark noise on shared runners is one-sided (interference only ever
makes you slower), so the gate is designed around that: pass SEVERAL
current files (repeated runs) and each metric's most favorable value is
compared, while the committed baseline should be the element-wise WORST
of several runs — build it with::

    python -m benchmarks.compare --merge worst --out BENCH_io_quick.json r1.json r2.json r3.json

A real regression still trips the gate (it shows up in every repeat);
a scheduler hiccup in one repeat does not.

Quick-mode runs use smaller problem sizes, so absolute numbers are only
comparable quick-vs-quick / full-vs-full; comparing across modes is
refused unless ``--force`` is given (CI keeps a quick-mode baseline
checked in for exactly this reason).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def _flatten(doc: dict, prefix: str = "") -> Dict[str, float]:
    out: Dict[str, float] = {}
    for k, v in doc.items():
        key = f"{prefix}{k}"
        if isinstance(v, dict):
            out.update(_flatten(v, key + "."))
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[key] = float(v)
    return out


def _higher_better(key: str) -> bool:
    return "MBps" in key or key.endswith("speedup_x")


def _gated(key: str) -> bool:
    return _higher_better(key) or key.endswith("_us")


def compare(baseline: dict, currents: List[dict], threshold: float):
    """Returns (regressions, compared): rows of (key, base, best, ratio)."""
    fb = _flatten(baseline)
    fcs = [_flatten(c) for c in currents]
    regressions, compared = [], []
    for key in sorted(fb):
        vals = [fc[key] for fc in fcs if key in fc]
        if not vals or fb[key] <= 0 or not _gated(key):
            continue
        b = fb[key]
        c = max(vals) if _higher_better(key) else min(vals)
        ratio = c / b
        bad = (ratio < 1 - threshold) if _higher_better(key) \
            else (ratio > 1 + threshold)
        compared.append((key, b, c, ratio))
        if bad:
            regressions.append((key, b, c, ratio))
    return regressions, compared


def merge(docs: List[dict], mode: str):
    """Element-wise best/worst across runs; non-metric fields from docs[0]."""
    def pick(key: str, vals: List[float]) -> float:
        favorable = max(vals) if _higher_better(key) else min(vals)
        unfavorable = min(vals) if _higher_better(key) else max(vals)
        return favorable if mode == "best" else unfavorable

    def walk(nodes: List[dict], prefix: str) -> dict:
        out = {}
        for k, v in nodes[0].items():
            key = f"{prefix}{k}"
            others = [n[k] for n in nodes[1:] if k in n]
            if isinstance(v, dict):
                out[k] = walk([v] + others, key + ".")
            elif isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and _gated(key):
                out[k] = round(pick(key, [v] + others), 1)
            else:
                out[k] = v
        return out

    return walk(docs, "")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail on >threshold regression between BENCH_io "
                    "trajectory files")
    ap.add_argument("files", nargs="+",
                    help="BASELINE CURRENT... (or inputs for --merge)")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="allowed fractional regression (default 0.30)")
    ap.add_argument("--force", action="store_true",
                    help="compare even across quick/full modes")
    ap.add_argument("--merge", choices=["best", "worst"], default=None,
                    help="merge the input files element-wise instead of "
                         "comparing")
    ap.add_argument("--out", default=None,
                    help="output path for --merge")
    args = ap.parse_args(argv)

    docs = []
    for path in args.files:
        with open(path) as fh:
            docs.append(json.load(fh))

    if args.merge:
        if not args.out:
            print("compare: --merge requires --out", file=sys.stderr)
            return 2
        with open(args.out, "w") as fh:
            json.dump(merge(docs, args.merge), fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.out} ({args.merge} of {len(docs)} runs)")
        return 0

    if len(docs) < 2:
        print("compare: need BASELINE and at least one CURRENT file",
              file=sys.stderr)
        return 2
    base, currents = docs[0], docs[1:]
    for cur in currents:
        if base.get("quick") != cur.get("quick") and not args.force:
            print(f"compare: baseline quick={base.get('quick')} vs current "
                  f"quick={cur.get('quick')}: sizes differ, refusing "
                  f"(--force to override)", file=sys.stderr)
            return 2

    regressions, compared = compare(base, currents, args.threshold)
    for row in compared:
        key, b, c, ratio = row
        flag = "REGRESSION" if row in regressions else "ok"
        print(f"{key:45s} {b:12.1f} -> {c:12.1f}  ({ratio:5.2f}x)  {flag}")
    if not compared:
        print("compare: no overlapping metrics", file=sys.stderr)
        return 2
    if regressions:
        print(f"compare: {len(regressions)} metric(s) regressed beyond "
              f"{args.threshold:.0%} (best of {len(currents)} runs)",
              file=sys.stderr)
        return 1
    print(f"compare: {len(compared)} metrics within {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
