"""§2 claim: the format is minimal — measure per-section byte overhead and
header encode/decode cost."""
import os
import statistics
import tempfile
import time

from repro.core import SerialComm, encode, fopen_read, fopen_write, spec


def _time(fn, n=200):
    """Median of n individually-timed calls — robust to GC/scheduler noise
    that a plain mean-of-n absorbs."""
    fn()  # warmup
    samples = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples) * 1e6


def run(quick=False):
    rows = []
    # overhead per section type at several payload sizes
    for payload in (0, 32, 1024, 1 << 20):
        data = os.urandom(payload)
        enc = encode.encode_block(b"u", data)
        over = len(enc) - payload
        rows.append((f"format.block_overhead_{payload}B",
                     _time(lambda: encode.encode_block(b"u", data), 50),
                     f"overhead={over}B"))
    n, e = 1000, 64
    arr = os.urandom(n * e)
    enc = encode.encode_array(b"u", arr, n, e)
    rows.append(("format.array_overhead_1000x64",
                 _time(lambda: encode.encode_array(b"u", arr, n, e), 20),
                 f"overhead={len(enc) - n * e}B"))
    elements = [os.urandom(100) for _ in range(100)]
    enc = encode.encode_varray(b"u", elements)
    rows.append(("format.varray_overhead_100x100",
                 _time(lambda: encode.encode_varray(b"u", elements), 100),
                 f"overhead={len(enc) - 100 * 100}B"))
    # header parse speed (the metadata-scan path)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "f.scda")
        with fopen_write(None, path) as f:
            for i in range(50):
                f.write_block(b"blk %02d" % i, os.urandom(4096))

        def scan():
            with fopen_read(None, path) as r:
                while not r.at_eof:
                    r.read_section_header()
                    r.skip_data()

        rows.append(("format.scan_50_sections", _time(scan, 20),
                     "sections=50"))
    return rows
