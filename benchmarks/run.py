"""Benchmark harness — one section per paper claim (the RFC has no numeric
tables; §4 is an intentional placeholder, so these quantify the format's
*claims*: minimal overhead, scalable parallel access, per-element
compression with selective access, and checkpoint/restart viability), plus
the roofline summary from the dry-run artifacts.

Prints ``name,us_per_call,derived`` CSV per the harness contract.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark prefixes to run")
    args = ap.parse_args()

    from benchmarks import (bench_checkpoint, bench_compression,
                            bench_format, bench_parallel_io, bench_roofline)
    suites = [
        ("format", bench_format.run),
        ("parallel_io", bench_parallel_io.run),
        ("compression", bench_compression.run),
        ("checkpoint", bench_checkpoint.run),
        ("roofline", bench_roofline.run),
    ]
    only = [s for s in args.only.split(",") if s]
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and not any(name.startswith(o) for o in only):
            continue
        for row in fn(quick=args.quick):
            bench, us, derived = row
            print(f"{bench},{us:.1f},{derived}")
            sys.stdout.flush()


if __name__ == "__main__":
    main()
