"""Benchmark harness — one section per paper claim (the RFC has no numeric
tables; §4 is an intentional placeholder, so these quantify the format's
*claims*: minimal overhead, scalable parallel access, per-element
compression with selective access, and checkpoint/restart viability), plus
the roofline summary from the dry-run artifacts.

Prints ``name,us_per_call,derived`` CSV per the harness contract.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json BENCH_io.json]

``--json`` additionally distills the I/O-path trajectory (write/read MB/s
per rank count, varray encode µs, codec MB/s, iovec coalescing speedup)
into a machine-readable file so future PRs can regress against it.
"""
from __future__ import annotations

import argparse
import json
import re
import sys


def _mbps(derived: str) -> float:
    m = re.search(r"(\d+(?:\.\d+)?)MB/s", derived)
    return float(m.group(1)) if m else 0.0


def _distill(rows, quick: bool) -> dict:
    """Map benchmark rows into the BENCH_io.json trajectory schema."""
    out = {
        "schema": "BENCH_io/1",
        "quick": quick,
        "write_MBps": {},
        "read_MBps": {},
        "varray_encode_100x100_us": None,
        "scan_50_sections_us": None,
        "codec_MBps": {},
        "iovec": {},
        "index": {},
        "restore_MBps": {},
        "save_MBps": {},
        "append": {},
        "delta": {},
        "shard": {},
    }
    for name, us, derived in rows:
        m = re.match(r"parallel_io\.(write|read|write_sync)_p(\d+)", name)
        if m:
            out.setdefault(f"{m.group(1)}_MBps", {})[m.group(2)] = \
                _mbps(derived)
            continue
        if name == "format.varray_overhead_100x100":
            out["varray_encode_100x100_us"] = round(us, 1)
        elif name == "format.scan_50_sections":
            out["scan_50_sections_us"] = round(us, 1)
        elif name.startswith("compression.per_element_"):
            out["codec_MBps"]["deflate_" + name.rsplit("_", 1)[-1]] = \
                _mbps(derived)
        elif name.startswith("compression.inflate_"):
            out["codec_MBps"]["inflate_" + name.rsplit("_", 1)[-1]] = \
                _mbps(derived)
        elif name.startswith("iovec."):
            key = name.split(".", 1)[1].rsplit("_", 1)[0]
            out["iovec"][key + "_us"] = round(us, 1)
            m2 = re.search(r"speedup=(\d+(?:\.\d+)?)x", derived)
            if m2:
                out["iovec"]["speedup_x"] = float(m2.group(1))
        elif name.startswith(("restore.", "save.")):
            group, key = name.split(".", 1)
            out[f"{group}_MBps"][key] = _mbps(derived)
            m2 = re.search(r"speedup=(\d+(?:\.\d+)?)x", derived)
            if m2:
                out[f"{group}_MBps"][key.split("_")[-1]
                                     + "_speedup_x"] = float(m2.group(1))
        elif name.startswith("append."):
            # strip the section-count suffix so quick/full keys align
            key = re.sub(r"_\d+$", "", name.split(".", 1)[1])
            out["append"][key + "_us"] = round(us, 1)
            m2 = re.search(r"(\d+(?:\.\d+)?)records/s", derived)
            if m2:
                out["append"][key + "_records_s"] = float(m2.group(1))
            m2 = re.search(r"speedup=(\d+(?:\.\d+)?)x", derived)
            if m2:
                out["append"]["reopen_speedup_x"] = float(m2.group(1))
        elif name.startswith("delta."):
            key = name.split(".", 1)[1]
            out["delta"][key + "_MBps"] = _mbps(derived)
            m2 = re.search(r"speedup=(\d+(?:\.\d+)?)x", derived)
            if m2:
                out["delta"][key + "_speedup_x"] = float(m2.group(1))
            m2 = re.search(r"cost=(\d+(?:\.\d+)?)x", derived)
            if m2:
                out["delta"][key + "_cost_x"] = float(m2.group(1))
        elif name.startswith("shard."):
            key = name.split(".", 1)[1]
            out["shard"][key + "_MBps"] = _mbps(derived)
            m2 = re.search(r"cost=(\d+(?:\.\d+)?)x", derived)
            if m2:
                out["shard"][key + "_cost_x"] = float(m2.group(1))
        elif name.startswith("index."):
            # strip the section-count suffix so quick/full keys align
            key = re.sub(r"_\d+$", "", name.split(".", 1)[1])
            out["index"][key + "_us"] = round(us, 1)
            m2 = re.search(r"speedup=(\d+(?:\.\d+)?)x", derived)
            if m2:
                out["index"]["seek_speedup_x"] = float(m2.group(1))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sizes (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark prefixes to run")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write the I/O trajectory (BENCH_io schema)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="capture a Chrome trace of the whole run "
                         "(REPRO_SCDA_TRACE equivalent); the per-stage "
                         "breakdown also lands in the --json trajectory")
    args = ap.parse_args()

    tc = None
    if args.trace:
        from repro.core import trace as _tr
        tc = _tr.install(_tr.TraceCollector(path=args.trace))

    from benchmarks import (bench_append, bench_checkpoint,
                            bench_compression, bench_delta, bench_format,
                            bench_index, bench_iovec, bench_parallel_io,
                            bench_restore, bench_save, bench_shard,
                            bench_roofline)
    suites = [
        ("format", bench_format.run),
        ("parallel_io", bench_parallel_io.run),
        ("index", bench_index.run),
        ("iovec", bench_iovec.run),
        ("compression", bench_compression.run),
        ("checkpoint", bench_checkpoint.run),
        ("restore", bench_restore.run),
        ("save", bench_save.run),
        ("delta", bench_delta.run),
        ("shard", bench_shard.run),
        ("append", bench_append.run),
        ("roofline", bench_roofline.run),
    ]
    only = [s for s in args.only.split(",") if s]
    rows = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and not any(name.startswith(o) for o in only):
            continue
        for row in fn(quick=args.quick):
            bench, us, derived = row
            rows.append(row)
            print(f"{bench},{us:.1f},{derived}")
            sys.stdout.flush()

    trace_summary = None
    if tc is not None:
        from repro.core import trace as _tr
        _tr.uninstall()
        tc.export()
        s = _tr.summarize_chrome(tc.chrome()["traceEvents"])
        trace_summary = {
            "wall_us": s["wall_us"],
            "io_calls": s["io_calls"],
            "io_bytes": s["io_bytes"],
            "stage_us": {k: st["total_us"]
                         for k, st in sorted(s["stages"].items())},
        }
        print(f"# wrote {args.trace}", file=sys.stderr)
        for line in _tr.format_summary(s):
            print(f"# {line}", file=sys.stderr)

    if args.json:
        doc = _distill(rows, args.quick)
        if trace_summary is not None:
            doc["trace"] = trace_summary
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
