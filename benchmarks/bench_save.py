"""Serial vs overlapped save bandwidth — the PR-4 write-pipeline claim.

Saves a multi-leaf checkpoint twice per variant:

* **serial** — the fully serial stage order: ``write_window=0`` (the
  legacy write path, no writeback queue) with the codec pool dispatch
  pinned to one thread (``codec.set_pool_width(1)``), so snapshot,
  deflate, and ``pwritev`` run strictly one stage at a time.  This is
  the same single-threaded baseline discipline as ``bench_restore``'s
  serial leg (whose inflate is single-threaded by construction).
* **pipelined** — the default overlapped engine: snapshots one leaf
  ahead, deflate batches on the codec pool (``REPRO_CODEC_THREADS``),
  background ``pwritev`` bounded by ``REPRO_SCDA_WRITE_PIPELINE``.

Raw leaves measure snapshot/writeback overlap; compressed leaves measure
deflate pooling + write overlap.  Leaf payloads are random float32 —
checkpoint-like weights (mantissa-dominated, deflate-speed realistic);
the arange ramps of the restore bench deflate an order of magnitude
slower and would hide the write stage entirely.

Methodology mirrors bench_restore: ``os.sync()`` quiesces writeback
between timed regions and each region is best-of-N.  Byte-identity of
the two modes is pinned by tests/test_save_pipeline.py; this file only
quantifies the overlap win.
"""
import os
import tempfile
import time

import numpy as np

from repro.checkpoint import pytree_io
from repro.core import codec


def _best_of(fn, reps=2):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
        os.sync()
    return best


def _make_tree(total_mb, nleaves):
    """Checkpoint-like leaves: random float32 weights (realistic deflate
    speed/ratio), identical across serial/pipelined runs."""
    rng = np.random.default_rng(42)
    per_elems = total_mb * (1 << 20) // nleaves // 4
    return {f"leaf{i:02d}": rng.standard_normal(per_elems)
            .astype(np.float32) for i in range(nleaves)}


def run(quick=False):
    rows = []
    total_mb = 16 if quick else 64
    nleaves = 8
    reps = 2 if quick else 3
    # 256 KiB deflate chunks, as in bench_restore: finer pipeline
    # granularity than the 1 MiB default.
    chunk_bytes = 256 << 10
    tree = _make_tree(total_mb, nleaves)
    # Warm the codec/writeback pools once so the pipelined leg measures
    # steady state, not thread spawn (the serial leg has no threads).
    with tempfile.TemporaryDirectory() as d:
        pytree_io.save(os.path.join(d, "warm.scda"),
                       {"w": np.zeros(1 << 20, np.uint8)},
                       compressed=True, chunk_bytes=chunk_bytes)
    for tag, compressed in (("raw", False), ("zlib", True)):
        with tempfile.TemporaryDirectory() as d:
            times = {}
            for mode, ww in (("serial", 0), ("pipelined", None)):
                path = os.path.join(d, f"{tag}_{mode}.scda")

                def do(path=path, ww=ww):
                    pytree_io.save(path, tree, compressed=compressed,
                                   chunk_bytes=chunk_bytes,
                                   write_window=ww)

                if mode == "serial":
                    prev = codec.set_pool_width(1)
                    try:
                        times[mode] = _best_of(do, reps)
                    finally:
                        codec.set_pool_width(prev)
                else:
                    times[mode] = _best_of(do, reps)
                derived = f"{total_mb / times[mode]:.0f}MB/s"
                if mode == "pipelined":
                    derived += (f" speedup="
                                f"{times['serial'] / times[mode]:.1f}x")
                rows.append((f"save.{mode}_{tag}",
                             times[mode] * 1e6, derived))
    return rows
