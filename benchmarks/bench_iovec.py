"""Micro-benchmark for the scatter-gather write core: one varray-shaped
section (header + count entries + many element payloads + padding) written

  * per-fragment  — one ``pwrite`` syscall per fragment (the seed path),
  * joined        — ``b"".join`` then one ``pwrite`` (copies the payload),
  * coalesced     — one ``pwritev`` via ``FileBackend.write_gather``
                    (zero-copy, the current fast path).

Shows where buffer coalescing around a positioned-write core wins (cf.
Lemon, arXiv:1106.4177)."""
import os
import tempfile
import time

from repro.core.io_backend import FileBackend


def _fragments(n_frag, frag_bytes):
    header = os.urandom(64)
    entries = [os.urandom(32) for _ in range(n_frag)]
    payload = [os.urandom(frag_bytes) for _ in range(n_frag)]
    frags = [header] + entries + payload + [os.urandom(32)]
    offs, pos = [], 0
    for f in frags:
        offs.append(pos)
        pos += len(f)
    return list(zip(offs, frags)), pos


def _time(fn, reps):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick=False):
    rows = []
    n_frag = 256 if quick else 1024
    # Above io_backend._JOIN_SMALL so the coalesced strategy actually
    # exercises the zero-copy multi-iovec pwritev branch (small fragments
    # would be user-space pre-joined and measure a plain pwrite).
    frag_bytes = 16384
    frags, total = _fragments(n_frag, frag_bytes)
    reps = 10 if quick else 30
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "iovec.bin")
        be = FileBackend(path, "w", create=True)

        def per_fragment():
            for off, buf in frags:
                be.pwrite(off, buf)

        def joined():
            be.pwrite(0, b"".join(f for _, f in frags))

        def coalesced():
            be.write_gather(frags)

        t_frag = _time(per_fragment, reps)
        t_join = _time(joined, reps)
        t_vec = _time(coalesced, reps)
        be.close()
        mb = total / (1 << 20)
        rows.append((f"iovec.per_fragment_{n_frag}", t_frag,
                     f"{mb / (t_frag / 1e6):.0f}MB/s"))
        rows.append((f"iovec.joined_{n_frag}", t_join,
                     f"{mb / (t_join / 1e6):.0f}MB/s"))
        rows.append((f"iovec.coalesced_{n_frag}", t_vec,
                     f"{mb / (t_vec / 1e6):.0f}MB/s;"
                     f"speedup={t_frag / t_vec:.1f}x"))
    return rows
