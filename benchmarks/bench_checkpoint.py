"""Checkpoint/restart end-to-end: save/restore wall time for a model state
(sync + async), compressed variant, and elastic restore cost."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore, save
from repro.configs import get_config, smoke
from repro.models import init_lm
from repro.optim import adamw


def _state(scale=4):
    cfg = smoke(get_config("yi-6b"))
    params = init_lm(cfg, jax.random.PRNGKey(0))
    # pad with a big dense leaf so timings are meaningful
    params["big"] = jnp.zeros((scale << 20,), jnp.float32)  # scale·4 MiB
    return {"params": params, "opt": adamw.init(params)}


def run(quick=False):
    rows = []
    state = _state(2 if quick else 8)
    nbytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(state))
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "sync.scda")
        t0 = time.perf_counter()
        save(p, state, step=1)
        dt = time.perf_counter() - t0
        rows.append(("checkpoint.save_sync", dt * 1e6,
                     f"{nbytes / dt / 1e6:.0f}MB/s"))

        t0 = time.perf_counter()
        out, _ = restore(p, jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
        dt = time.perf_counter() - t0
        rows.append(("checkpoint.restore", dt * 1e6,
                     f"{nbytes / dt / 1e6:.0f}MB/s"))

        mgr = CheckpointManager(os.path.join(d, "mgr"))
        t0 = time.perf_counter()
        mgr.save(2, state)          # async: only snapshot is synchronous
        dt_fg = time.perf_counter() - t0
        mgr.wait()
        dt_total = time.perf_counter() - t0
        rows.append(("checkpoint.save_async_foreground", dt_fg * 1e6,
                     f"background={dt_total - dt_fg:.2f}s"))

        mgrc = CheckpointManager(os.path.join(d, "c"), compressed=True)
        t0 = time.perf_counter()
        mgrc.save(3, state, blocking=True)
        dt = time.perf_counter() - t0
        csize = os.path.getsize(mgrc.path_for(3))
        rows.append(("checkpoint.save_compressed", dt * 1e6,
                     f"ratio={nbytes / csize:.2f}x"))
    return rows
