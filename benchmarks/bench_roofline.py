"""Roofline summary from the dry-run artifacts (see EXPERIMENTS.md for the
full table and methodology)."""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")

PEAK = 197e12
HBM = 819e9


def roofline_fraction(r):
    t = r["roofline"]
    bound = t["step_s_lower_bound"]
    if not bound:
        return 0.0
    if r["kind"] in ("train", "prefill"):
        ideal = r["model_flops_per_chip"] / PEAK
    else:  # decode: bandwidth utilization of the minimal state read
        ideal = r["hbm_state_bytes_per_device"] / HBM
    return ideal / bound


def run(quick=False):
    if not os.path.exists(RESULTS):
        return [("roofline.missing", 0.0, "run repro.launch.dryrun --all")]
    rows = []
    records = json.load(open(RESULTS))
    for r in records:
        if r["mesh"] != [16, 16]:
            continue
        t = r["roofline"]
        frac = roofline_fraction(r)
        name = "roofline." + r["arch"] + "." + r["shape"]
        rows.append((name, t["step_s_lower_bound"] * 1e6,
                     "dom=" + t["dominant"] + f";frac={frac:.3f}"))
    n_multi = sum(1 for r in records if r["mesh"] == [2, 16, 16])
    rows.append(("roofline.multipod_cells_compiled", 0.0, f"n={n_multi}"))
    return rows
