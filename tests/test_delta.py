"""Content-addressed incremental (delta) checkpoints.

Acceptance criteria exercised here:

* a delta chain ≥ 3 deep restores byte-identically to the full state,
  raw and compressed, serial and pipelined, concurrently at
  P ∈ {1, 2, 4, 8} thread ranks;
* unchanged chunks are never rewritten (save cost ∝ changed bytes);
* stale / corrupt / deleted bases fail loudly with CORRUPT_* taxonomy
  codes and exact byte offsets — never silently wrong tensors;
* a CRC32 collision alone can never mark a chunk unchanged;
* retention is chain-aware: bases referenced by retained deltas survive;
* ``squash`` output is byte-identical to a direct full save;
* ``scdatool`` chain tooling (ls --json / verify --chain / fsck /
  diff --logical / squash) observes all of the above.
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.checkpoint import delta as ckdelta
from repro.checkpoint import manifest as mf
from repro.checkpoint import pytree_io
from repro.checkpoint.manager import CheckpointManager, _ckpt_name
from repro.core import (ScdaError, ScdaErrorCode, ScdaIndex, ThreadComm,
                        fopen_append, run_ranks)

from repro.tools.cli import main as cli_main
from repro.tools.fsck import fsck_file

PF = 1 << 16   # prefetch window for pipelined restores
CB = 1 << 12   # 4 KiB chunks: small enough that one edit != whole leaf


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 48)).astype(np.float32),
        "b": np.arange(1 << 13, dtype=np.float64),   # compressible
        "m": rng.integers(0, 255, (3, 5, 7), dtype=np.uint8),
        "empty": np.zeros((0, 4), np.int32),
        "scalar": np.float32(3.25),
        "lr": 0.125,
    }


def _mutate(tree, seed):
    """Copy ``tree`` with ONE element of ``w`` changed (one dirty chunk)."""
    rng = np.random.default_rng(seed)
    out = {k: (v.copy() if isinstance(v, np.ndarray) else v)
           for k, v in tree.items()}
    flat = out["w"].reshape(-1)
    flat[int(rng.integers(0, flat.size))] += 1.0
    return out


def _save_chain(tmp_path, n, compressed, mutate=_mutate):
    """``n`` checkpoints: a full base then n-1 deltas.  Returns
    (paths, trees)."""
    trees = [_tree(0)]
    for k in range(1, n):
        trees.append(mutate(trees[-1], k))
    paths, doc = [], None
    for k, t in enumerate(trees):
        p = str(tmp_path / f"step_{k:010d}.scda")
        base = (doc, os.path.basename(paths[-1])) if paths else None
        doc = pytree_io.save(p, t, step=k, compressed=compressed,
                             chunk_bytes=CB, record_hashes=True,
                             delta_base=base)
        paths.append(p)
    return paths, trees


def _assert_tree_equal(got, want):
    for k in ("w", "b", "m", "empty", "scalar"):
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))
    assert got["lr"] == want["lr"]


# --------------------------------------------------------------------------
# Round trips
# --------------------------------------------------------------------------

@pytest.mark.parametrize("compressed", [False, True])
def test_delta_roundtrip_serial_and_pipelined(tmp_path, compressed):
    paths, trees = _save_chain(tmp_path, 2, compressed)
    doc = pytree_io.read_manifest(paths[1])
    assert doc["version"] == mf.DELTA_FORMAT_VERSION
    assert doc["delta"]["depth"] == 1
    assert [b["file"] for b in doc["delta"]["bases"]] == \
        [os.path.basename(paths[0])]
    for spec_ in doc["leaves"]:
        assert spec_["store"] == "delta"
    serial, st0 = pytree_io.restore(paths[1], prefetch_bytes=0)
    piped, st1 = pytree_io.restore(paths[1], prefetch_bytes=PF)
    assert st0 == st1 == 1
    _assert_tree_equal(serial, trees[1])
    _assert_tree_equal(piped, trees[1])


@pytest.mark.parametrize("compressed", [False, True])
def test_delta_stores_only_changed_chunks(tmp_path, compressed):
    paths, _ = _save_chain(tmp_path, 2, compressed)
    doc = pytree_io.read_manifest(paths[1])
    by_name = {l["name"]: l for l in doc["leaves"]}
    # only w was touched, and only in one chunk
    assert len(by_name["w"]["present"]) == 1
    for name in ("b", "m", "empty"):
        assert by_name[name]["present"] == []
    # untouched leaves emit no section at all
    idx = ScdaIndex.build(paths[1])
    names = [l["name"] for l in doc["leaves"]]
    for name in ("b", "m"):
        user = mf.leaf_user_string(names.index(name))
        assert idx.find(user) < 0
    # save cost ∝ changed bytes: the delta is far smaller than the base
    assert os.path.getsize(paths[1]) < os.path.getsize(paths[0]) / 4


@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_chain_restore_identity_under_thread_ranks(tmp_path, P, compressed):
    """≥3-deep chain, restored rank-locally at P concurrent thread ranks,
    pipelined and serial — byte-identical to the final full state."""
    paths, trees = _save_chain(tmp_path, 4, compressed)
    assert pytree_io.read_manifest(paths[3])["delta"]["depth"] == 3

    def workload(comm):
        out = {}
        out["serial"], _ = pytree_io.restore(paths[3], prefetch_bytes=0)
        out["piped"], _ = pytree_io.restore(paths[3], prefetch_bytes=PF)
        return out

    for rank_out in run_ranks(ThreadComm.group(P), workload):
        _assert_tree_equal(rank_out["serial"], trees[3])
        _assert_tree_equal(rank_out["piped"], trees[3])


@pytest.mark.parametrize("compressed", [False, True])
def test_restore_leaf_and_like_through_chain(tmp_path, compressed):
    paths, trees = _save_chain(tmp_path, 3, compressed)
    for name in ("w", "b", "m", "scalar"):
        got = pytree_io.restore_leaf(paths[2], name, prefetch_bytes=PF)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(trees[2][name]))
    assert pytree_io.restore_leaf(paths[2], "lr") == 0.125
    like = {k: v for k, v in trees[0].items()}  # concrete template
    got, step = pytree_io.restore(paths[2], like, prefetch_bytes=PF)
    assert step == 2
    _assert_tree_equal(got, trees[2])


def test_append_to_base_keeps_chain_valid(tmp_path):
    """Mode-'a' appends (journals) on a base must not invalidate deltas:
    the content id covers the manifest, not the file tail, and chunk
    references resolve by user string through the index."""
    paths, trees = _save_chain(tmp_path, 2, compressed=False)
    with fopen_append(None, paths[0]) as w:
        w.write_block(b"journal", b"{\"loss\": 1.5}")
    got, _ = pytree_io.restore(paths[1], prefetch_bytes=PF)
    _assert_tree_equal(got, trees[1])
    assert ckdelta.verify_chain(paths[1]) == []


# --------------------------------------------------------------------------
# Failure modes: stale, deleted, corrupt bases
# --------------------------------------------------------------------------

def test_rewritten_base_refused(tmp_path):
    paths, trees = _save_chain(tmp_path, 2, compressed=False)
    # rewrite the base in place: same name, different content
    pytree_io.save(paths[0], _tree(99), step=0, chunk_bytes=CB,
                   record_hashes=True)
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(paths[1], prefetch_bytes=PF)
    assert ei.value.code == ScdaErrorCode.CORRUPT_CHECKSUM
    assert "rewritten" in str(ei.value)
    # fsck agrees, without jax-level restores (shallow chain check)
    findings = fsck_file(paths[1], deep=False)
    assert any(f.severity == "error" and "content id" in f.message
               for f in findings)


def test_deleted_base_refused(tmp_path):
    paths, _ = _save_chain(tmp_path, 2, compressed=False)
    os.remove(paths[0])
    with pytest.raises(ScdaError):
        pytree_io.restore(paths[1], prefetch_bytes=0)
    findings = fsck_file(paths[1], deep=False)
    assert any(f.severity == "error" for f in findings)


@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_corrupt_base_chunk_fails_with_offset(tmp_path, P, compressed):
    """A flipped byte anywhere in a referenced base chunk surfaces as a
    CORRUPT_* error with an exact byte offset, on every restoring rank,
    at several fuzzed positions."""
    from repro.checkpoint import layout
    from repro.core.reader import fopen_read

    paths, _ = _save_chain(tmp_path, 3, compressed)
    # pick a chunk of w the newest delta still resolves FROM THE BASE
    # (a mutated chunk's newest copy lives in a later archive and a flip
    # under it would legitimately go unread)
    doc2 = pytree_io.read_manifest(paths[2])
    spec_w = next(l for l in doc2["leaves"] if l["name"] == "w")
    sid0 = 1 + [b["file"] for b in doc2["delta"]["bases"]].index(
        os.path.basename(paths[0]))
    c = next(i for i, s in enumerate(spec_w["src"]) if s == sid0)
    usizes = layout.chunk_sizes(spec_w["nbytes"], CB)
    user = spec_w["sections"][str(sid0)].encode("ascii")
    with fopen_read(None, paths[0]) as r:
        sec = r.index().find(user)
        assert sec >= 0
        e = r.index().entries[sec]
        ext, _, _ = ckdelta._SrcSection(r, sec).chunk_read(
            spec_w["elem"][c], usizes[c], CB, "w")
    rng = np.random.default_rng(P)
    with open(paths[0], "rb") as fh:
        fh.seek(ext[0])
        stream = fh.read(ext[1])
    # §3 base64 framing makes line-break bytes content-neutral: flip a
    # fuzzed *payload-bearing* byte, not an ignorable one
    start = int(rng.integers(0, ext[1]))
    rel = next((start + k) % ext[1] for k in range(ext[1])
               if stream[(start + k) % ext[1]] not in b"\r\n")
    pos = ext[0] + rel
    with open(paths[0], "r+b") as fh:
        fh.seek(pos)
        fh.write(bytes([stream[rel] ^ 0xFF]))
    # sidecar would now be stale vs the flipped byte only in content, not
    # geometry — readers re-verify payloads, which is the point.

    def workload(comm):
        try:
            pytree_io.restore(paths[2], prefetch_bytes=PF)
            return None
        except ScdaError as err:
            return (err.code.name, err.offset)

    for got in run_ranks(ThreadComm.group(P), workload):
        assert got is not None, "corruption went unnoticed"
        code, offset = got
        assert code.startswith("CORRUPT_")
        assert offset is not None
        assert e.start <= offset <= e.end


def test_crc_collision_alone_never_marks_unchanged():
    """plan_refs: the dedup decision is keyed on the 128-bit strong hash
    alone — a CRC32 collision alone never marks a chunk unchanged, and
    unchanged chunks inherit the base's CRC32 into the fresh table."""
    data = np.arange(CB, dtype=np.uint8).tobytes()
    crcs, hashes = mf.chunk_digests(memoryview(data), [CB])
    # the decision hash must be a 128-bit SHA-256 prefix
    assert len(hashes[0]) == 2 * mf.CHUNK_HASH_BYTES == 32
    assert hashes[0] == hashlib.sha256(data).hexdigest()[:32]
    base_leaf = mf.LeafSpec.make("w", (CB,), np.uint8, False, None)
    base_leaf["chunks"] = {"bytes": CB, "crc32": list(crcs),
                           "hash": list(hashes)}
    base_doc = mf.document(0, [base_leaf], {})

    def fresh(h):
        s = mf.LeafSpec.make("w", (CB,), np.uint8, False, None)
        s["chunks"] = {"bytes": CB, "hash": [h]}
        return s

    # hash matches -> referenced, nothing stored; the base's CRC32 is
    # inherited (no fresh CRC pass over the unchanged fraction)
    s = fresh(hashes[0])
    ckdelta.plan_refs([s], base_doc, "base.scda",
                      views=[memoryview(data)])
    assert s["present"] == [] and s["src"] == [1]
    assert s["chunks"]["crc32"] == list(crcs)
    # CRC32 would collide (same bytes CRC'd) but the content hash
    # differs -> stored, never referenced: CRC equality is irrelevant
    # to the decision
    s = fresh("0" * 2 * mf.CHUNK_HASH_BYTES)
    ckdelta.plan_refs([s], base_doc, "base.scda",
                      views=[memoryview(data)])
    assert s["present"] == [0] and s["src"] == [0]
    assert s["chunks"]["crc32"] == list(crcs)  # computed from the bytes
    # a chunk table lacking CRC32s without the bytes to derive them is
    # a caller error, not a silently CRC-less manifest
    with pytest.raises(ValueError, match="no crc32"):
        ckdelta.plan_refs([fresh(hashes[0])], base_doc, "base.scda")


def test_manifest_version_taxonomy(tmp_path):
    paths, _ = _save_chain(tmp_path, 2, compressed=False)
    assert pytree_io.read_manifest(paths[0])["version"] == 1
    assert pytree_io.read_manifest(paths[1])["version"] == 2
    with pytest.raises(ValueError, match="version"):
        mf.parse(json.dumps({"format": "repro-scda-checkpoint",
                             "version": 3}).encode())


def test_delta_save_requires_single_rank(tmp_path):
    path = str(tmp_path / "multi.scda")
    tree = _tree(0)

    def workload(comm):
        try:
            pytree_io.save(path, tree, comm=comm, record_hashes=True)
            return None
        except ScdaError as err:
            comm.barrier()
            return err.code.name

    assert run_ranks(ThreadComm.group(2), workload) == \
        ["ARG_SEQUENCE", "ARG_SEQUENCE"]


# --------------------------------------------------------------------------
# Manager integration: chain growth, depth cap, chain-aware retention
# --------------------------------------------------------------------------

def test_manager_delta_chain_and_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10, delta=True,
                            chunk_bytes=CB)
    trees = [_tree(0)]
    mgr.save(0, trees[0], blocking=True)
    for k in range(1, 4):
        trees.append(_mutate(trees[-1], k))
        mgr.save(k, trees[k], blocking=True)
    doc = pytree_io.read_manifest(mgr.path_for(3))
    assert doc["delta"]["depth"] == 3
    got, step = mgr.restore_latest()
    assert step == 3
    _assert_tree_equal(got, trees[3])


def test_manager_chain_depth_cap_forces_full_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=10, delta=True,
                            delta_chain=2, chunk_bytes=CB)
    t = _tree(0)
    for k in range(4):
        mgr.save(k, t, blocking=True)
        t = _mutate(t, k + 1)
    docs = [pytree_io.read_manifest(mgr.path_for(k)) for k in range(4)]
    assert "delta" not in docs[0]
    assert docs[1]["delta"]["depth"] == 1
    assert docs[2]["delta"]["depth"] == 2
    assert "delta" not in docs[3]      # cap reached: full (but hashed) save
    assert mf.content_id(docs[3])      # still a usable future base
    assert docs[3]["version"] == 1


def test_manager_retention_protects_referenced_bases(tmp_path):
    """Dropping old steps must never strand a retained delta: referenced
    bases (and their sidecars) survive; unreferenced ones are deleted."""
    mgr = CheckpointManager(str(tmp_path), keep=2, delta=True,
                            chunk_bytes=CB)
    trees = [_tree(0)]
    mgr.save(0, trees[0], blocking=True)
    for k in range(1, 5):
        trees.append(_mutate(trees[-1], k))
        mgr.save(k, trees[k], blocking=True)
    kept = mgr.all_steps()
    assert kept[-2:] == [3, 4]
    # steps 3 and 4 are deltas referencing step 0 (the bulk of every
    # leaf still lives there): retention must have kept it
    doc = pytree_io.read_manifest(mgr.path_for(4))
    referenced = {b["file"] for b in doc["delta"]["bases"]}
    assert _ckpt_name(0) in referenced
    assert os.path.exists(mgr.path_for(0))
    for name in referenced:
        assert os.path.exists(os.path.join(str(tmp_path), name))
    # ... and the chain restores
    got, step = mgr.restore_latest()
    assert step == 4
    _assert_tree_equal(got, trees[4])


def test_manager_retention_drops_unreferenced_steps(tmp_path):
    """A full-rewrite step cuts the chain: older archives fall out of the
    reference closure and retention reclaims them (sidecars included)."""
    def fresh(seed):
        rng = np.random.default_rng(seed)
        return {"w": rng.standard_normal((64, 48)).astype(np.float32),
                "b": rng.standard_normal((1 << 13,)),
                "m": rng.integers(0, 255, (3, 5, 7), dtype=np.uint8),
                "empty": np.zeros((0, 4), np.int32),
                "scalar": np.float32(3.25), "lr": 0.125}

    mgr = CheckpointManager(str(tmp_path), keep=2, delta=True,
                            chunk_bytes=CB)
    mgr.save(0, _tree(0), blocking=True)
    mgr.save(1, _mutate(_tree(0), 1), blocking=True)
    # steps 2..3: every chunk regenerated — deltas that share no chunk
    # with (and hence do not reference) steps 0..1
    mgr.save(2, fresh(50), blocking=True)
    mgr.save(3, _mutate(fresh(50), 60), blocking=True)
    for b in pytree_io.read_manifest(mgr.path_for(3))["delta"]["bases"]:
        assert b["file"] != _ckpt_name(0)
    assert mgr.all_steps() == [2, 3]
    assert not os.path.exists(mgr.path_for(0))
    assert not os.path.exists(mgr.path_for(1))
    assert not os.path.exists(mgr.path_for(0) + ".scdax")
    got, step = mgr.restore_latest()
    assert step == 3


def test_manager_env_default_enables_delta(tmp_path, monkeypatch):
    monkeypatch.setenv(ckdelta.DELTA_ENV, "1")
    monkeypatch.setenv(ckdelta.CHAIN_ENV, "5")
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.delta is True and mgr.delta_chain == 5
    monkeypatch.setenv(ckdelta.DELTA_ENV, "0")
    assert CheckpointManager(str(tmp_path)).delta is False


# --------------------------------------------------------------------------
# Squash and logical diff
# --------------------------------------------------------------------------

@pytest.mark.parametrize("compressed", [False, True])
def test_squash_byte_identical_to_full_save(tmp_path, compressed):
    paths, trees = _save_chain(tmp_path, 3, compressed)
    sq = str(tmp_path / "squash.scda")
    ckdelta.squash(paths[2], sq)
    direct = str(tmp_path / "direct.scda")
    pytree_io.save(direct, trees[2], step=2, compressed=compressed,
                   chunk_bytes=CB, record_hashes=True)
    with open(sq, "rb") as a, open(direct, "rb") as b:
        assert a.read() == b.read()
    assert ckdelta.checkpoint_diff(sq, paths[2]) == []


def test_checkpoint_diff_reports_changed_chunks(tmp_path):
    paths, _ = _save_chain(tmp_path, 2, compressed=False)
    lines = ckdelta.checkpoint_diff(paths[0], paths[1])
    assert any(l.startswith("leaf w:") for l in lines)
    assert not any(l.startswith("leaf b:") for l in lines)
    assert any("step" in l for l in lines)


# --------------------------------------------------------------------------
# scdatool chain tooling
# --------------------------------------------------------------------------

class TestCli:
    def test_ls_json(self, tmp_path, capsys):
        paths, _ = _save_chain(tmp_path, 2, compressed=False)
        assert cli_main(["ls", "--json", paths[1]]) == 0
        doc = json.loads(capsys.readouterr().out)
        ck = doc["checkpoint"]
        assert ck["version"] == 2 and ck["step"] == 1
        assert ck["delta"]["depth"] == 1
        assert ck["delta"]["bases"][0]["file"] == \
            os.path.basename(paths[0])
        assert ck["delta"]["chunks_stored"] < ck["delta"]["chunks_total"]
        assert {s["user"] for s in doc["sections"]} >= \
            {"scda-ckpt status", "scda-ckpt manifest"}

    def test_ls_plain_mentions_chain(self, tmp_path, capsys):
        paths, _ = _save_chain(tmp_path, 2, compressed=False)
        assert cli_main(["ls", paths[1]]) == 0
        assert "delta checkpoint: depth 1" in capsys.readouterr().out

    def test_verify_chain_and_fsck_clean(self, tmp_path, capsys):
        paths, _ = _save_chain(tmp_path, 3, compressed=True)
        assert cli_main(["verify", "--chain", paths[2]]) == 0
        assert "verified" in capsys.readouterr().out
        assert cli_main(["fsck", paths[2]]) == 0

    def test_verify_chain_catches_rewritten_base(self, tmp_path, capsys):
        paths, _ = _save_chain(tmp_path, 2, compressed=False)
        pytree_io.save(paths[0], _tree(7), step=0, chunk_bytes=CB,
                       record_hashes=True)
        assert cli_main(["verify", "--chain", paths[1]]) == 1
        assert "rewritten" in capsys.readouterr().out
        assert cli_main(["fsck", "--fast", paths[1]]) == 1

    def test_squash_then_logical_diff(self, tmp_path, capsys):
        paths, _ = _save_chain(tmp_path, 3, compressed=False)
        sq = str(tmp_path / "sq.scda")
        assert cli_main(["squash", paths[2], sq, "--index"]) == 0
        assert os.path.exists(sq + ".scdax")
        assert cli_main(["diff", "--logical", sq, paths[2]]) == 0
        out = capsys.readouterr().out
        assert "chain depth 2 -> 0" in out
        assert "same checkpoint state" in out
        # physical diff of chain vs squash differs, logical does not
        assert cli_main(["diff", sq, paths[2]]) == 1
        capsys.readouterr()
        assert cli_main(["diff", "--logical", sq, paths[0]]) == 1
