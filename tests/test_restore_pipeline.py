"""The overlapped restore engine (PR 3): scatter reads, prefetch,
read_batch, and the pipelined checkpoint restore scheduler.

Core invariant: the pipeline changes WHEN bytes are read and WHERE they
inflate, never WHAT is returned — every pipelined result must be
byte-identical to the serial forward-walk oracle (REPRO_SCDA_PREFETCH=0),
at every reading partition, and every failure must raise the same
ScdaError the serial path raises (no hangs, no leaked futures).
"""
import os

import numpy as np
import pytest

from repro.checkpoint import pytree_io
from repro.core import (ScdaError, ThreadComm, fopen_read, fopen_write,
                        partition, run_ranks)
from repro.core.errors import ScdaErrorCode
from repro.core.io_backend import FileBackend, prefetch_window
from repro.core.pipeline import ReadItem, run_pipeline

PF = 1 << 20  # pipelined prefetch window used throughout
V_SIZES = [5, 0, 17, 3, 64, 1]


def write_all_kinds(path):
    rng = __import__("random").Random(7)
    elems = [bytes(rng.randrange(256) for _ in range(s)) for s in V_SIZES]
    blk = b"0123456789abcdef" * 40
    arr = bytes(range(256)) * 2
    with fopen_write(None, path, user_string=b"pipeline test") as f:
        f.write_inline(b"inl", b"#" * 32)
        f.write_block(b"blk", blk)
        f.write_array(b"arr", arr, [64], 8)
        f.write_varray(b"var", elems, [len(elems)], V_SIZES)
        f.write_block(b"zblk", blk, encode=True)
        f.write_array(b"zarr", arr, [128], 4, encode=True)
        f.write_varray(b"zvar", elems, [len(elems)], V_SIZES, encode=True)
    return blk, arr, elems


# --------------------------------------------------------------------------
# FileBackend: read_scatter / preadv / prefetch / readahead refit
# --------------------------------------------------------------------------

class TestReadScatter:
    @pytest.fixture
    def datafile(self, tmp_path):
        path = str(tmp_path / "d.bin")
        data = bytes(np.random.default_rng(0).integers(
            0, 256, 1 << 20, dtype=np.uint8))
        with open(path, "wb") as fh:
            fh.write(data)
        return path, data

    def test_adjacent_and_gapped_fragments(self, datafile):
        path, data = datafile
        b = FileBackend(path, "r", create=False)
        bufs = [bytearray(100), bytearray(50), bytearray(200),
                bytearray(0), bytearray(7)]
        b.read_scatter([(0, bufs[0]), (100, bufs[1]), (500, bufs[2]),
                        (700, bufs[3]), (700, bufs[4])])
        assert bytes(bufs[0]) == data[:100]
        assert bytes(bufs[1]) == data[100:150]
        assert bytes(bufs[2]) == data[500:700]
        assert bytes(bufs[4]) == data[700:707]
        b.close()

    def test_truncation_raises_like_pread(self, datafile):
        path, data = datafile
        b = FileBackend(path, "r", create=False)
        with pytest.raises(ScdaError) as ei:
            b.read_scatter([(len(data) - 10, bytearray(100))])
        assert ei.value.code == ScdaErrorCode.CORRUPT_TRUNCATED
        b.close()

    def test_prefetch_serves_reads_and_release_advises(self, datafile):
        path, data = datafile
        b = FileBackend(path, "r", create=False)
        accepted = b.prefetch([(1000, 4096), (5096, 4096), (20000, 512)],
                              window=1 << 20)
        assert accepted == 3
        out = bytearray(8192)
        b.read_scatter([(1000, out)])  # served from the prefetch cache
        assert bytes(out) == data[1000:9192]
        assert b.pread(20000, 100) == data[20000:20100]
        b.release(10000)
        assert b.pending_prefetch() == 1  # the 20000 extent survives
        b.release(1 << 30)
        assert b.pending_prefetch() == 0
        b.close()

    def test_prefetch_window_bounds_buffering(self, datafile):
        path, _ = datafile
        b = FileBackend(path, "r", create=False)
        # 16 KiB window cannot accept 1 MiB of extents up front.
        extents = [(i * 4096, 4096) for i in range(256)]
        accepted = b.prefetch(extents, window=16 << 10)
        assert 0 < accepted < len(extents)
        b.close()
        assert b.pending_prefetch() == 0  # close drains everything

    def test_prefetch_noop_on_write_mode_and_zero_window(self, tmp_path):
        path = str(tmp_path / "w.bin")
        b = FileBackend(path, "w", create=True)
        assert b.prefetch([(0, 10)], window=1 << 20) == 0
        b.close()
        datapath = str(tmp_path / "r.bin")
        with open(datapath, "wb") as fh:
            fh.write(b"x" * 100)
        b = FileBackend(datapath, "r", create=False)
        assert b.prefetch([(0, 10)], window=0) == 0
        assert b.pending_prefetch() == 0
        b.close()

    def test_refit_readahead_on_jump(self, datafile):
        path, data = datafile
        b = FileBackend(path, "r", create=False, readahead=4096)
        b.pread(0, 32)  # window at 0
        assert b._cache_off == 0
        b.refit_readahead(300000)  # jump outside → drop and refit
        assert b._cache_off == 300000 and len(b._cache) > 0
        assert b.pread(300010, 20) == data[300010:300030]
        b.refit_readahead(300100)  # inside the window → untouched
        assert b._cache_off == 300000
        b.close()

    def test_run_pipeline_serial_equals_pipelined(self, datafile):
        path, data = datafile
        items = [ReadItem(i, [(i * 1000, 500), ((i + 1) * 1000, 250)])
                 for i in range(20)]
        results = {}
        for pf in (0, PF):
            b = FileBackend(path, "r", create=False)
            results[pf] = {k: [bytes(x) for x in res]
                           for k, res in run_pipeline(b, items, pf)}
            b.close()
        assert results[0] == results[PF]
        assert results[0][3][0] == data[3000:3500]


# --------------------------------------------------------------------------
# read_batch: byte-identity against the forward walk at P∈{1,2,4,8}
# --------------------------------------------------------------------------

@pytest.mark.parametrize("P", [1, 2, 4, 8])
@pytest.mark.parametrize("pf", [0, PF])
def test_read_batch_matches_forward_walk(tmp_path, P, pf):
    path = str(tmp_path / "a.scda")
    blk, arr, elems = write_all_kinds(path)
    # serial oracle: full payloads via the forward walk
    oracle = {}
    with fopen_read(None, path) as r:
        i = 0
        while not r.at_eof:
            hdr = r.read_section_header()
            if hdr.type == "I":
                oracle[i] = r.read_inline_data()
            elif hdr.type == "B":
                oracle[i] = r.read_block_data()
            elif hdr.type == "A":
                oracle[i] = b"".join(r.read_array_data([hdr.N]))
            else:
                sizes = r.read_varray_sizes([hdr.N])
                oracle[i] = b"".join(r.read_varray_data([hdr.N], sizes))
            i += 1

    batchable = {2: 64, 3: len(V_SIZES), 5: 128, 6: len(V_SIZES)}

    def workload(comm):
        out = {}
        with fopen_read(comm, path) as r:
            reqs = []
            for sec, N in batchable.items():
                counts = partition.uniform(N, comm.size)
                offs = partition.offsets(counts)
                lo, n = offs[comm.rank], counts[comm.rank]
                reqs.append((sec, [(lo, n)] if n else []))
            for pos, res in r.read_batch(reqs, prefetch_bytes=pf):
                out[list(batchable)[pos]] = b"".join(res)
        return out

    per_rank = run_ranks(ThreadComm.group(P), workload)
    for sec in batchable:
        joined = b"".join(rank[sec] for rank in per_rank)
        assert joined == oracle[sec], f"section {sec} differs under P={P}"


def test_read_batch_window_validation(tmp_path):
    path = str(tmp_path / "a.scda")
    write_all_kinds(path)
    with fopen_read(None, path) as r:
        with pytest.raises(ScdaError):
            list(r.read_batch([(2, [(60, 10)])]))  # beyond N=64
        with pytest.raises(ScdaError):
            list(r.read_batch([(0, [(0, 1)])]))  # inline not batchable
        with pytest.raises(ScdaError):
            list(r.read_batch([(99, [(0, 1)])]))


# --------------------------------------------------------------------------
# Checkpoint restore: pipelined == serial oracle, raw + compressed
# --------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 48)).astype(np.float32),
        "b": np.arange(1 << 15, dtype=np.float64),  # compressible
        "m": rng.integers(0, 255, (3, 5, 7), dtype=np.uint8),
        "empty": np.zeros((0, 4), np.int32),
        "scalar": np.float32(3.25),
        "lr": 0.125,
    }


@pytest.mark.parametrize("compressed", [False, True])
def test_restore_pipelined_equals_serial(tmp_path, compressed):
    path = str(tmp_path / "ck.scda")
    tree = _tree()
    pytree_io.save(path, tree, step=11, compressed=compressed,
                   chunk_bytes=1 << 12)
    serial, st0 = pytree_io.restore(path, prefetch_bytes=0)
    piped, st1 = pytree_io.restore(path, prefetch_bytes=PF)
    assert st0 == st1 == 11
    for k in ("w", "b", "m", "empty", "scalar"):
        np.testing.assert_array_equal(serial[k], piped[k])
        np.testing.assert_array_equal(piped[k], tree[k])
    assert piped["lr"] == tree["lr"]


@pytest.mark.parametrize("compressed", [False, True])
def test_restore_leaf_pipelined_equals_serial(tmp_path, compressed):
    path = str(tmp_path / "ck.scda")
    tree = _tree(1)
    pytree_io.save(path, tree, compressed=compressed, chunk_bytes=1 << 12)
    for name in ("w", "b", "m"):
        serial = pytree_io.restore_leaf(path, name, prefetch_bytes=0)
        piped = pytree_io.restore_leaf(path, name, prefetch_bytes=PF)
        np.testing.assert_array_equal(serial, piped)
    assert pytree_io.restore_leaf(path, "lr", prefetch_bytes=PF) == 0.125


@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_restore_identity_under_thread_ranks(tmp_path, P):
    """Every rank's pipelined restore equals the serial oracle — prefetch
    on and off, raw and compressed, concurrently at P ranks."""
    raw = str(tmp_path / "raw.scda")
    comp = str(tmp_path / "comp.scda")
    tree = _tree(2)
    pytree_io.save(raw, tree)
    pytree_io.save(comp, tree, compressed=True, chunk_bytes=1 << 12)
    oracle = {p: pytree_io.restore(p, prefetch_bytes=0)[0]
              for p in (raw, comp)}

    def workload(comm):
        # rank-local pipelined restores against one shared file
        out = {}
        for p in (raw, comp):
            out[p], _ = pytree_io.restore(p, prefetch_bytes=PF)
        return out

    for rank_out in run_ranks(ThreadComm.group(P), workload):
        for p in (raw, comp):
            for k in ("w", "b", "m", "empty", "scalar"):
                np.testing.assert_array_equal(rank_out[p][k], oracle[p][k])


def test_restore_like_pipelined_equals_serial(tmp_path):
    jax = pytest.importorskip("jax")
    path = str(tmp_path / "ck.scda")
    tree = _tree(3)
    pytree_io.save(path, tree, step=5)
    like = {"w": jax.ShapeDtypeStruct((64, 48), np.float32),
            "b": jax.ShapeDtypeStruct((1 << 15,), np.float64),
            "lr": 0.0}
    serial, _ = pytree_io.restore(path, like, prefetch_bytes=0)
    piped, _ = pytree_io.restore(path, like, prefetch_bytes=PF)
    np.testing.assert_array_equal(serial["w"], piped["w"])
    np.testing.assert_array_equal(serial["b"], piped["b"])
    assert piped["lr"] == 0.125

    bad = {"w": jax.ShapeDtypeStruct((4, 4), np.float32)}
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(path, bad, prefetch_bytes=PF)
    assert ei.value.code == ScdaErrorCode.ARG_SEQUENCE


def test_prefetch_env_knob(tmp_path, monkeypatch):
    path = str(tmp_path / "ck.scda")
    tree = _tree(4)
    pytree_io.save(path, tree)
    monkeypatch.setenv("REPRO_SCDA_PREFETCH", "0")
    assert prefetch_window() == 0
    s0, _ = pytree_io.restore(path)
    monkeypatch.setenv("REPRO_SCDA_PREFETCH", str(PF))
    assert prefetch_window() == PF
    s1, _ = pytree_io.restore(path)
    for k in ("w", "b", "m"):
        np.testing.assert_array_equal(s0[k], s1[k])


# --------------------------------------------------------------------------
# Failure behavior: same errors as serial, no hangs, no leaked futures
# --------------------------------------------------------------------------

def _leaf_payload_extent(path):
    """(data_start, end) of the compressed leaf's carrier V payload."""
    from repro.core import ScdaIndex
    idx = ScdaIndex.build(path)
    for e in idx:
        if e.kind == "zV":
            return e.v_data_start, e.end
    raise AssertionError("no compressed leaf found")


@pytest.fixture
def corrupt_compressed_ckpt(tmp_path):
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, _tree(5), compressed=True, chunk_bytes=1 << 12)
    data_start, end = _leaf_payload_extent(path)
    with open(path, "r+b") as fh:  # clobber a chunk mid-payload
        fh.seek(data_start + (end - data_start) // 2)
        fh.write(b"\x00" * 16)
    return path


def test_corrupt_chunk_same_error_serial_vs_pipelined(
        corrupt_compressed_ckpt):
    path = corrupt_compressed_ckpt
    errors = {}
    for pf in (0, PF):
        with pytest.raises(ScdaError) as ei:
            pytree_io.restore(path, prefetch_bytes=pf)
        errors[pf] = ei.value.code
    assert errors[0] == errors[PF]
    assert errors[0] in (ScdaErrorCode.CORRUPT_ENCODING,
                         ScdaErrorCode.CORRUPT_CHECKSUM)


@pytest.mark.parametrize("sizes,want", [
    ([3000, 5000, 2000], "ok"),       # re-chunked, total preserved
    ([4096, 4096, 1900], "error"),    # total disagrees with the manifest
])
def test_foreign_chunking_parity(tmp_path, sizes, want):
    """A foreign archive whose chunk sizes stray from the manifest layout
    (chunk count intact, U-entries self-consistent): the serial oracle
    joins chunks boundary-blind and checks only the total, so the
    pipelined whole-leaf path must do exactly the same — same bytes when
    the total matches, same CORRUPT_CHECKSUM when it doesn't."""
    from repro.checkpoint import manifest as mf
    orig = str(tmp_path / "orig.scda")
    data = np.arange(2500, dtype=np.float32)  # 10000 bytes, 3 chunks @4096
    pytree_io.save(orig, {"w": data}, compressed=True, chunk_bytes=4096)
    with fopen_read(None, orig) as r:
        r.read_section_header()
        status = r.read_inline_data()
        r.read_section_header()
        man = r.read_block_data()
    path = str(tmp_path / "foreign.scda")
    flat, chunks, pos = data.tobytes(), [], 0
    for s in sizes:
        c = flat[pos:pos + s]
        chunks.append(c + b"\0" * (s - len(c)))
        pos += s
    with fopen_write(None, path, user_string=b"repro checkpoint") as w:
        w.write_inline(mf.STATUS_USER_STRING, status)
        w.write_block(mf.MANIFEST_USER_STRING, man, E=None)
        w.write_varray(mf.leaf_user_string(0), chunks, [len(sizes)],
                       [len(c) for c in chunks], encode=True)
    outcomes = []
    for pf in (0, PF):
        try:
            out, _ = pytree_io.restore(path, prefetch_bytes=pf)
            outcomes.append(("ok", out["w"].tobytes()))
        except ScdaError as e:
            outcomes.append(("error", e.code))
    assert outcomes[0] == outcomes[1]
    assert outcomes[0][0] == want
    if want == "ok":
        assert outcomes[0][1] == flat
    else:
        assert outcomes[0][1] == ScdaErrorCode.CORRUPT_CHECKSUM


def test_corrupt_chunk_no_leaked_futures(corrupt_compressed_ckpt):
    path = corrupt_compressed_ckpt
    # reader-level: batch every chunk of the corrupt leaf
    with fopen_read(None, path) as r:
        idx = r.index()
        sec = next(i for i, e in enumerate(idx.entries) if e.kind == "zV")
        N = idx.entries[sec].N
        with pytest.raises(ScdaError) as ei:
            for _ in r.read_batch([(sec, [(0, N)])], prefetch_bytes=PF):
                pass
        assert ei.value.code in (ScdaErrorCode.CORRUPT_ENCODING,
                                 ScdaErrorCode.CORRUPT_CHECKSUM)
        backend = r._backend
    # close() ran inside the context manager: everything drained
    assert backend.pending_prefetch() == 0
    assert backend._pf_pool is None


def test_truncated_archive_same_error_serial_vs_pipelined(tmp_path):
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, _tree(6))
    size = os.path.getsize(path)
    with open(path, "r+b") as fh:
        fh.truncate(size - 200)  # cut into the last leaf's payload
    errors = {}
    for pf in (0, PF):
        with pytest.raises(ScdaError) as ei:
            pytree_io.restore(path, prefetch_bytes=pf)
        errors[pf] = ei.value.code
    assert errors[0] == errors[PF] == ScdaErrorCode.CORRUPT_TRUNCATED


def test_short_chunk_raises_scda_error_not_valueerror():
    """A chunk shorter than the manifest geometry implies (corrupt or
    foreign U-entries) must raise CORRUPT_CHECKSUM from both scatter
    implementations, never a bare ValueError."""
    runs = [(0, 0, 2048)]
    chunks = {0: b"x" * 1024, 1: b"y" * 100}  # chunk 1 short of 1024
    with pytest.raises(ScdaError) as ei:
        pytree_io._scatter_chunks(runs, chunks, 1024, bytearray(2048))
    assert ei.value.code == ScdaErrorCode.CORRUPT_CHECKSUM
    with pytest.raises(ScdaError) as ei:
        pytree_io._scatter_chunks_np(runs, chunks, 1024,
                                     np.empty(2048, np.uint8))
    assert ei.value.code == ScdaErrorCode.CORRUPT_CHECKSUM
