"""Erasure-coded shard sets: GF(2^8) algebra, parity archive validity,
degraded-mode restores under every covered loss combination, byte-exact
shard rebuilds, parity-aware fsck/repair, the advisory writer lock, and
verify-on-restore."""
import glob
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import pytree_io, redundancy as red, sharding
from repro.checkpoint.manager import CheckpointManager
from repro.core import (ScdaError, ScdaErrorCode, ThreadComm, faults,
                        fopen_read, run_ranks)
from repro.tools.fsck import fsck_file, repair_set


def _assert_tree_equal(got, want):
    assert set(got) == set(want)
    for k, v in want.items():
        if isinstance(v, np.ndarray) or hasattr(v, "dtype"):
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(v))
        else:
            assert got[k] == v


def _fuzz_tree(rng, max_leaves=5):
    dtypes = [np.float32, np.int32, np.uint8, np.float16]
    tree = {}
    for i in range(int(rng.integers(2, max_leaves + 1))):
        dt = dtypes[int(rng.integers(0, len(dtypes)))]
        shape = (int(rng.integers(1, 4000)),)
        if np.issubdtype(dt, np.floating):
            tree[f"leaf{i:02d}"] = rng.standard_normal(shape).astype(dt)
        else:
            tree[f"leaf{i:02d}"] = rng.integers(0, 100, shape).astype(dt)
    tree["aux_note"] = "hello"
    return tree


def _read_doc(path):
    return sharding.read_sharded_manifest(path)


def _shard_paths(path, doc):
    base = os.path.dirname(path)
    return [os.path.join(base, s["file"]) for s in doc["shards"]]


def _parity_paths(path, doc):
    base = os.path.dirname(path)
    return [os.path.join(base, r["file"])
            for r in (doc.get("parity") or {}).get("files", [])]


# ------------------------------------------------------------- GF(2^8) ----

class TestGF:
    def test_mul_matches_reference(self):
        rng = np.random.default_rng(0)
        for _ in range(200):
            a = int(rng.integers(0, 256))
            b = int(rng.integers(0, 256))
            # schoolbook carry-less multiply mod 0x11d
            acc, x, y = 0, a, b
            while y:
                if y & 1:
                    acc ^= x
                x <<= 1
                if x & 0x100:
                    x ^= 0x11D
                y >>= 1
            assert red.gf_mul(a, b) == acc

    def test_inverse(self):
        for a in range(1, 256):
            assert red.gf_mul(a, red.gf_inv(a)) == 1

    def test_mul_table_vectorized(self):
        rng = np.random.default_rng(1)
        data = rng.integers(0, 256, 512).astype(np.uint8)
        for c in (0, 1, 2, 37, 255):
            acc = np.zeros(512, dtype=np.uint8)
            red._mul_into(acc, c, data.tobytes())
            want = np.array([red.gf_mul(c, int(v)) for v in data],
                            dtype=np.uint8)
            np.testing.assert_array_equal(acc, want)

    def test_rs_coefficients_distinct_rows(self):
        # Any 2x2 minor of the rs8 coefficient matrix must be
        # invertible for 2-erasure decoding to exist.
        for x in range(8):
            for y in range(x + 1, 8):
                a, b = red._coeff(x, 0), red._coeff(y, 0)
                c, d = red._coeff(x, 1), red._coeff(y, 1)
                det = red.gf_mul(a, d) ^ red.gf_mul(b, c)
                assert det != 0, (x, y)

    def test_geometry_limits(self):
        red.check_geometry(4, 0)
        red.check_geometry(4, 2)
        with pytest.raises(ScdaError):
            red.check_geometry(4, 3)
        with pytest.raises(ScdaError):
            red.check_geometry(256, 2)


# ------------------------------------------------- parity file format ----

class TestParityFormat:
    def test_parity_naming_round_trip(self):
        p = red.parity_file("/x/ck.scda", 1, 2)
        assert os.path.basename(p) == "ck-p01of02.scda"
        assert red.is_parity_name("ck-p01of02.scda") == ("ck.scda", 1, 2)
        assert red.is_parity_name("ck-s01of02.scda") is None
        assert sharding.is_shard_name("ck-p01of02.scda") is None

    def test_parity_files_are_valid_scda(self, tmp_path):
        path = str(tmp_path / "ck.scda")
        tree = _fuzz_tree(np.random.default_rng(2))
        pytree_io.save(path, tree, step=1, shards=3, parity=2)
        doc = _read_doc(path)
        assert doc["parity"]["code"] == "rs8"
        for pp in _parity_paths(path, doc):
            findings = fsck_file(pp, deep=True)
            assert not findings, findings
            with fopen_read(None, pp) as r:
                meta = red._parity_sections(r)[0]
            assert meta["format"] == red.PARITY_FORMAT

    def test_xor_parity_is_xor_of_streams(self, tmp_path):
        path = str(tmp_path / "ck.scda")
        pytree_io.save(path, _fuzz_tree(np.random.default_rng(3)),
                       step=1, shards=2, parity=1)
        doc = _read_doc(path)
        shard_bytes = [open(p, "rb").read()
                       for p in _shard_paths(path, doc)]
        length = doc["parity"]["length"]
        want = np.zeros(length, dtype=np.uint8)
        for b in shard_bytes:
            want[:len(b)] ^= np.frombuffer(b, dtype=np.uint8)
        pp = _parity_paths(path, doc)[0]
        with fopen_read(None, pp) as r:
            meta, data_start, nbytes = red._parity_sections(r)
            got = r._backend.pread(data_start, nbytes)
        np.testing.assert_array_equal(
            np.frombuffer(got, dtype=np.uint8), want)

    def test_manifest_records_code_geometry_and_ids(self, tmp_path):
        path = str(tmp_path / "ck.scda")
        pytree_io.save(path, _fuzz_tree(np.random.default_rng(4)),
                       step=1, shards=4, parity=2)
        prec = _read_doc(path)["parity"]
        assert prec["m"] == 2 and len(prec["files"]) == 2
        for j, rec in enumerate(prec["files"]):
            pp = str(tmp_path / rec["file"])
            assert os.path.getsize(pp) == rec["bytes"]
            meta = red.read_parity_meta(pp)
            assert red.parity_id(meta) == rec["id"]
            assert meta["j"] == j and meta["code"] == "rs8"


# ------------------------------------- non-degraded byte identity ---------

@pytest.mark.parametrize("P", [1, 2, 4, 8])
@pytest.mark.parametrize("parity", [0, 1, 2])
def test_parity_never_changes_data_shards_fuzzed(tmp_path, P, parity):
    """Data shard files (and restores) at parity m are byte-identical to
    the parity=0 save — parity only ADDS files, raw and compressed."""
    rng = np.random.default_rng(100 + 10 * P + parity)
    # Compressed saves need chunk-aligned partitions (serial comm only).
    variants = (False, True) if P == 1 else (False,)
    for trial, compressed in enumerate(variants):
        tree = _fuzz_tree(rng)
        os.makedirs(tmp_path / f"o{trial}")
        os.makedirs(tmp_path / f"p{trial}")
        oracle = str(tmp_path / f"o{trial}" / "ck.scda")
        pytree_io.save(oracle, tree, step=trial, shards=2,
                       compressed=compressed)
        path = str(tmp_path / f"p{trial}" / "ck.scda")

        def workload(comm):
            pytree_io.save(path, tree, step=trial, comm=comm, shards=2,
                           parity=parity, compressed=compressed)
        run_ranks(ThreadComm.group(P), workload)
        for k in range(2):
            got = open(sharding.shard_file(path, k, 2), "rb").read()
            want = open(sharding.shard_file(oracle, k, 2), "rb").read()
            assert got == want, f"shard {k} differs (P={P} m={parity})"
        out, step = pytree_io.restore(path)
        assert step == trial
        _assert_tree_equal(out, tree)


# --------------------------------------------- degraded-mode restore ------

@pytest.mark.parametrize("n", [2, 4, 8])
def test_every_single_shard_loss_restores_xor(tmp_path, n):
    rng = np.random.default_rng(200 + n)
    tree = _fuzz_tree(rng)
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=n, parity=1)
    doc = _read_doc(path)
    originals = {p: open(p, "rb").read() for p in _shard_paths(path, doc)}
    for lost in _shard_paths(path, doc):
        os.remove(lost)
        out, step = pytree_io.restore(path)
        assert step == 1
        _assert_tree_equal(out, tree)
        with open(lost, "wb") as f:
            f.write(originals[lost])


@pytest.mark.parametrize("n", [2, 4, 8])
def test_every_two_shard_loss_restores_rs8(tmp_path, n):
    rng = np.random.default_rng(300 + n)
    tree = _fuzz_tree(rng)
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=2, shards=n, parity=2)
    doc = _read_doc(path)
    paths = _shard_paths(path, doc)
    originals = {p: open(p, "rb").read() for p in paths}
    combos = [(a,) for a in range(n)] \
        + [(a, b) for a in range(n) for b in range(a + 1, n)]
    for combo in combos:
        for i in combo:
            os.remove(paths[i])
        out, step = pytree_io.restore(path)
        assert step == 2, combo
        _assert_tree_equal(out, tree)
        for i in combo:
            with open(paths[i], "wb") as f:
                f.write(originals[paths[i]])


def test_data_plus_parity_loss_within_budget(tmp_path):
    """m=2 covers one data + one parity shard lost at once."""
    tree = _fuzz_tree(np.random.default_rng(5))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=3, parity=2)
    doc = _read_doc(path)
    os.remove(_shard_paths(path, doc)[0])
    os.remove(_parity_paths(path, doc)[1])
    out, _ = pytree_io.restore(path)
    _assert_tree_equal(out, tree)


def test_loss_beyond_budget_refused_loudly(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(6))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=4, parity=1)
    doc = _read_doc(path)
    for p in _shard_paths(path, doc)[:2]:
        os.remove(p)
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(path)
    assert "unrecoverable" in str(ei.value)


def test_rewritten_shard_triggers_degraded_read(tmp_path):
    """A shard rewritten in place (content-id mismatch) reconstructs
    through parity instead of refusing."""
    tree = _fuzz_tree(np.random.default_rng(7))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=2, parity=1)
    doc = _read_doc(path)
    victim = _shard_paths(path, doc)[0]
    other = {"other": np.zeros(10, dtype=np.float32)}
    pytree_io.save(victim, other, step=9)
    out, _ = pytree_io.restore(path)
    _assert_tree_equal(out, tree)


def test_degraded_restore_leaf_and_like(tmp_path):
    tree = {"a": np.arange(1000, dtype=np.float32),
            "b": np.ones((5, 5), dtype=np.float64)}
    path = str(tmp_path / "ck.scda")
    doc = pytree_io.save(path, tree, step=1, shards=2, parity=1)
    placement = {e["name"]: e["shard"] for e in doc["leaves"]}
    lost_k = placement["a"]
    os.remove(sharding.shard_file(path, lost_k, 2))
    got = pytree_io.restore_leaf(path, "a")
    np.testing.assert_array_equal(got, tree["a"])
    like = {"a": np.zeros_like(tree["a"]), "b": np.zeros_like(tree["b"])}
    out, _ = pytree_io.restore(path, like=like)
    _assert_tree_equal(out, tree)


def test_degraded_delta_chain_over_sharded_base(tmp_path):
    """Losing a shard of the BASE set still resolves a delta restore."""
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=4, shards=2, parity=1, delta=True,
                            delta_chain=3)
    rng = np.random.default_rng(8)
    t1 = {"w": rng.standard_normal(2048).astype(np.float32)}
    t2 = {"w": t1["w"].copy()}
    t2["w"][:4] += 1.0
    mgr.save(1, t1, blocking=True)
    mgr.save(2, t2, blocking=True)
    base_shards = sorted(glob.glob(os.path.join(
        d, "step_0000000001-s*.scda")))
    os.remove(base_shards[0])
    out, step = mgr.restore_latest()
    assert step == 2
    np.testing.assert_array_equal(out["w"], t2["w"])
    mgr.close()


def test_missing_and_unlink_fault_specs(tmp_path):
    tree = {"a": np.arange(256, dtype=np.float32), "b": np.ones(300)}
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=2, parity=1)
    with faults.inject("open:missing:path=-s00of02:count=-1") as inj:
        out, _ = pytree_io.restore(path)
    _assert_tree_equal(out, tree)
    assert any(k == "missing" for _, _, k in inj.injected)

    path2 = str(tmp_path / "ck2.scda")
    pytree_io.save(path2, tree, step=1, shards=2, parity=1)
    with faults.inject("open:unlink:path=ck2-s00of02:count=-1") as inj:
        out, _ = pytree_io.restore(path2)
    _assert_tree_equal(out, tree)
    assert any(k == "unlink" for _, _, k in inj.injected)
    assert not os.path.exists(str(tmp_path / "ck2-s00of02.scda"))


# ------------------------------------------------------ rebuild / fsck ----

def test_rebuild_shard_byte_identical(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(9))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=3, shards=4, parity=2)
    doc = _read_doc(path)
    paths = _shard_paths(path, doc)
    originals = {p: open(p, "rb").read() for p in paths}
    os.remove(paths[1])
    os.remove(paths[3])
    for p in (paths[1], paths[3]):
        red.rebuild_shard(path, doc, os.path.basename(p))
        assert open(p, "rb").read() == originals[p]
    assert red.set_health(path)[0] == "clean"


def test_rebuild_parity_shard_byte_identical(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(10))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=3, shards=2, parity=2)
    doc = _read_doc(path)
    pp = _parity_paths(path, doc)[1]
    orig = open(pp, "rb").read()
    os.remove(pp)
    red.rebuild_shard(path, doc, os.path.basename(pp))
    assert open(pp, "rb").read() == orig


def test_set_health_classification(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(11))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=3, parity=1)
    doc = _read_doc(path)
    assert red.set_health(path)[0] == "clean"
    lost = _shard_paths(path, doc)[2]
    data = open(lost, "rb").read()
    os.remove(lost)
    health, lost_data, _ = red.set_health(path)
    assert health == "degraded-recoverable"
    assert lost_data == [os.path.basename(lost)]
    os.remove(_shard_paths(path, doc)[0])
    assert red.set_health(path)[0] == "unrecoverable"
    with open(lost, "wb") as f:
        f.write(data)
    assert red.set_health(path)[0] == "degraded-recoverable"


def test_fsck_reports_set_health(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(12))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=2, parity=1)
    assert not fsck_file(path)
    doc = _read_doc(path)
    lost = _shard_paths(path, doc)[0]
    os.remove(lost)
    msgs = [f.message for f in fsck_file(path)]
    health = [m for m in msgs if m.startswith("set health:")]
    assert health and "degraded-recoverable" in health[0]
    assert os.path.basename(lost) in health[0]


def test_repair_rebuild_cli_path(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(13))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=3, parity=1)
    doc = _read_doc(path)
    lost = _shard_paths(path, doc)[1]
    orig = open(lost, "rb").read()
    os.remove(lost)
    results = repair_set(path, rebuild=True)
    actions = {os.path.basename(r.path): r.action for r in results}
    assert actions[os.path.basename(lost)] == "rebuilt"
    assert open(lost, "rb").read() == orig
    assert not fsck_file(path)


def test_repair_set_rebuilds_damaged_manifest(tmp_path):
    """Satellite: per-shard repair + manifest rebuild from surviving
    shard headers when the manifest itself is mangled."""
    tree = {"a": np.arange(2048, dtype=np.float32),
            "b": np.ones((32, 32))}
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=9, shards=3, parity=1)
    with open(path, "r+b") as f:
        f.write(b"\x00" * 128)
    results = repair_set(path, rebuild=True)
    assert results[0].action == "rebuilt"
    out, step = pytree_io.restore(path)
    assert step == 9
    _assert_tree_equal(out, tree)


def test_repair_set_manifest_gone_plus_shard_lost(tmp_path):
    tree = {"a": np.arange(2048, dtype=np.float32),
            "b": np.ones((32, 32))}
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=9, shards=3, parity=1)
    doc = _read_doc(path)
    os.remove(path)
    os.remove(_shard_paths(path, doc)[1])
    # scdatool routes this through set repair via sibling shard names
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.tools.cli", "repair", "--rebuild",
         path], capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    out, step = pytree_io.restore(path)
    assert step == 9
    _assert_tree_equal(out, tree)


# --------------------------------------------------- manager / lockfile ---

def test_manager_parity_knob_and_retention(tmp_path, monkeypatch):
    d = str(tmp_path / "ck")
    tree = {"a": np.arange(64, dtype=np.float32), "b": np.ones(500)}
    mgr = CheckpointManager(d, keep=1, shards=2, parity=1)
    mgr.save(1, tree, blocking=True)
    mgr.save(2, tree, blocking=True)
    names = set(os.listdir(d))
    assert "step_0000000002-p00of01.scda" in names
    assert "step_0000000001-p00of01.scda" not in names  # swept with set
    mgr.close()
    monkeypatch.setenv(red.PARITY_ENV, "2")
    mgr2 = CheckpointManager(d, keep=1, shards=3)
    assert mgr2.parity == 2
    mgr2.close()


def test_manager_degraded_restore_latest(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": np.arange(512, dtype=np.float32), "b": np.ones(700)}
    with CheckpointManager(d, keep=2, shards=2, parity=1) as mgr:
        mgr.save(5, tree, blocking=True)
        lost = glob.glob(os.path.join(d, "step_0000000005-s0*.scda"))[0]
        os.remove(lost)
        out, step = mgr.restore_latest()
    assert step == 5
    _assert_tree_equal(out, tree)


def test_writer_lock_excludes_live_holder(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2, shards=0)
    lock = os.path.join(d, ".scda-lock")
    assert os.path.exists(lock)
    # same pid shares silently (multiple managers in one process)
    mgr2 = CheckpointManager(d, keep=2, shards=0)
    # a live FOREIGN holder refuses
    with open(lock, "w") as f:
        json.dump({"pid": os.getpid() + 1, "host": "elsewhere",
                   "time": __import__("time").time()}, f)
    with pytest.raises(ScdaError) as ei:
        CheckpointManager(d, keep=2, shards=0)
    assert ei.value.code == ScdaErrorCode.FS_OPEN
    os.remove(lock)
    mgr.close()
    mgr2.close()


def test_writer_lock_stale_takeover(tmp_path, caplog):
    d = str(tmp_path / "ck")
    os.makedirs(d)
    lock = os.path.join(d, ".scda-lock")
    # same-host pid that is certainly dead
    with open(lock, "w") as f:
        json.dump({"pid": 2 ** 22 + 1,
                   "host": __import__("socket").gethostname(),
                   "time": 0.0}, f)
    with caplog.at_level("WARNING", logger="repro.scda"):
        mgr = CheckpointManager(d, keep=2, shards=0)
    assert "TAKING OVER" in caplog.text
    mgr.close()
    assert not os.path.exists(lock)


# --------------------------------------------------- verify-on-restore ----

def test_verify_restore_needs_checksummed_sidecar(tmp_path):
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, {"a": np.arange(64, dtype=np.float32)}, step=1)
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(path, verify=True)
    assert ei.value.code == ScdaErrorCode.ARG_SEQUENCE
    assert "scdatool index --checksums" in str(ei.value)


def test_verify_restore_catches_payload_corruption(tmp_path, monkeypatch):
    path = str(tmp_path / "ck.scda")
    tree = {"a": np.arange(512, dtype=np.float32)}
    pytree_io.save(path, tree, step=1)
    with fopen_read(None, path) as r:
        r.index().with_checksums(r).write_sidecar()
    out, _ = pytree_io.restore(path, verify=True)
    _assert_tree_equal(out, tree)
    off = os.path.getsize(path) - 200  # inside the tensor payload
    with open(path, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(path, verify=True)
    assert ei.value.code == ScdaErrorCode.CORRUPT_CHECKSUM
    assert ei.value.offset is not None
    # the env knob takes the same path
    monkeypatch.setenv(pytree_io.VERIFY_RESTORE_ENV, "1")
    with pytest.raises(ScdaError):
        pytree_io.restore(path)


def test_verify_restore_covers_shards(tmp_path):
    path = str(tmp_path / "ck.scda")
    tree = {"a": np.arange(512, dtype=np.float32), "b": np.ones(700)}
    pytree_io.save(path, tree, step=1, shards=2)
    doc = _read_doc(path)
    for p in [path] + _shard_paths(path, doc):
        with fopen_read(None, p) as r:
            r.index().with_checksums(r).write_sidecar()
    out, _ = pytree_io.restore(path, verify=True)
    _assert_tree_equal(out, tree)
    victim = _shard_paths(path, doc)[0]
    off = os.path.getsize(victim) - 64
    with open(victim, "r+b") as f:
        f.seek(off)
        b = f.read(1)
        f.seek(off)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(path, verify=True)
    assert ei.value.code == ScdaErrorCode.CORRUPT_CHECKSUM
