"""Codec edge cases the fast path must preserve (§3.1 rules).

These pin the corners the vectorized/streaming implementation could get
wrong: extreme zlib levels, both line-break styles, zero-length varray
elements, and the exact-multiple-of-76 single-trailing-break rule.  All
example-based — they run with or without hypothesis.
"""
import base64
import os
import struct
import zlib

import pytest

from repro.core import (ScdaError, SerialComm, ThreadComm, codec, encode,
                        fopen_read, fopen_write, partition, run_ranks,
                        spec)
from repro.core.errors import ScdaErrorCode


class TestZlibLevels:
    """REPRO_ZLIB_LEVEL=0 (stored blocks) and 9 (best) are both legal."""

    @pytest.mark.parametrize("level", [0, 1, 6, 9])
    @pytest.mark.parametrize("style", [spec.UNIX, spec.MIME])
    def test_roundtrip_all_levels(self, level, style):
        payloads = [b"", b"x", b"a" * 1000, os.urandom(5000),
                    bytes(range(256)) * 16]
        for data in payloads:
            stream = codec.compress(data, style, level)
            assert codec.decompress(stream) == data

    def test_level_zero_is_stored(self):
        # level 0 emits stored (uncompressed) deflate blocks — bigger than
        # the input, but a legal stream any inflater accepts.
        data = os.urandom(4096)
        stream = codec.compress(data, level=0)
        assert codec.decompress(stream) == data
        assert len(stream) > len(data)

    def test_levels_interoperate(self):
        # a reader never knows the writer's level; streams at every level
        # carry identical logical content
        data = b"mixed " * 500 + os.urandom(100)
        for level in (0, 9):
            assert codec.decompress(codec.compress(data, level=level)) == data

    def test_env_level_round_trips(self, monkeypatch):
        # REPRO_ZLIB_LEVEL is read at import into DEFAULT_LEVEL; reload the
        # module under each extreme and roundtrip with the default path.
        import importlib
        try:
            for level in ("0", "9"):
                monkeypatch.setenv("REPRO_ZLIB_LEVEL", level)
                importlib.reload(codec)
                assert codec.DEFAULT_LEVEL == int(level)
                data = os.urandom(2048)
                assert codec.decompress(codec.compress(data)) == data
        finally:
            monkeypatch.delenv("REPRO_ZLIB_LEVEL", raising=False)
            importlib.reload(codec)


class TestLineBreakStyles:
    """MIME vs UNIX §2.1 break bytes on the stage-2 framing."""

    @pytest.mark.parametrize("nbytes", [0, 1, 56, 57, 58, 500, 4096])
    def test_break_geometry_both_styles(self, nbytes):
        data = os.urandom(nbytes)
        for style, brk in ((spec.UNIX, b"=\n"), (spec.MIME, b"\r\n")):
            stream = codec.compress(data, style)
            # every 78-byte chunk ends with the style's break bytes; the
            # final (possibly short) chunk does too
            i = 0
            while i < len(stream):
                chunk = stream[i:i + 78]
                assert chunk[-2:] == brk, (style, nbytes, i)
                i += len(chunk)
            assert codec.decompress(stream) == data

    def test_styles_decode_identically(self):
        # §2.1: the writer's style choice has no effect on reading
        data = os.urandom(1000)
        assert (codec.decompress(codec.compress(data, spec.UNIX))
                == codec.decompress(codec.compress(data, spec.MIME))
                == data)

    def test_break_bytes_are_not_validated(self):
        # §3.1: the 2 break bytes are arbitrary on read — only geometry;
        # decode with clobbered break bytes must equal the original decode
        data = os.urandom(300)
        bad = bytearray(codec.compress(data))
        assert len(bad) > 78
        bad[76:78] = b"!!"
        assert codec.decompress(bytes(bad)) == data


class TestZeroLengthVarrayElements:
    """Zero-byte elements: compressed streams exist for them, and raw
    varrays must carry them partition-independently."""

    def test_empty_element_compresses_and_inflates(self):
        stream = codec.compress(b"")
        stage1 = base64.b64decode(
            b"".join(stream[i:i + 78][:-2]
                     for i in range(0, len(stream), 78)), validate=True)
        assert struct.unpack(">Q", stage1[:8])[0] == 0
        assert codec.decompress(stream) == b""

    def test_encoded_varray_with_empty_elements_roundtrip(self, tmp_path):
        sizes = [0, 5, 0, 0, 123, 0]
        elements = [os.urandom(s) for s in sizes]
        path = str(tmp_path / "v0.scda")
        with fopen_write(SerialComm(), path) as f:
            f.write_varray(b"v", elements, [len(sizes)], sizes, encode=True)
        with fopen_read(SerialComm(), path) as r:
            hdr = r.read_section_header(decode=True)
            assert hdr.type == "V" and hdr.decoded and hdr.N == len(sizes)
            got_sizes = r.read_varray_sizes([len(sizes)])
            assert got_sizes == sizes
            assert r.read_varray_data([len(sizes)], got_sizes) == elements

    def test_all_empty_elements_parallel_equals_serial(self, tmp_path):
        elements = [b""] * 7
        oracle = encode.encode_file(b"vendor", b"user", [
            encode.encode_varray(b"v", elements)])
        path = str(tmp_path / "allempty.scda")
        counts = [3, 0, 4]
        offs = partition.offsets(counts)

        def workload(comm):
            with fopen_write(comm, path, b"user", b"vendor") as f:
                f.write_varray(b"v",
                               elements[offs[comm.rank]:offs[comm.rank + 1]],
                               counts, [0] * counts[comm.rank])

        run_ranks(ThreadComm.group(len(counts)), workload)
        with open(path, "rb") as fh:
            assert fh.read() == oracle


class TestExact76Multiple:
    """§3.1: an encoded payload that is an exact multiple of 76 code bytes
    gets exactly ONE trailing break (the full final line's own)."""

    @staticmethod
    def _stage1_len(data, level):
        return len(base64.b64encode(
            struct.pack(">Q", len(data)) + b"z" + zlib.compress(data, level)))

    def _find_exact_multiple(self, level):
        # deterministic sweep for a payload whose stage-2 encoding is an
        # exact multiple of 76
        for n in range(400):
            data = bytes((i * 13 + n) % 256 for i in range(n))
            if self._stage1_len(data, level) % 76 == 0:
                return data
        raise AssertionError("no exact-76-multiple payload in sweep")

    @pytest.mark.parametrize("style", [spec.UNIX, spec.MIME])
    def test_single_trailing_break(self, style):
        level = 6
        data = self._find_exact_multiple(level)
        stream = codec.compress(data, style, level)
        enc_len = self._stage1_len(data, level)
        assert enc_len % 76 == 0
        # exactly one break per full line, none extra
        assert len(stream) == enc_len + (enc_len // 76) * 2
        assert stream.endswith(codec._LINE_BREAK[style])
        assert not stream.endswith(codec._LINE_BREAK[style] * 2)
        assert codec.decompress(stream) == data

    def test_one_past_multiple_gets_short_line(self):
        # the neighboring case: 76k+1 code bytes → short final line + break
        level = 6
        for n in range(400):
            data = bytes((i * 11 + n) % 256 for i in range(n))
            enc_len = self._stage1_len(data, level)
            if enc_len % 76 == 1:
                stream = codec.compress(data, level=level)
                assert len(stream) == enc_len + (enc_len // 76 + 1) * 2
                assert codec.decompress(stream) == data
                return
        pytest.skip("no 76k+1 case found in sweep")


class TestCompressElementsParity:
    """The (possibly thread-pooled) batch compressor must be byte-identical
    to element-wise compress at every size mix."""

    def test_batch_equals_scalar(self):
        elements = [os.urandom(s) for s in
                    (0, 1, 100, 0, 65536, 7, 0, 300000, 12, 300000)]
        for style in (spec.UNIX, spec.MIME):
            batch = codec.compress_elements(elements, style)
            scalar = [codec.compress(e, style) for e in elements]
            assert batch == scalar

    def test_batch_accepts_memoryviews(self):
        data = os.urandom(1 << 16)
        views = [memoryview(data)[i:i + 4096]
                 for i in range(0, len(data), 4096)]
        assert codec.compress_elements(views) == \
            [codec.compress(bytes(v)) for v in views]


class TestFastStage1Parity:
    """The single-pass stage-2 fast decode (geometry-verified lenient
    base64) must match the strict reference decoder byte-for-byte on
    every valid stream, and decline anything unusual."""

    def test_fast_equals_strict_across_sizes_and_styles(self):
        rng = __import__("random").Random(3)
        sizes = [0, 1, 2, 3, 55, 56, 57, 76, 1023, 1024, 4096, 65537]
        for style in (spec.UNIX, spec.MIME):
            for n in sizes:
                for data in (bytes(rng.randrange(256) for _ in range(n)),
                             bytes(n)):
                    z = codec.compress(data, style)
                    assert codec.decompress(z) == data
                    strict = base64.b64decode(codec._unbreak_lines(z),
                                              validate=True)
                    fast = codec._fast_stage1(z)
                    if fast is not None:
                        assert bytes(fast) == strict, (style, n)

    def test_fast_declines_exotic_break_bytes(self):
        z = bytearray(codec.compress(os.urandom(4096)))
        # §3.1 allows arbitrary break bytes; the fast path must hand
        # such streams to the reference decoder, not mis-decode them.
        z[76] = ord("#")
        assert codec._fast_stage1(bytes(z)) is None
        with pytest.raises(ScdaError):
            # strict path still validates the code bytes...
            codec.decompress(bytes(z[:76] + z[78:]))
        # ...but the stream with only its break bytes rewritten decodes
        # to the same payload through the reference path
        z2 = bytearray(codec.compress(b"x" * 4096))
        for i in range(76, len(z2), 78):
            z2[i] = ord("!")
        assert codec.decompress(bytes(z2)) == b"x" * 4096

    def test_invalid_code_byte_error_parity(self):
        # Lenient a2b_base64 *skips* bytes outside the alphabet, so a
        # corrupted code byte sails through the fast parse and only
        # fails at inflate — the canonical-fallback retry must surface
        # the reference path's CORRUPT_ENCODING, not CORRUPT_CHECKSUM,
        # through every batch entry point.
        z = bytearray(codec.compress(os.urandom(1 << 20)))
        for pos in (0, 40, 100, len(z) - 5):
            if z[pos] in codec._LINE_BREAK[spec.UNIX]:
                continue
            bad = bytes(z[:pos]) + b"\xff" + bytes(z[pos + 1:])
            batch = [bad] * codec._POOL_MIN_ELEMENTS  # force the pool path
            with pytest.raises(ScdaError) as serial:
                codec.decompress(bad)
            with pytest.raises(ScdaError) as pooled:
                codec.decompress_elements(batch)
            with pytest.raises(ScdaError) as submitted:
                codec.submit_decompress_batch(batch).result()
            assert serial.value.code == pooled.value.code \
                == submitted.value.code == ScdaErrorCode.CORRUPT_ENCODING

    def test_fast_accepts_trailing_padding(self):
        # Streams whose stage-1 length is not a multiple of 3 end with
        # 1–2 '=' padding bytes; the strict-acceptance gate must not
        # reject those legal streams.  Generate until both padding
        # widths have been seen.
        rng = __import__("random").Random(7)
        seen = set()
        for _ in range(500):
            if seen == {1, 2}:
                break
            data = bytes(rng.randrange(256)
                         for _ in range(rng.randrange(60, 400)))
            z = codec.compress(data)
            enc = codec._unbreak_lines(z)
            pad = len(enc) - len(enc.rstrip(b"="))
            if pad == 0 or len(z) < 78 + 3:
                continue
            seen.add(pad)
            fast = codec._fast_stage1(z)
            assert fast is not None
            assert bytes(fast) == base64.b64decode(enc, validate=True)
            assert codec.decompress_elements([z]) == [data]
        assert seen == {1, 2}, seen

    def test_batch_decompress_parity_and_sizes(self):
        elements = [os.urandom(s) for s in (0, 1, 4096, 300000, 7)]
        streams = [codec.compress(e) for e in elements]
        assert codec.decompress_elements(streams) == elements
        assert codec.decompress_elements(
            streams, [len(e) for e in elements]) == elements
        with pytest.raises(ScdaError) as ei:
            codec.decompress_elements(streams, [len(e) + 1
                                                for e in elements])
        assert ei.value.code == ScdaErrorCode.CORRUPT_CHECKSUM
