"""End-to-end telemetry (repro.core.trace): metrics aggregation, Chrome
trace_event export and schema, byte-parity of traced vs untraced saves,
the warn() channel, per-commit journal records, error op-context, and
the scdatool stats / --timing surfaces."""
import json
import os
import time

import numpy as np
import pytest

from repro.checkpoint import pytree_io, sharding
from repro.checkpoint.manager import CheckpointManager
from repro.core import (ScdaError, ScdaErrorCode, ThreadComm, run_ranks,
                        trace)
from repro.core.io_backend import FileBackend
from repro.journal import iter_records
from repro.tools import cli

WW = 1 << 16  # write window enabling the background writeback path


@pytest.fixture(autouse=True)
def _trace_isolation(monkeypatch):
    monkeypatch.delenv(trace.TRACE_ENV, raising=False)
    trace.uninstall()
    trace.reset_warn_limits()
    yield
    trace.uninstall()
    trace.reset_warn_limits()


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 33)).astype(np.float32),
        "b": np.arange(257, dtype=np.int64),
        "bytes": np.frombuffer(b"scda trace " * 300,
                               dtype=np.uint8).copy(),
    }


def _assert_tree_equal(got, want):
    assert set(got) == set(want)
    for k, v in want.items():
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(v))


# ------------------------------------------------------------ metrics ----

def test_metrics_counters_and_histograms():
    m = trace.Metrics()
    m.count("io.pwrite.calls")
    m.count("io.pwrite.calls", 2)
    m.count("io.pwrite.bytes", 4096)
    for us in (1.0, 10.0, 100.0, 1000.0):
        m.observe("io.pwrite.us", us)
    snap = m.snapshot()
    assert snap["counters"]["io.pwrite.calls"] == 3
    assert snap["counters"]["io.pwrite.bytes"] == 4096
    h = snap["histograms"]["io.pwrite.us"]
    assert h["count"] == 4
    assert h["min_us"] == 1.0 and h["max_us"] == 1000.0
    assert h["mean_us"] == pytest.approx(1111.0 / 4)
    assert h["p50_us"] <= h["p99_us"]
    assert json.dumps(snap)  # plain-dict, JSON-able as-is


def test_commit_record_returns_deltas():
    c = trace.TraceCollector()
    c.metrics.count("io.pwrite.calls", 5)
    first = c.commit_record()
    assert first == {"io.pwrite.calls": 5}
    assert c.commit_record() == {}  # nothing new since
    c.metrics.count("io.pwrite.calls", 2)
    c.metrics.count("io.fsync.calls")
    assert c.commit_record() == {"io.pwrite.calls": 2,
                                 "io.fsync.calls": 1}


# ----------------------------------------------------------- activation ----

def test_quiet_by_default_and_env_activation(tmp_path, monkeypatch):
    assert trace.collector() is None
    monkeypatch.setenv(trace.TRACE_ENV, "mem")
    c = trace.collector()
    assert c is not None and c.path is None
    assert trace.collector() is c  # installed, not re-created
    trace.uninstall()
    target = str(tmp_path / "t.json")
    monkeypatch.setenv(trace.TRACE_ENV, target)
    c = trace.collector()
    assert c is not None and c.path == target
    c.event("hello", "ckpt")
    assert trace.flush() == target
    assert trace.load_chrome(target)


def test_quiet_path_is_cheap():
    # The disabled guard is one global load + one environ lookup; a
    # generous absolute bound catches an accidental allocation or I/O
    # on the quiet path without being timing-flaky.
    assert trace.collector() is None
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        trace.collector()
    per_call_us = (time.perf_counter() - t0) * 1e6 / n
    assert per_call_us < 25.0


def test_scoped_installs_and_restores(tmp_path):
    outer = trace.install(trace.TraceCollector())
    inner = trace.TraceCollector()
    with trace.scoped(inner) as got:
        assert got is inner
        assert trace.collector() is inner
    assert trace.collector() is outer
    # a path scope exports on exit
    target = str(tmp_path / "scoped.json")
    with trace.scoped(target) as c:
        c.event("x", "ckpt")
    assert os.path.exists(target)


# ------------------------------------------------------- chrome schema ----

def _spans_nest(events):
    """Complete events on one tid must nest (contain or be disjoint)."""
    by_tid = {}
    for ev in events:
        if ev["ph"] == "X":
            by_tid.setdefault(ev["tid"], []).append(
                (ev["ts"], ev["ts"] + ev.get("dur", 0.0)))
    for spans in by_tid.values():
        spans.sort()
        for i, (s0, e0) in enumerate(spans):
            for s1, e1 in spans[i + 1:]:
                if s1 >= e0:
                    break  # disjoint, and sorted: all later ones too
                assert e1 <= e0 + 1e-6, \
                    f"partial overlap: [{s0},{e0}] vs [{s1},{e1}]"


def test_traced_sharded_parity_save_restore_chrome_trace(tmp_path):
    """The acceptance path: a traced sharded+parity save/restore yields
    a loadable Chrome trace with pid/tid/ts/dur spans that nest, real
    io events, and a non-empty per-stage summary."""
    path = str(tmp_path / "ck.scda")
    tree = _tree()
    target = str(tmp_path / "trace.json")
    tc = trace.install(trace.TraceCollector(path=target))
    try:
        pytree_io.save(path, tree, step=9, shards=2, parity=1,
                       compressed=True)
        out, step = pytree_io.restore(path)
    finally:
        trace.uninstall()
    assert step == 9
    _assert_tree_equal(out, tree)
    tc.export()
    events = trace.load_chrome(target)
    assert events
    cats = set()
    for ev in events:
        assert set(ev) >= {"name", "cat", "ph", "pid", "tid", "ts"}
        if ev["ph"] == "X":
            assert "dur" in ev and ev["dur"] >= 0
        cats.add(ev["cat"])
    assert {"io", "ckpt"} <= cats
    _spans_nest(events)
    names = {ev["name"] for ev in events}
    assert {"save", "restore", "parity_encode",
            "shard_placement"} <= names
    assert any(ev["cat"] == "io" and ev["name"] in ("pwrite", "pwritev")
               for ev in events)
    summary = trace.summarize_chrome(events)
    assert summary["wall_us"] > 0
    assert summary["io_calls"] > 0 and summary["io_bytes"] > 0
    assert any(k.startswith("ckpt.save") for k in summary["stages"])
    lines = list(trace.format_summary(summary))
    assert lines and lines[0].startswith("wall ")


# ----------------------------------------------------------- byte parity ----

@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_traced_saves_byte_identical(tmp_path, P):
    """Tracing must never perturb bytes: traced saves are byte-identical
    to untraced ones — raw, compressed (serial: compressed parallel
    saves need chunk-aligned partitions), and sharded+parity."""
    configs = [dict(shards=0, parity=0, compressed=False),
               dict(shards=3, parity=1, compressed=False)]
    if P == 1:
        configs.append(dict(shards=0, parity=0, compressed=True))
        configs.append(dict(shards=2, parity=1, compressed=True))
    for i, cfg in enumerate(configs):
        tree = _tree(seed=100 + i)

        def run(tag, traced):
            d = tmp_path / f"{tag}{i}"
            os.makedirs(d)
            path = str(d / "ck.scda")

            def workload(comm):
                pytree_io.save(path, tree, comm=comm, step=i, **cfg)
            tc = trace.install(trace.TraceCollector()) if traced else None
            try:
                if P == 1:
                    pytree_io.save(path, tree, step=i, **cfg)
                else:
                    run_ranks(ThreadComm.group(P), workload)
                out, _ = pytree_io.restore(path)
            finally:
                if traced:
                    trace.uninstall()
            _assert_tree_equal(out, tree)
            if traced:
                assert tc.metrics.get("io.pwrite.calls") \
                    + tc.metrics.get("io.pwritev.calls") > 0
            return {n: (d / n).read_bytes()
                    for n in sorted(os.listdir(d))
                    if not n.endswith(".scdax")}
        assert run("plain", False) == run("traced", True), \
            f"P={P} cfg={cfg}: tracing changed bytes"


# ------------------------------------------------------------- warn() ----

def test_warn_logs_and_rate_limits(caplog):
    c = trace.install(trace.TraceCollector())
    with caplog.at_level("WARNING", logger="repro.scda"):
        assert trace.warn("shard s0 lost", key="k1")
        assert not trace.warn("shard s0 lost", key="k1")  # suppressed
        assert trace.warn("other problem", key="k2")
        assert trace.warn("always", interval=0)
        assert trace.warn("always", interval=0)
    assert caplog.text.count("shard s0 lost") == 1
    assert "other problem" in caplog.text
    snap = c.metrics.snapshot()["counters"]
    assert snap["warn.emitted"] == 4
    assert snap["warn.suppressed"] == 1
    trace.reset_warn_limits()
    with caplog.at_level("WARNING", logger="repro.scda"):
        assert trace.warn("shard s0 lost", key="k1")  # limit forgotten


def test_degraded_read_warns_once_per_set(tmp_path, caplog):
    path = str(tmp_path / "ck.scda")
    tree = _tree(seed=7)
    pytree_io.save(path, tree, step=1, shards=2, parity=1)
    os.remove(sharding.shard_file(path, 1, 2))
    with caplog.at_level("WARNING", logger="repro.scda"):
        out, _ = pytree_io.restore(path)
    _assert_tree_equal(out, tree)
    assert "DEGRADED READ" in caplog.text


# ----------------------------------------------- journal metrics sink ----

def test_manager_journals_commit_record(tmp_path):
    d = str(tmp_path / "ck")
    tc = trace.install(trace.TraceCollector())
    try:
        with CheckpointManager(d, keep=3, shards=0) as mgr:
            mgr.save(1, _tree(), blocking=True)
            mgr.save(2, _tree(seed=1), blocking=True)
    finally:
        trace.uninstall()
    newest = os.path.join(d, "step_0000000002.scda")
    recs = [rec for _, rec in iter_records(newest)]
    traced = [r for r in recs if any(k.startswith("trace/")
                                     for k in r["data"])]
    assert traced, f"no trace record in journal: {recs}"
    data = traced[-1]["data"]
    assert any(k.startswith("trace/io.") for k in data)
    assert all(isinstance(v, int) for v in data.values())


# ---------------------------------------------------- error op-context ----

def test_writeback_error_carries_op_context(tmp_path, monkeypatch):
    b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)

    def boom(fd, bufs, off):
        raise OSError(28, "No space left on device")

    monkeypatch.setattr(os, "pwritev", boom)
    b.submit_write_gather([(0, b"z" * 100)], window=WW)
    monkeypatch.undo()
    with pytest.raises(ScdaError) as ei:
        b.drain_writes()
    err = ei.value
    assert err.code == ScdaErrorCode.FS_WRITE
    assert err.stage == "writeback"
    assert err.op_context["offset"] == 0
    assert err.op_context["bytes"] == 100
    assert err.op_context["path"].endswith("w.bin")
    b.close()


# -------------------------------------------------- CLI: stats/--timing ----

def test_cli_stats_table_and_json(tmp_path, capsys):
    path = str(tmp_path / "a.scda")
    pytree_io.save(path, _tree(), step=1, compressed=True)
    assert cli.main(["stats", path]) == 0
    out = capsys.readouterr().out
    assert "sections" in out and "ratio" in out
    assert cli.main(["stats", "--json", path]) == 0
    doc = json.loads(capsys.readouterr().out)
    f = doc["files"][0]
    assert f["stored_bytes"] > 0
    assert f["logical_bytes"] >= f["stored_bytes"]  # §3 compresses
    kinds = {row["kind"] for row in f["sections"]}
    assert any(k.startswith("z") for k in kinds)


def test_cli_stats_expands_sharded_set(tmp_path, capsys):
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, _tree(), step=1, shards=2)
    assert cli.main(["stats", "--json", path]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert len(doc["files"]) == 3  # manifest + 2 shards


def test_cli_stats_trace_summary(tmp_path, capsys):
    path = str(tmp_path / "ck.scda")
    target = str(tmp_path / "trace.json")
    with trace.scoped(target):
        pytree_io.save(path, _tree(), step=1, shards=2, parity=1)
    assert cli.main(["stats", "--trace", target]) == 0
    out = capsys.readouterr().out
    assert "wall " in out and "io." in out
    assert cli.main(["stats", "--trace", target, "--json", path]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["trace"]["io_calls"] > 0
    assert doc["trace"]["stages"]
    # no args at all is a usage error
    assert cli.main(["stats"]) == 2


def test_cli_verify_and_fsck_timing(tmp_path, capsys):
    path = str(tmp_path / "a.scda")
    pytree_io.save(path, _tree(), step=1)
    assert cli.main(["index", "--checksums", path]) == 0
    capsys.readouterr()
    assert cli.main(["verify", "--timing", path]) == 0
    out = capsys.readouterr().out
    assert "verified" in out
    assert "# verify timing:" in out and "bytes scanned" in out
    assert cli.main(["fsck", "--timing", path]) == 0
    out = capsys.readouterr().out
    assert "clean" in out and "# fsck timing:" in out


# ----------------------------------------------------- save(trace=...) ----

def test_save_trace_kwarg_exports(tmp_path):
    path = str(tmp_path / "ck.scda")
    target = str(tmp_path / "save-trace.json")
    pytree_io.save(path, _tree(), step=4, trace=target)
    assert trace.collector() is None  # scope restored
    events = trace.load_chrome(target)
    assert any(ev["name"] == "save" and ev["cat"] == "ckpt"
               for ev in events)
    tc = trace.TraceCollector()
    pytree_io.save(path, _tree(), step=5, trace=tc)
    assert tc.metrics.get("ckpt.save.calls") == 1
