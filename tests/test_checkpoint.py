"""Checkpoint layer: shard-run decomposition, pytree round-trips,
compressed chunking, manager semantics (async/atomic/retention/fallback)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (CheckpointManager, chunk_sizes, leaf_name,
                              read_manifest, restore, runs_cover_exactly,
                              save, shard_runs)
from repro.core import ScdaError, scan_sections


# ------------------------------------------------------------------ layout --
class TestShardRuns:
    def test_whole_tensor_is_one_run(self):
        runs = shard_runs((4, 6), (slice(0, 4), slice(0, 6)), 4)
        assert runs == [(0, 0, 96)]

    def test_leading_axis_shard_is_one_run(self):
        runs = shard_runs((8, 6), (slice(2, 4), slice(0, 6)), 4)
        assert runs == [(2 * 6 * 4, 0, 2 * 6 * 4)]

    def test_trailing_axis_shard_is_strided(self):
        runs = shard_runs((4, 6), (slice(0, 4), slice(3, 6)), 1)
        assert runs == [(3, 0, 3), (9, 3, 3), (15, 6, 3), (21, 9, 3)]

    def test_2d_block(self):
        runs = shard_runs((4, 6), (slice(2, 4), slice(0, 3)), 1)
        assert runs == [(12, 0, 3), (18, 3, 3)]

    def test_scalar(self):
        assert shard_runs((), (), 8) == [(0, 0, 8)]

    def test_empty_shard(self):
        assert shard_runs((4, 6), (slice(2, 2), slice(0, 6)), 4) == []

    @given(st.integers(1, 12), st.integers(1, 12), st.integers(1, 4),
           st.data())
    @settings(max_examples=50, deadline=None)
    def test_runs_reassemble_correctly(self, d0, d1, itemsize, data):
        """Property: runs copy exactly the shard's bytes at the right spots."""
        a0 = data.draw(st.integers(0, d0 - 1))
        b0 = data.draw(st.integers(a0 + 1, d0))
        a1 = data.draw(st.integers(0, d1 - 1))
        b1 = data.draw(st.integers(a1 + 1, d1))
        global_ = np.arange(d0 * d1 * itemsize, dtype=np.uint8) % 251
        global_ = global_.reshape(d0, d1 * itemsize)
        elem = global_.reshape(d0, d1, itemsize)
        shard = elem[a0:b0, a1:b1]
        flat_shard = shard.tobytes()
        flat_global = global_.tobytes()
        runs = shard_runs((d0, d1), (slice(a0, b0), slice(a1, b1)), itemsize)
        assert sum(n for _, _, n in runs) == len(flat_shard)
        for goff, loff, n in runs:
            assert flat_global[goff:goff + n] == flat_shard[loff:loff + n]

    def test_cover_exactly(self):
        r1 = shard_runs((4, 4), (slice(0, 2), slice(0, 4)), 1)
        r2 = shard_runs((4, 4), (slice(2, 4), slice(0, 4)), 1)
        assert runs_cover_exactly([r1, r2], 16)
        assert not runs_cover_exactly([r1, r1], 16)
        assert not runs_cover_exactly([r1], 16)

    def test_chunk_sizes(self):
        assert chunk_sizes(0, 10) == []
        assert chunk_sizes(10, 10) == [10]
        assert chunk_sizes(25, 10) == [10, 10, 5]


# ---------------------------------------------------------------- round-trip --
def make_state():
    k = jax.random.PRNGKey(0)
    return {
        "params": {
            "embed": jax.random.normal(k, (32, 16), jnp.float32),
            "layers": {
                "w": jax.random.normal(k, (4, 16, 16), jnp.bfloat16),
                "b": jnp.zeros((4, 16), jnp.float32),
            },
        },
        "opt": {
            "mu": jnp.ones((32, 16), jnp.float32) * 0.5,
            "count": jnp.array(7, jnp.int32),
        },
        "step": 123,             # aux (non-array) leaf
        "run_name": "test-run",  # aux string leaf
    }


def assert_tree_equal(a, b):
    fa, _ = jax.tree_util.tree_flatten(a)
    fb, _ = jax.tree_util.tree_flatten(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        if isinstance(x, (jax.Array, np.ndarray)):
            assert np.asarray(x).dtype == np.asarray(y).dtype
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            assert x == y


class TestPytreeRoundTrip:
    def test_raw(self, tmp_path):
        state = make_state()
        p = str(tmp_path / "c.scda")
        save(p, state, step=123)
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype)
            if isinstance(x, (jax.Array, np.ndarray)) else x, state)
        out, step = restore(p, like)
        assert step == 123
        assert_tree_equal(out, state)

    def test_compressed(self, tmp_path):
        state = make_state()
        p = str(tmp_path / "c.scda")
        save(p, state, step=5, compressed=True, chunk_bytes=256)
        out, step = restore(p, state)
        assert step == 5
        assert_tree_equal(out, state)

    def test_restore_without_like(self, tmp_path):
        state = {"a": jnp.arange(10, dtype=jnp.int32),
                 "nested": {"b": jnp.ones((3, 3))}}
        p = str(tmp_path / "c.scda")
        save(p, state, step=1)
        out, _ = restore(p)
        np.testing.assert_array_equal(out["a"], np.arange(10))
        np.testing.assert_array_equal(out["nested"]["b"], np.ones((3, 3)))

    def test_manifest_probe(self, tmp_path):
        state = make_state()
        p = str(tmp_path / "c.scda")
        save(p, state, step=42)
        doc = read_manifest(p)
        assert doc["step"] == 42
        names = {l["name"] for l in doc["leaves"]}
        assert "params/embed" in names
        assert doc["aux"]["step"] == 123

    def test_bytes_deterministic(self, tmp_path):
        """Same logical state → identical checkpoint bytes (archival)."""
        state = make_state()
        p1, p2 = str(tmp_path / "a.scda"), str(tmp_path / "b.scda")
        save(p1, state, step=9)
        save(p2, jax.tree_util.tree_map(lambda x: x, state), step=9)
        assert open(p1, "rb").read() == open(p2, "rb").read()

    def test_file_is_valid_scda(self, tmp_path):
        """The checkpoint must be an ordinary scda file, inspectable by any
        conforming reader with no knowledge of the checkpoint layer."""
        p = str(tmp_path / "c.scda")
        save(p, make_state(), step=3)
        headers = scan_sections(p)
        assert headers[0].type == "I"
        assert headers[1].type == "B"
        assert all(h.type == "A" for h in headers[2:])

    def test_compressed_file_sections(self, tmp_path):
        p = str(tmp_path / "c.scda")
        save(p, make_state(), step=3, compressed=True, chunk_bytes=128)
        decoded = scan_sections(p, decode=True)
        assert all(h.type == "V" and h.decoded for h in decoded[2:])

    def test_shape_mismatch_rejected(self, tmp_path):
        p = str(tmp_path / "c.scda")
        save(p, {"w": jnp.zeros((4, 4))})
        with pytest.raises(ScdaError):
            restore(p, {"w": jax.ShapeDtypeStruct((4, 5), jnp.float32)})

    def test_missing_leaf_rejected(self, tmp_path):
        p = str(tmp_path / "c.scda")
        save(p, {"w": jnp.zeros((4, 4))})
        with pytest.raises(ScdaError) as e:
            restore(p, {"w": jnp.zeros((4, 4)), "extra": jnp.zeros(3)})
        assert "extra" in str(e.value)

    def test_subset_restore_skips_unwanted(self, tmp_path):
        """Selective restore: only requested leaves are materialized."""
        p = str(tmp_path / "c.scda")
        state = make_state()
        save(p, state, step=1)
        like = {"params": {"embed": jax.ShapeDtypeStruct(
            (32, 16), jnp.float32)}}
        out, _ = restore(p, like)
        np.testing.assert_array_equal(out["params"]["embed"],
                                      np.asarray(state["params"]["embed"]))


class TestLeafNames:
    def test_dict_and_list_paths(self):
        from repro.checkpoint import flatten_named
        named, _ = flatten_named({"a": [jnp.zeros(1), {"b": 2}]})
        assert [n for n, _ in named] == ["a/0", "a/1/b"]


# ------------------------------------------------------------------ manager --
class TestCheckpointManager:
    def test_save_restore_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=2)
        state = make_state()
        mgr.save(10, state, blocking=True)
        mgr.save(20, state, blocking=True)
        assert mgr.latest_step() == 20
        out, step = mgr.restore_latest(state)
        assert step == 20
        assert_tree_equal(out, state)

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path / "ckpts"))
        state = make_state()
        mgr.save(1, state)     # async
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=2)
        state = {"x": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            mgr.save(s, state, blocking=True)
        assert mgr.all_steps() == [3, 4]

    def test_crash_before_commit_leaves_no_partial(self, tmp_path):
        d = str(tmp_path / "ckpts")
        mgr = CheckpointManager(d, keep=3)
        state = {"x": jnp.arange(100, dtype=jnp.float32)}
        mgr.save(1, state, blocking=True)
        mgr._crash_before_commit = True
        with pytest.raises(RuntimeError):
            mgr.save(2, state, blocking=True)
        # step 2 must not be visible; step 1 must still restore
        assert mgr.all_steps() == [1]
        out, step = mgr.restore_latest(state)
        assert step == 1

    def test_corrupt_latest_falls_back(self, tmp_path):
        d = str(tmp_path / "ckpts")
        mgr = CheckpointManager(d, keep=3)
        state = {"x": jnp.arange(10, dtype=jnp.float32)}
        mgr.save(1, state, blocking=True)
        mgr.save(2, state, blocking=True)
        # corrupt the newest file
        with open(mgr.path_for(2), "r+b") as fh:
            fh.seek(0)
            fh.write(b"garbage!")
        out, step = mgr.restore_latest(state)
        assert step == 1

    def test_restore_or_init(self, tmp_path):
        d = str(tmp_path / "ckpts")
        mgr = CheckpointManager(d)
        state = {"x": jnp.ones(3)}
        tree, step = mgr.restore_or_init(lambda: state, like=state)
        assert step == -1
        mgr.save(7, state, blocking=True)
        tree, step = mgr.restore_or_init(lambda: state, like=state)
        assert step == 7

    def test_async_error_surfaces_on_next_call(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path / "ckpts"))
        mgr._crash_before_commit = True
        mgr.save(1, {"x": jnp.zeros(2)})  # async; fails in background
        with pytest.raises(RuntimeError):
            mgr.wait()
        # manager stays usable (training never crashed)
        mgr._crash_before_commit = False
        mgr.save(2, {"x": jnp.zeros(2)}, blocking=True)
        assert mgr.all_steps() == [2]
