"""The paper's core claim: file contents are invariant under linear parallel
repartition of the data prior to writing, and indistinguishable from writing
in serial; files can be read under any partition that agrees on N.

These tests run P genuine concurrent ranks (threads against one shared file,
positioned writes — the MPI-IO pattern) and compare bytes across partitions.
"""
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (SerialComm, ThreadComm, encode, fopen_read,
                        fopen_write, partition, run_ranks)


def split(data, counts, E=1):
    """Slice global data into per-rank contiguous pieces."""
    offs = partition.offsets(counts)
    return [data[offs[p] * E:offs[p + 1] * E] for p in range(len(counts))]


def parallel_write(path, P, build):
    """Run the collective write workload ``build(f, rank)`` on P ranks."""
    comms = ThreadComm.group(P)

    def workload(comm):
        with fopen_write(comm, path, b"user", b"vendor") as f:
            build(f, comm.rank)

    run_ranks(comms, workload)


class TestWriteInvariance:
    """Identical bytes for every partition, equal to the serial oracle."""

    def test_array_all_partitions(self, tmp_path):
        N, E = 24, 10
        data = os.urandom(N * E)
        oracle = encode.encode_file(b"vendor", b"user", [
            encode.encode_array(b"arr", data, N, E)])

        for counts in ([24], [12, 12], [24, 0], [0, 24], [1, 2, 3, 18],
                       [5, 5, 5, 5, 4], [0, 0, 24, 0]):
            path = str(tmp_path / f"arr_{len(counts)}_{counts[0]}.scda")
            pieces = split(data, counts, E)
            parallel_write(
                path, len(counts),
                lambda f, r: f.write_array(b"arr", pieces[r], counts, E))
            with open(path, "rb") as fh:
                assert fh.read() == oracle, f"partition {counts} differs"

    def test_varray_all_partitions(self, tmp_path):
        sizes = [3, 0, 47, 1, 12, 0, 200, 5]
        elements = [os.urandom(s) for s in sizes]
        oracle = encode.encode_file(b"vendor", b"user", [
            encode.encode_varray(b"v", elements)])

        for counts in ([8], [4, 4], [1, 1, 1, 1, 1, 1, 1, 1], [0, 8],
                       [3, 0, 5]):
            path = str(tmp_path / f"v_{len(counts)}_{counts[0]}.scda")
            offs = partition.offsets(counts)
            parallel_write(
                path, len(counts),
                lambda f, r: f.write_varray(
                    b"v", elements[offs[r]:offs[r + 1]], counts,
                    sizes[offs[r]:offs[r + 1]]))
            with open(path, "rb") as fh:
                assert fh.read() == oracle, f"partition {counts} differs"

    def test_mixed_file_parallel_equals_serial(self, tmp_path):
        """A realistic multi-section file written on 1 vs 4 ranks."""
        N, E = 40, 8
        arr = os.urandom(N * E)
        blk = os.urandom(500)
        inline = b"step 000041 time 1.5e-3 ok....!!"
        vsizes = [7, 0, 13, 100, 2, 9, 1, 0, 55, 21]
        velems = [os.urandom(s) for s in vsizes]

        def build(counts):
            def _b(f, r):
                voffs = partition.offsets(counts2)
                f.write_inline(b"status", inline if r == 0 else None)
                f.write_block(b"ctx", blk if r == 0 else None, len(blk))
                f.write_array(b"mesh", split(arr, counts, E)[r], counts, E)
                f.write_varray(b"vdat", velems[voffs[r]:voffs[r + 1]],
                               counts2, vsizes[voffs[r]:voffs[r + 1]])
            return _b

        counts2 = None
        files = []
        for counts, c2 in (([40], [10]), ([10, 10, 10, 10], [1, 3, 0, 6]),
                           ([0, 40, 0, 0], [4, 4, 1, 1])):
            counts2 = c2
            path = str(tmp_path / f"mix_{len(counts)}_{counts[0]}.scda")
            parallel_write(path, len(counts), build(counts))
            with open(path, "rb") as fh:
                files.append(fh.read())
        assert files[0] == files[1] == files[2]

    def test_encoded_array_partition_invariant(self, tmp_path):
        """§3 per-element compression must also be partition-independent."""
        N, E = 16, 64
        data = (os.urandom(E // 2) + b"\0" * (E // 2)) * N
        outs = []
        for counts in ([16], [7, 9], [4, 4, 4, 4]):
            path = str(tmp_path / f"enc_{len(counts)}.scda")
            pieces = split(data, counts, E)
            parallel_write(
                path, len(counts),
                lambda f, r: f.write_array(b"z", pieces[r], counts, E,
                                           encode=True))
            with open(path, "rb") as fh:
                outs.append(fh.read())
        assert outs[0] == outs[1] == outs[2]

    @given(st.integers(1, 6), st.binary(min_size=0, max_size=400),
           st.integers(1, 16), st.randoms(use_true_random=False))
    @settings(max_examples=25, deadline=None)
    def test_property_random_partitions(self, P, payload, E, rng):
        """Hypothesis: any (data, E, random partition) → serial-equal bytes."""
        import tempfile
        n_extra = (-len(payload)) % E
        data = payload + b"\0" * n_extra
        N = len(data) // E
        # random composition of N into P parts
        counts = [0] * P
        for _ in range(N):
            counts[rng.randrange(P)] += 1
        oracle = encode.encode_file(b"vendor", b"user", [
            encode.encode_array(b"a", data, N, E)])
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "f.scda")
            pieces = split(data, counts, E)
            parallel_write(
                path, P,
                lambda f, r: f.write_array(b"a", pieces[r], counts, E))
            with open(path, "rb") as fh:
                assert fh.read() == oracle


class TestReadAnyPartition:
    """Write under one partition, read under others (paper §A.5)."""

    def test_array_cross_partition(self, tmp_path):
        N, E = 30, 12
        data = os.urandom(N * E)
        path = str(tmp_path / "a.scda")
        wcounts = [13, 17]
        parallel_write(path, 2,
                       lambda f, r: f.write_array(
                           b"a", split(data, wcounts, E)[r], wcounts, E))

        for rcounts in ([30], [10, 10, 10], [0, 30], [1, 1, 28], [6] * 5):
            comms = ThreadComm.group(len(rcounts))

            def read(comm):
                with fopen_read(comm, path) as r:
                    hdr = r.read_section_header()
                    assert (hdr.N, hdr.E) == (N, E)
                    return b"".join(r.read_array_data(rcounts))

            parts = run_ranks(comms, read)
            assert b"".join(parts) == data

    def test_varray_cross_partition_with_decode(self, tmp_path):
        sizes = [100, 3, 0, 512, 77, 1]
        elements = [os.urandom(s) for s in sizes]
        path = str(tmp_path / "v.scda")
        # write compressed on 3 ranks
        wcounts = [2, 2, 2]
        offs = partition.offsets(wcounts)
        parallel_write(path, 3,
                       lambda f, r: f.write_varray(
                           b"v", elements[offs[r]:offs[r + 1]], wcounts,
                           sizes[offs[r]:offs[r + 1]], encode=True))
        # read decoded on 2 ranks with a different partition
        rcounts = [5, 1]
        roffs = partition.offsets(rcounts)
        comms = ThreadComm.group(2)

        def read(comm):
            with fopen_read(comm, path) as r:
                hdr = r.read_section_header(decode=True)
                assert hdr.type == "V" and hdr.decoded and hdr.N == 6
                ls = r.read_varray_sizes(rcounts)
                assert ls == sizes[roffs[comm.rank]:roffs[comm.rank + 1]]
                return r.read_varray_data(rcounts, ls)

        parts = run_ranks(comms, read)
        assert parts[0] + parts[1] == elements

    def test_serial_write_parallel_read(self, tmp_path):
        """Serial-equivalence in the other direction."""
        N, E = 64, 4
        data = os.urandom(N * E)
        path = str(tmp_path / "s.scda")
        with fopen_write(SerialComm(), path, b"user", b"vendor") as f:
            f.write_array(b"a", data, [N], E)
        comms = ThreadComm.group(4)
        rcounts = [16, 16, 16, 16]

        def read(comm):
            with fopen_read(comm, path) as r:
                r.read_section_header()
                return b"".join(r.read_array_data(rcounts))

        assert b"".join(run_ranks(comms, read)) == data
