"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-gradient step + decode steps on CPU; outputs finite, shapes right.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config, smoke
from repro.models import (forward, forward_hidden, init_cache, init_lm,
                          lm_loss, serve_step)

ARCHS = sorted(REGISTRY)
B, S = 2, 16


def _inputs(cfg, key):
    kw = {}
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        kw["enc_embeds"] = jax.random.normal(
            key, (B, cfg.max_source_len, cfg.d_model), jnp.float32)
    return tokens, kw


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = smoke(get_config(arch))
    params = init_lm(cfg, rng)
    tokens, kw = _inputs(cfg, rng)
    logits = jax.jit(lambda p, t: forward(cfg, p, t, **kw))(params, tokens)
    S_out = S + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_gradient_step(arch, rng):
    cfg = smoke(get_config(arch))
    params = init_lm(cfg, rng)
    tokens, kw = _inputs(cfg, rng)
    labels = jnp.roll(tokens, -1, axis=1)

    loss_fn = lambda p: lm_loss(cfg, p, tokens, labels, loss_chunk=8, **kw)
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # a full-vocab uniform guess has loss ~ log(vocab); sanity-band it
    assert 0.0 < float(loss) < 3 * np.log(cfg.vocab)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    # gradients actually flow to the embedding and to deep layers
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))
    assert float(gn) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_steps(arch, rng):
    cfg = smoke(get_config(arch))
    params = init_lm(cfg, rng)
    cache = init_cache(cfg, B, max_len=32)
    if cfg.family == "encdec":
        enc = jax.random.normal(rng, (B, cfg.max_source_len, cfg.d_model))
        cache["enc_out"] = enc.astype(cache["enc_out"].dtype)
    step = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t))
    tok = jnp.zeros((B, 1), jnp.int32)
    for i in range(3):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        assert int(cache["pos"]) == i + 1
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


def test_decode_matches_prefill_dense(rng):
    """Step-by-step decode must agree with the parallel forward pass."""
    cfg = smoke(get_config("qwen3-1.7b"))
    params = init_lm(cfg, rng)
    tokens = jax.random.randint(rng, (B, 8), 0, cfg.vocab)
    ref = forward(cfg, params, tokens)           # (B, 8, V)
    cache = init_cache(cfg, B, max_len=8)
    outs = []
    step = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t))
    for i in range(8):
        logits, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_ssm(rng):
    cfg = smoke(get_config("falcon-mamba-7b"))
    params = init_lm(cfg, rng)
    tokens = jax.random.randint(rng, (B, 8), 0, cfg.vocab)
    ref = forward(cfg, params, tokens)
    cache = init_cache(cfg, B, max_len=8)
    step = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t))
    outs = []
    for i in range(8):
        logits, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_hybrid(rng):
    cfg = smoke(get_config("zamba2-2.7b"))
    params = init_lm(cfg, rng)
    tokens = jax.random.randint(rng, (B, 8), 0, cfg.vocab)
    ref = forward(cfg, params, tokens)
    cache = init_cache(cfg, B, max_len=8)
    step = jax.jit(lambda p, c, t: serve_step(cfg, p, c, t))
    outs = []
    for i in range(8):
        logits, cache = step(params, cache, tokens[:, i:i + 1])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_local_global_pattern():
    cfg = get_config("gemma3-4b")
    flags = [cfg.layer_is_global(i) for i in range(12)]
    # 5 local then 1 global, repeating
    assert flags == [False] * 5 + [True] + [False] * 5 + [True]


def test_param_counts_plausible():
    """Config-derived N within ~35% of the published sizes."""
    expect = {
        "zamba2-2.7b": 2.7e9, "gemma3-4b": 4e9, "yi-6b": 6e9,
        "nemotron-4-15b": 15e9, "qwen3-1.7b": 1.7e9,
        "falcon-mamba-7b": 7e9, "llava-next-mistral-7b": 7e9,
        "granite-moe-3b-a800m": 3e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 1.6 * n, f"{arch}: {got / 1e9:.2f}B vs {n / 1e9}B"
    # MoE active-param count ~17B total/16e: scout ~109B total, ~17B active
    scout = get_config("llama4-scout-17b-a16e")
    assert 60e9 < scout.param_count() < 140e9
    assert 8e9 < scout.active_param_count() < 25e9
