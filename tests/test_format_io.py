"""Writer/reader behaviour: round-trips, serial oracle equality, decode
semantics (Table 2), sequencing errors, selective access."""
import os

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ScdaError, ScdaErrorCode, SerialComm, codec, encode,
                        fopen_read, fopen_write, partition, scan_sections,
                        spec)


@pytest.fixture
def path(tmp_path):
    return str(tmp_path / "test.scda")


def serial_write(path, sections, user=b"u", vendor=b"vendor"):
    """Write a file through the parallel writer with one rank."""
    with fopen_write(SerialComm(), path, user, vendor) as f:
        for kind, args in sections:
            getattr(f, f"write_{kind}")(*args)


class TestSerialEquivalenceToOracle:
    """The parallel writer (P=1) must equal the in-memory oracle encoder."""

    def test_header_only(self, path):
        serial_write(path, [])
        with open(path, "rb") as fh:
            assert fh.read() == encode.encode_file(b"vendor", b"u", [])

    def test_all_section_types(self, path):
        inline = b"0123456789abcdef0123456789abcdef"
        block = b"global simulation context\n"
        arr = bytes(range(160))          # N=10, E=16
        elements = [b"a", b"bb" * 30, b"", b"ccc"]
        serial_write(path, [
            ("inline", (b"i", inline)),
            ("block", (b"b", block)),
            ("array", (b"a", arr, [10], 16)),
            ("varray", (b"v", elements, [4], [len(e) for e in elements])),
        ])
        expect = encode.encode_file(b"vendor", b"u", [
            encode.encode_inline(b"i", inline),
            encode.encode_block(b"b", block),
            encode.encode_array(b"a", arr, 10, 16),
            encode.encode_varray(b"v", elements),
        ])
        with open(path, "rb") as fh:
            assert fh.read() == expect

    def test_mime_style(self, path):
        with fopen_write(SerialComm(), path, b"u", b"v",
                         style=spec.MIME) as f:
            f.write_block(b"b", b"data")
        expect = (spec.file_header(b"v", b"u", spec.MIME)
                  + encode.encode_block(b"b", b"data", spec.MIME))
        with open(path, "rb") as fh:
            assert fh.read() == expect

    def test_ascii_payload_keeps_file_ascii(self, path):
        """§1: pure ASCII data → the entire file stays ASCII."""
        serial_write(path, [
            ("inline", (b"note", b"x = 42; y = 3.14159; z = ok!\n###")),
            ("block", (b"cfg", b"alpha = 1\nbeta = 2\n")),
            ("array", (b"tbl", b"0123" * 8, [8], 4)),
        ])
        with open(path, "rb") as fh:
            content = fh.read()
        assert all(b < 128 for b in content)

    def test_encoded_binary_file_stays_ascii_after_headers(self, path):
        """§3: compressed+base64 payloads keep sections ASCII."""
        binary = bytes(range(256)) * 4
        serial_write(path, [("block", (b"blob", binary, None, 0, True))])
        with open(path, "rb") as fh:
            content = fh.read()
        assert all(b < 128 for b in content)


class TestRoundTrip:
    def test_inline(self, path):
        data = b"#" * 32
        serial_write(path, [("inline", (b"i", data))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header()
            assert (hdr.type, hdr.N, hdr.E) == ("I", 0, 0)
            assert r.read_inline_data() == data
            assert r.at_eof

    def test_block(self, path):
        data = os.urandom(1000)
        serial_write(path, [("block", (b"blk", data))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header()
            assert hdr.type == "B" and hdr.E == 1000
            assert r.read_block_data() == data

    def test_empty_block(self, path):
        serial_write(path, [("block", (b"empty", b""))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header()
            assert hdr.E == 0
            assert r.read_block_data() == b""

    def test_array(self, path):
        data = os.urandom(7 * 24)
        serial_write(path, [("array", (b"arr", data, [7], 24))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header()
            assert (hdr.type, hdr.N, hdr.E) == ("A", 7, 24)
            elems = r.read_array_data([7])
            assert b"".join(elems) == data

    def test_varray(self, path):
        elements = [os.urandom(n) for n in (5, 0, 300, 1, 77)]
        serial_write(path, [("varray", (b"v", elements, [5],
                                        [len(e) for e in elements]))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header()
            assert hdr.type == "V" and hdr.N == 5
            sizes = r.read_varray_sizes([5])
            assert sizes == [5, 0, 300, 1, 77]
            out = r.read_varray_data([5], sizes)
            assert out == elements

    def test_multi_section_file_and_scan(self, path):
        serial_write(path, [
            ("inline", (b"one", b"1" * 32)),
            ("array", (b"two", b"xy" * 10, [10], 2)),
            ("block", (b"three", b"z")),
        ])
        headers = scan_sections(path)
        assert [h.type for h in headers] == ["I", "A", "B"]
        assert [h.user_string for h in headers] == [b"one", b"two", b"three"]

    def test_zero_element_array(self, path):
        serial_write(path, [("array", (b"none", b"", [0], 8))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header()
            assert hdr.N == 0
            assert r.read_array_data([0]) == []


class TestCompressionConvention:
    def test_block_encoded_roundtrip(self, path):
        data = b"compressible " * 500
        serial_write(path, [("block", (b"blk", data, None, 0, True))])
        # decode=True → transparent
        with fopen_read(None, path) as r:
            hdr = r.read_section_header(decode=True)
            assert hdr.type == "B" and hdr.decoded and hdr.E == len(data)
            assert hdr.user_string == b"blk"
            assert r.read_block_data() == data
        # decode=False → the two raw sections (Table 2)
        with fopen_read(None, path) as r:
            h1 = r.read_section_header(decode=False)
            assert h1.type == "I" and h1.user_string == codec.MAGIC_BLOCK
            u = codec.parse_uncompressed_size_entry(r.read_inline_data())
            assert u == len(data)
            h2 = r.read_section_header(decode=False)
            assert h2.type == "B" and h2.user_string == b"blk"
            compressed = r.read_block_data()
            assert codec.decompress(compressed) == data

    def test_array_encoded_roundtrip(self, path):
        E, N = 48, 12
        data = bytes((i * 13) % 251 for i in range(N * E))
        serial_write(path, [("array", (b"arr", data, [N], E, False, True))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header(decode=True)
            assert hdr.type == "A" and hdr.decoded
            assert hdr.N == N and hdr.E == E
            elems = r.read_array_data([N])
            assert b"".join(elems) == data

    def test_varray_encoded_roundtrip(self, path):
        elements = [b"q" * n for n in (100, 0, 3, 1000, 8)]
        serial_write(path, [("varray", (b"v", elements, [5],
                                        [len(e) for e in elements],
                                        None, False, True))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header(decode=True)
            assert hdr.type == "V" and hdr.decoded and hdr.N == 5
            sizes = r.read_varray_sizes([5])
            assert sizes == [100, 0, 3, 1000, 8]
            out = r.read_varray_data([5], sizes)
            assert out == elements

    def test_decode_true_on_uncompressed_reads_raw(self, path):
        """Table 2: input true + non-compression header → output false."""
        serial_write(path, [("block", (b"plain", b"payload"))])
        with fopen_read(None, path) as r:
            hdr = r.read_section_header(decode=True)
            assert hdr.type == "B" and not hdr.decoded
            assert r.read_block_data() == b"payload"

    def test_encoded_sections_skippable(self, path):
        serial_write(path, [
            ("block", (b"b1", b"x" * 100, None, 0, True)),
            ("array", (b"a1", b"y" * 64, [8], 8, False, True)),
            ("inline", (b"after", b"?" * 32)),
        ])
        with fopen_read(None, path) as r:
            assert r.read_section_header().decoded
            r.skip_data()
            assert r.read_section_header().decoded
            r.skip_data()
            hdr = r.read_section_header()
            assert hdr.type == "I" and hdr.user_string == b"after"


class TestSelectiveAccess:
    def test_windowed_reads(self, path):
        """§1: selective random data access on array sections."""
        N, E = 100, 16
        data = b"".join(bytes([i] * E) for i in range(N))
        serial_write(path, [("array", (b"arr", data, [N], E))])
        with fopen_read(None, path) as r:
            r.read_section_header()
            w = r.read_array_windows([(10, 2), (99, 1), (0, 1)], E)
            assert w[0] == bytes([10] * E) + bytes([11] * E)
            assert w[1] == bytes([99] * E)
            assert w[2] == bytes([0] * E)
            r.skip_data()
            assert r.at_eof


class TestErrorsAndSequencing:
    def test_reading_missing_file(self, tmp_path):
        with pytest.raises(ScdaError) as e:
            fopen_read(None, str(tmp_path / "nope.scda"))
        assert e.value.code == ScdaErrorCode.FS_OPEN
        assert e.value.group == 2

    def test_not_an_scda_file(self, path):
        with open(path, "wb") as fh:
            fh.write(b"PK\x03\x04" + b"\0" * 124)
        with pytest.raises(ScdaError) as e:
            fopen_read(None, path)
        assert e.value.group == 1

    def test_truncated_header(self, path):
        with open(path, "wb") as fh:
            fh.write(b"scdata0 short")
        with pytest.raises(ScdaError) as e:
            fopen_read(None, path)
        assert e.value.code == ScdaErrorCode.CORRUPT_TRUNCATED

    def test_data_read_before_header(self, path):
        serial_write(path, [("block", (b"b", b"d"))])
        with fopen_read(None, path) as r:
            with pytest.raises(ScdaError) as e:
                r.read_block_data()
            assert e.value.code == ScdaErrorCode.ARG_SEQUENCE

    def test_varray_data_before_sizes(self, path):
        serial_write(path, [("varray", (b"v", [b"ab"], [1], [2]))])
        with fopen_read(None, path) as r:
            r.read_section_header()
            with pytest.raises(ScdaError) as e:
                r.read_varray_data([1], [2])
            assert e.value.code == ScdaErrorCode.ARG_SEQUENCE

    def test_wrong_partition_sum_rejected(self, path):
        serial_write(path, [("array", (b"a", b"x" * 10, [10], 1))])
        with fopen_read(None, path) as r:
            r.read_section_header()
            with pytest.raises(ScdaError) as e:
                r.read_array_data([9])
            assert e.value.code == ScdaErrorCode.ARG_PARTITION

    def test_inline_wrong_size_rejected(self, path):
        with fopen_write(None, path) as f:
            with pytest.raises(ScdaError) as e:
                f.write_inline(b"i", b"only 20 bytes.......")
            assert e.value.code == ScdaErrorCode.ARG_INLINE_SIZE

    def test_overlong_user_string_rejected(self, path):
        with fopen_write(None, path) as f:
            with pytest.raises(ScdaError) as e:
                f.write_block(b"u" * 59, b"d")
            assert e.value.code == ScdaErrorCode.ARG_USER_STRING

    def test_write_after_close(self, path):
        f = fopen_write(None, path)
        f.close()
        with pytest.raises(ScdaError) as e:
            f.write_block(b"b", b"d")
        assert e.value.code == ScdaErrorCode.ARG_SEQUENCE

    def test_truncated_section_detected(self, path):
        serial_write(path, [("block", (b"b", b"x" * 100))])
        size = os.path.getsize(path)
        with open(path, "r+b") as fh:
            fh.truncate(size - 40)
        with fopen_read(None, path) as r:
            r.read_section_header()
            with pytest.raises(ScdaError) as e:
                r.read_block_data()
            assert e.value.group == 1


class TestPropertyRoundTrips:
    @given(st.binary(max_size=2000), st.booleans())
    @settings(max_examples=30, deadline=None)
    def test_block_any_bytes(self, data, enc):
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "f.scda")
            serial_write(p, [("block", (b"b", data, None, 0, enc))])
            with fopen_read(None, p) as r:
                hdr = r.read_section_header()
                assert hdr.E == len(data)
                assert r.read_block_data() == data

    @given(st.lists(st.binary(max_size=200), max_size=12), st.booleans())
    @settings(max_examples=30, deadline=None)
    def test_varray_any_elements(self, elements, enc):
        import tempfile
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "f.scda")
            serial_write(p, [("varray", (b"v", elements, [len(elements)],
                                         [len(e) for e in elements],
                                         None, False, enc))])
            with fopen_read(None, p) as r:
                hdr = r.read_section_header()
                assert hdr.N == len(elements)
                sizes = r.read_varray_sizes([hdr.N])
                assert sizes == [len(e) for e in elements]
                assert r.read_varray_data([hdr.N], sizes) == elements
