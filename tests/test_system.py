"""End-to-end system tests: the training loop with checkpoint/restart,
failure injection, gradient compression, and the data pipeline contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed.grad_compress import (compress_grads,
                                             compress_with_feedback,
                                             init_error_feedback)
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train


@pytest.fixture
def tiny_cfg():
    return smoke(get_config("qwen3-1.7b"))


class TestDataPipeline:
    def test_partition_independent_rows(self):
        """Any host slicing must see identical global rows (elastic data)."""
        d = SyntheticTokens(DataConfig(vocab=128, seq_len=16, global_batch=8))
        whole = d.global_batch_shard(3, 0, 8)
        parts = [d.global_batch_shard(3, i, 2) for i in (0, 2, 4, 6)]
        np.testing.assert_array_equal(
            whole["tokens"], np.concatenate([p["tokens"] for p in parts]))

    def test_deterministic_across_restarts(self):
        d1 = SyntheticTokens(DataConfig(vocab=128, seq_len=16, global_batch=4))
        d2 = SyntheticTokens(DataConfig(vocab=128, seq_len=16, global_batch=4))
        np.testing.assert_array_equal(
            d1.global_batch_shard(7, 0, 4)["tokens"],
            d2.global_batch_shard(7, 0, 4)["tokens"])

    def test_labels_shifted(self):
        d = SyntheticTokens(DataConfig(vocab=128, seq_len=8, global_batch=2))
        b = d.global_batch_shard(0, 0, 2)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestOptimizer:
    def test_adamw_step_descends(self):
        params = {"w": jnp.array([1.0, -2.0, 3.0])}
        st = adamw.init(params)
        cfg = AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0,
                          clip_norm=0.0)
        grads = {"w": jnp.array([1.0, -1.0, 1.0])}
        new, st, stats = adamw.update(cfg, grads, st, params)
        assert float(new["w"][0]) < 1.0
        assert float(new["w"][1]) > -2.0
        assert int(st.count) == 1

    def test_clipping(self):
        params = {"w": jnp.zeros(3)}
        st = adamw.init(params)
        cfg = AdamWConfig(clip_norm=1.0)
        grads = {"w": jnp.ones(3) * 1e6}
        _, _, stats = adamw.update(cfg, grads, st, params)
        assert float(stats["grad_norm"]) > 1e5  # reported pre-clip

    def test_schedule_warmup_and_decay(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.array(s)))
               for s in (0, 9, 50, 99)]
        assert lrs[0] < lrs[1] <= 1.0
        assert lrs[2] < lrs[1]
        assert abs(lrs[3] - 0.1) < 0.02


class TestGradCompression:
    def test_stateless_roundtrip_close(self):
        g = {"w": jnp.linspace(-1, 1, 64)}
        out = compress_grads(g)
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                                   rtol=1e-2, atol=1e-2)

    def test_error_feedback_is_unbiased_over_steps(self):
        """Accumulated EF-compressed grads ≈ accumulated true grads."""
        g = {"w": jnp.full((32,), 1e-3 + 1e-5)}  # below bf16 resolution step
        ef = init_error_feedback(g)
        total = jnp.zeros((32,))
        for _ in range(100):
            sent, ef = compress_with_feedback(g, ef)
            total = total + sent["w"]
        np.testing.assert_allclose(np.asarray(total),
                                   np.asarray(g["w"] * 100), rtol=1e-3)


class TestTrainLoop:
    def _loop(self, tmp_path, steps, **kw):
        return TrainLoopConfig(total_steps=steps, ckpt_every=4,
                               ckpt_dir=str(tmp_path / "ckpts"),
                               log_every=100, **kw)

    def test_loss_decreases(self, tiny_cfg, tmp_path):
        out = train(tiny_cfg, self._loop(tmp_path, 12),
                    AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=12),
                    seq_len=32, global_batch=4)
        assert out["losses"][-1] < out["losses"][0]

    def test_restart_resumes_and_matches(self, tiny_cfg, tmp_path):
        """Die at step 6, restart, finish; state must continue (not reset)."""
        loop = self._loop(tmp_path, 12)
        with pytest.raises(SystemExit):
            train(tiny_cfg, loop, AdamWConfig(total_steps=12),
                  seq_len=32, global_batch=4,
                  hooks={"should_die": lambda s: s == 6})
        out = train(tiny_cfg, loop, AdamWConfig(total_steps=12),
                    seq_len=32, global_batch=4)
        assert out["start_step"] >= 4          # resumed from a checkpoint
        # uninterrupted reference run
        ref = train(tiny_cfg, self._loop(tmp_path / "ref", 12),
                    AdamWConfig(total_steps=12), seq_len=32, global_batch=4)
        # identical data + restored state ⇒ final losses agree closely
        assert abs(out["losses"][-1] - ref["losses"][-1]) < 0.05

    def test_grad_compress_trains(self, tiny_cfg, tmp_path):
        out = train(tiny_cfg, self._loop(tmp_path, 8, grad_compress=True),
                    AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=8),
                    seq_len=32, global_batch=4)
        assert np.isfinite(out["losses"]).all()
        assert out["losses"][-1] < out["losses"][0] + 0.1

    def test_compressed_checkpoints(self, tiny_cfg, tmp_path):
        loop = self._loop(tmp_path, 6, ckpt_compressed=True)
        out = train(tiny_cfg, loop, AdamWConfig(total_steps=6),
                    seq_len=32, global_batch=4)
        assert out["manager"].all_steps()
        out2 = train(tiny_cfg, loop, AdamWConfig(total_steps=6),
                     seq_len=32, global_batch=4)
        assert out2["start_step"] == 5
