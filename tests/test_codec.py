"""§3 compression convention tests: stage-1/stage-2 algorithm + checks."""
import base64
import struct
import zlib

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import codec, spec
from repro.core.errors import ScdaError, ScdaErrorCode


class TestStage1Stage2:
    def test_structure(self):
        data = b"hello world" * 10
        stream = codec.compress(data)
        # every line is ≤76 code bytes + 2 break bytes
        i = 0
        while i < len(stream):
            chunk = stream[i:i + 78]
            assert len(chunk) >= 3
            i += len(chunk)
        # stage 1: 8-byte BE size + 'z' + zlib stream
        code = b"".join(stream[j:j + 78][:-2]
                        for j in range(0, len(stream), 78))
        stage1 = base64.b64decode(code, validate=True)
        assert struct.unpack(">Q", stage1[:8])[0] == len(data)
        assert stage1[8:9] == b"z"
        assert zlib.decompress(stage1[9:]) == data

    def test_unix_break_bytes(self):
        import os
        stream = codec.compress(os.urandom(300), spec.UNIX)
        assert len(stream) > 78 and stream[76:78] == b"=\n"

    def test_mime_break_bytes(self):
        import os
        stream = codec.compress(os.urandom(300), spec.MIME)
        assert len(stream) > 78 and stream[76:78] == b"\r\n"

    def test_ascii_output(self):
        # §1: compressed data re-encoded to ASCII keeps the file ASCII
        stream = codec.compress(bytes(range(256)))
        assert all(b < 128 for b in stream)

    def test_level_zero_legal(self):
        data = b"some incompressible-ish data 123"
        assert codec.decompress(codec.compress(data, level=0)) == data

    @given(st.binary(max_size=5000),
           st.sampled_from([spec.UNIX, spec.MIME]),
           st.sampled_from([0, 1, 9]))
    @settings(max_examples=60, deadline=None)
    def test_roundtrip(self, data, style, level):
        assert codec.decompress(codec.compress(data, style, level)) == data

    def test_exact_76_multiple_gets_single_break(self):
        # find data whose encoding is an exact multiple of 76 → stream ends
        # with exactly one break after the full final line
        for n in range(200):
            data = bytes((i * 7) % 256 for i in range(n))
            stream = codec.compress(data)
            stage1_len = len(base64.b64encode(
                struct.pack(">Q", n) + b"z" + zlib.compress(data, 9)))
            if stage1_len % 76 == 0:
                assert len(stream) == stage1_len + (stage1_len // 76) * 2
                assert codec.decompress(stream) == data
                return
        pytest.skip("no exact-multiple case found in sweep")


class TestChecks:
    """The three redundant checks of §3.1 must all be enforced."""

    def test_size_mismatch_detected(self):
        data = b"payload bytes"
        stage1 = struct.pack(">Q", len(data) + 1) + b"z" + zlib.compress(data)
        stream = codec.compress(b"")  # get valid framing, then rebuild
        enc = base64.b64encode(stage1)
        lines = [enc[i:i + 76] + b"=\n" for i in range(0, len(enc), 76)]
        with pytest.raises(ScdaError) as e:
            codec.decompress(b"".join(lines))
        assert e.value.code == ScdaErrorCode.CORRUPT_CHECKSUM

    def test_missing_z_marker(self):
        stage1 = struct.pack(">Q", 3) + b"q" + zlib.compress(b"abc")
        enc = base64.b64encode(stage1)
        lines = [enc[i:i + 76] + b"=\n" for i in range(0, len(enc), 76)]
        with pytest.raises(ScdaError) as e:
            codec.decompress(b"".join(lines))
        assert e.value.code == ScdaErrorCode.CORRUPT_ENCODING

    def test_adler32_corruption_detected(self):
        import os
        stream = bytearray(codec.compress(os.urandom(500)))
        # flip a code byte mid-stream (avoid break bytes at 76..77)
        stream[40] = (stream[40] + 1) % 128 or 65
        with pytest.raises(ScdaError):
            codec.decompress(bytes(stream))

    def test_truncated_stream(self):
        with pytest.raises(ScdaError) as e:
            codec.decompress(b"")
        assert e.value.code == ScdaErrorCode.CORRUPT_ENCODING

    def test_bad_base64(self):
        with pytest.raises(ScdaError) as e:
            codec.decompress(b"!!!!=\n")
        assert e.value.code == ScdaErrorCode.CORRUPT_ENCODING
