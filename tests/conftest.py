"""Shared test configuration.

Provides a fallback stub for ``hypothesis`` so the suite collects and runs
even when the dependency is absent: property tests (``@given``) skip
cleanly, every example-based test in the same modules still executes.
Install the real package (see requirements-dev.txt) to run the property
tests.

Also provides the ``fault_injection`` fixture: a factory installing a
process-wide deterministic fault plan (``repro.core.faults``) that is
always uninstalled on test exit, so no fault can leak into the next test.
"""
import sys
import types

import pytest

try:  # pragma: no cover - trivial when hypothesis is installed
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        """Inert placeholder: absorbs chaining (.map/.filter/|/...)."""

        def __call__(self, *args, **kwargs):
            return self

        def __or__(self, other):
            return self

        def __getattr__(self, name):
            return self

    def _given(*args, **kwargs):
        def deco(fn):
            def wrapper(*a, **k):
                pytest.skip("hypothesis is not installed")
            wrapper.__name__ = getattr(fn, "__name__", "test")
            wrapper.__doc__ = getattr(fn, "__doc__", None)
            return wrapper
        return deco

    def _settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def fault_injection():
    """Factory: ``inject(spec)`` installs a deterministic fault plan and
    returns its injector (``.injected`` lists every fault fired).  The
    plan is uninstalled automatically, even when the test raises."""
    from repro.core import faults

    def inject(spec: str):
        return faults.install(faults.FaultPlan.parse(spec))

    yield inject
    faults.uninstall()
