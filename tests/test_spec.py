"""Byte-exact conformance tests for the scda format primitives (paper §2)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spec
from repro.core.errors import ScdaError, ScdaErrorCode


# ---------------------------------------------------------------- padding --
class TestFixedPadding:
    def test_unix_layout(self):
        # d=24, n=5 → p=19: ' ' + 16×'-' + "-\n"
        out = spec.pad_fixed(b"hello", 24)
        assert out == b"hello" + b" " + b"-" * 16 + b"-\n"
        assert len(out) == 24

    def test_mime_layout(self):
        out = spec.pad_fixed(b"hello", 24, spec.MIME)
        assert out == b"hello" + b" " + b"-" * 16 + b"\r\n"

    def test_minimum_padding_is_four(self):
        # n = d-4 → p = 4: ' ' + 1×'-' + 2 terminal bytes
        out = spec.pad_fixed(b"x" * 20, 24)
        assert out == b"x" * 20 + b" -" + b"-\n"

    def test_empty_input(self):
        out = spec.pad_fixed(b"", 8)
        assert out == b" " + b"-" * 5 + b"-\n"

    def test_overlong_rejected(self):
        with pytest.raises(ScdaError) as e:
            spec.pad_fixed(b"x" * 21, 24)
        assert e.value.code == ScdaErrorCode.ARG_USER_STRING

    @given(st.binary(max_size=58), st.sampled_from([spec.UNIX, spec.MIME]))
    def test_roundtrip(self, data, style):
        assert spec.unpad_fixed(spec.pad_fixed(data, 62, style), 62) == data

    def test_unpad_rejects_bad_terminal(self):
        with pytest.raises(ScdaError) as e:
            spec.unpad_fixed(b"ab" + b" " + b"-" * 3 + b"xy", 8)
        assert e.value.code == ScdaErrorCode.CORRUPT_PADDING

    def test_unpad_rejects_missing_space(self):
        with pytest.raises(ScdaError):
            spec.unpad_fixed(b"abc" + b"-" * 3 + b"-\n", 8)


class TestDataPadding:
    @pytest.mark.parametrize("n,expect_p", [
        (0, 32), (1, 31), (25, 7), (26, 38), (31, 33), (32, 32), (33, 31),
        (57, 7), (58, 38), (64, 32),
    ])
    def test_length_rule(self, n, expect_p):
        # p is the unique integer in [7, 38] with (n+p) % 32 == 0 (§2.1.2)
        p = spec.data_pad_length(n)
        assert p == expect_p
        assert 7 <= p <= 38 and (n + p) % 32 == 0

    def test_unix_not_ending_in_newline(self):
        pad = spec.pad_data(1, ord("x"))
        assert pad.startswith(b"\n=") and pad.endswith(b"\n\n")
        assert len(pad) == 31

    def test_unix_ending_in_newline(self):
        pad = spec.pad_data(1, 0x0A)
        assert pad.startswith(b"==") and pad.endswith(b"\n\n")

    def test_mime_variants(self):
        assert spec.pad_data(1, ord("x"), spec.MIME).startswith(b"\r\n")
        assert spec.pad_data(1, 0x0A, spec.MIME).startswith(b"==")
        assert spec.pad_data(1, ord("x"), spec.MIME).endswith(b"\r\n\r\n")

    def test_zero_bytes(self):
        pad = spec.pad_data(0, None)
        assert len(pad) == 32 and pad.startswith(b"\n=")

    @given(st.integers(0, 10_000), st.one_of(st.none(), st.integers(0, 255)),
           st.sampled_from([spec.UNIX, spec.MIME]))
    def test_always_correct_length_and_blank_line(self, n, last, style):
        if n == 0:
            last = None
        elif last is None:
            last = 0
        pad = spec.pad_data(n, last, style)
        assert len(pad) == spec.data_pad_length(n)
        # §2.1: padding concludes with a blank line
        assert pad.endswith(b"\n\n") or pad.endswith(b"\r\n\r\n")


# ----------------------------------------------------------------- counts --
class TestCountEntries:
    def test_entry_is_32_bytes(self):
        e = spec.count_entry(b"E", 12345)
        assert len(e) == 32 and e.startswith(b"E 12345 ")

    def test_roundtrip_extremes(self):
        for v in (0, 1, 10**26 - 1):
            assert spec.parse_count_entry(spec.count_entry(b"N", v), b"N") == v

    def test_rejects_negative_and_overflow(self):
        for v in (-1, 10**26):
            with pytest.raises(ScdaError) as e:
                spec.count_entry(b"E", v)
            assert e.value.code == ScdaErrorCode.ARG_COUNT_RANGE

    def test_rejects_leading_zeros(self):
        bad = b"E " + spec.pad_fixed(b"007", 30)
        with pytest.raises(ScdaError) as e:
            spec.parse_count_entry(bad, b"E")
        assert e.value.code == ScdaErrorCode.CORRUPT_COUNT

    def test_rejects_wrong_letter(self):
        with pytest.raises(ScdaError):
            spec.parse_count_entry(spec.count_entry(b"E", 5), b"N")

    @given(st.integers(0, 10**26 - 1))
    def test_roundtrip(self, v):
        assert spec.parse_count_entry(spec.count_entry(b"E", v), b"E") == v


# ------------------------------------------------------------ file header --
class TestFileHeader:
    def test_magic_is_scdata0(self):
        assert spec.MAGIC == b"scdata0"

    def test_golden_128_bytes(self):
        hdr = spec.file_header(b"libsc 2.8.5", b"hello scda")
        assert len(hdr) == 128
        assert hdr[:7] == b"scdata0"
        assert hdr[7:8] == b" "
        # vendor field: 'libsc 2.8.5' (11) + ' ' + 10×'-' + "-\n" (total 24)
        assert hdr[8:32] == b"libsc 2.8.5 " + b"-" * 10 + b"-\n"
        assert hdr[32:34] == b"F "
        assert hdr[96:128] == spec.pad_data(0, None)

    def test_roundtrip(self):
        hdr = spec.file_header(b"vendor", b"user-string", version=0xA0)
        parsed = spec.parse_file_header(hdr)
        assert parsed.version == 0xA0
        assert parsed.vendor == b"vendor"
        assert parsed.user_string == b"user-string"

    def test_version_range(self):
        spec.file_header(b"", b"", version=0xFF)  # max version ok
        with pytest.raises(ScdaError):
            spec.file_header(b"", b"", version=0x9F)

    def test_rejects_wrong_identifier(self):
        hdr = bytearray(spec.file_header(b"v", b"u"))
        hdr[2:4] = b"00"  # identifier (da)16 → (00)16
        with pytest.raises(ScdaError) as e:
            spec.parse_file_header(bytes(hdr))
        assert e.value.code == ScdaErrorCode.CORRUPT_MAGIC

    def test_rejects_overlong_vendor(self):
        with pytest.raises(ScdaError) as e:
            spec.file_header(b"x" * 21, b"")
        assert e.value.code == ScdaErrorCode.ARG_VENDOR_STRING


# --------------------------------------------------------- size arithmetic --
class TestSectionSizes:
    def test_inline_96(self):
        assert spec.inline_section_bytes() == 96

    @given(st.integers(0, 10**6))
    def test_block(self, E):
        assert spec.block_section_bytes(E) == 96 + spec.padded_data_bytes(E)
        assert spec.block_section_bytes(E) % 32 == 0

    @given(st.integers(0, 1000), st.integers(0, 1000))
    def test_array_divisible_by_32(self, N, E):
        assert spec.array_section_bytes(N, E) % 32 == 0

    @given(st.lists(st.integers(0, 100), max_size=20))
    def test_varray(self, sizes):
        N, total = len(sizes), sum(sizes)
        assert spec.varray_section_bytes(N, total) % 32 == 0
