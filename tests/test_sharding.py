"""Multi-file sharded archives: per-shard byte identity vs the serial
oracle (fuzzed over P ranks × shard counts, raw and compressed), manifest
resolution on restore, delta chains over sharded bases, manager
retention/commit semantics, and the content-id / missing-shard refusal
paths."""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import delta as ckdelta
from repro.checkpoint import manifest as mf
from repro.checkpoint import pytree_io, sharding
from repro.checkpoint.manager import CheckpointManager
from repro.core import (ScdaError, ScdaErrorCode, ThreadComm, fopen_read,
                        run_ranks)

PF = 1 << 16  # small prefetch window → exercises refills


def _assert_tree_equal(got, want):
    for k, v in want.items():
        if isinstance(v, dict):
            _assert_tree_equal(got[k], v)
        elif isinstance(v, np.ndarray) or hasattr(v, "dtype"):
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(v))
        else:
            assert got[k] == v


def _fuzz_tree(rng, max_leaves=7):
    dtypes = [np.float32, np.float64, np.int32, np.uint8, np.float16]
    tree = {}
    n = int(rng.integers(1, max_leaves + 1))
    for i in range(n):
        kind = int(rng.integers(0, 4))
        dt = dtypes[int(rng.integers(0, len(dtypes)))]
        if kind == 0:
            shape = (0, int(rng.integers(1, 5)))
        elif kind == 1:
            shape = ()
        elif kind == 2:
            shape = (int(rng.integers(1, 20000)),)
        else:
            shape = tuple(int(rng.integers(1, 30))
                          for _ in range(int(rng.integers(2, 4))))
        if np.issubdtype(dt, np.floating):
            val = rng.standard_normal(shape).astype(dt)
        else:
            val = rng.integers(0, 100, shape).astype(dt)
        tree[f"leaf{i:02d}"] = val
    tree["aux_lr"] = 0.5
    return tree


def _read_files(path, shards):
    return [open(p, "rb").read() for p in sharding.set_paths(path, shards)]


# -------------------------------------------------------------- placement --

class TestAssignShards:
    def test_deterministic_and_total(self):
        sizes = [100, 1, 50, 50, 3, 0, 200]
        a = sharding.assign_shards(sizes, 3)
        assert a == sharding.assign_shards(sizes, 3)
        assert len(a) == len(sizes)
        assert set(a) <= set(range(3))

    def test_greedy_balances_load(self):
        sizes = [100, 100, 100, 100]
        a = sharding.assign_shards(sizes, 4)
        assert sorted(a) == [0, 1, 2, 3]

    def test_more_shards_than_leaves(self):
        a = sharding.assign_shards([10], 4)
        assert a == [0]

    def test_shard_name_round_trip(self):
        name = sharding.shard_file("/x/step_0000000007.scda", 1, 4)
        parsed = sharding.is_shard_name(os.path.basename(name))
        assert parsed == ("step_0000000007.scda", 1, 4)
        assert sharding.is_shard_name("step_0000000007.scda") is None
        assert sharding.is_shard_name("weird.txt") is None


# -------------------------------------------------- fuzzed byte identity --

@pytest.mark.parametrize("P", [1, 2, 4, 8])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_byte_identity_raw_fuzzed(tmp_path, P, shards):
    """P thread ranks × N shards: every file of the set byte-identical
    to the single-rank write of the same set (same basename)."""
    rng = np.random.default_rng(1000 + 10 * P + shards)
    for trial in range(2):
        tree = _fuzz_tree(rng)
        os.makedirs(tmp_path / f"o{trial}")
        os.makedirs(tmp_path / f"p{trial}")
        oracle = str(tmp_path / f"o{trial}" / "ck.scda")
        pytree_io.save(oracle, tree, step=trial, shards=shards,
                       write_window=0)
        piped = str(tmp_path / f"p{trial}" / "ck.scda")

        def workload(comm):
            pytree_io.save(piped, tree, step=trial, comm=comm,
                           shards=shards)
        run_ranks(ThreadComm.group(P), workload)
        assert _read_files(piped, shards) == _read_files(oracle, shards), \
            f"trial {trial}: sharded save differs at P={P} N={shards}"


@pytest.mark.parametrize("shards", [2, 4])
def test_each_shard_equals_serial_save_of_its_subset(tmp_path, shards):
    """The tentpole claim: shard k is byte-identical to a plain
    single-file save of exactly its leaf subset."""
    rng = np.random.default_rng(42)
    tree = _fuzz_tree(rng, max_leaves=6)
    path = str(tmp_path / "ck.scda")
    doc = pytree_io.save(path, tree, step=5, shards=shards)
    for k in range(shards):
        # Aux leaves live in the set manifest, not the shards, so the
        # serial oracle of shard k is a plain save of its array subset.
        subset = {e["name"]: tree[e["name"]]
                  for e in doc["leaves"] if e["shard"] == k}
        oracle = str(tmp_path / f"subset{k}.scda")
        pytree_io.save(oracle, subset, step=5, shards=0)
        got = open(sharding.shard_file(path, k, shards), "rb").read()
        want = open(oracle, "rb").read()
        assert got == want, f"shard {k} differs from serial subset save"


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_compressed_sharded_round_trip(tmp_path, shards):
    rng = np.random.default_rng(7 + shards)
    tree = _fuzz_tree(rng)
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=3, shards=shards, compressed=True,
                   chunk_bytes=1 << 12)
    for pf in (0, PF, None):
        got, step = pytree_io.restore(path, prefetch_bytes=pf)
        assert step == 3
        _assert_tree_equal(got, tree)


@pytest.mark.parametrize("P", [2, 4, 8])
def test_restore_any_rank_count(tmp_path, P):
    """Readers may use any process count regardless of writer's shards."""
    tree = _fuzz_tree(np.random.default_rng(11))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=2)

    def workload(comm):
        got, step = pytree_io.restore(path, prefetch_bytes=PF)
        assert step == 1
        _assert_tree_equal(got, tree)
        return True
    assert run_ranks(ThreadComm.group(P), workload) == [True] * P


# ------------------------------------------------------ restore semantics --

def test_restore_leaf_and_like(tmp_path):
    import jax
    tree = {"a": np.arange(48, dtype=np.float32).reshape(6, 8),
            "b": np.ones((9,), np.int64), "lr": 0.25}
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=2, shards=2)
    np.testing.assert_array_equal(
        np.asarray(pytree_io.restore_leaf(path, "a")), tree["a"])
    assert pytree_io.restore_leaf(path, "lr") == 0.25
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore_leaf(path, "nope")
    assert ei.value.code == ScdaErrorCode.ARG_SEQUENCE
    like = {"a": jax.ShapeDtypeStruct((6, 8), np.float32),
            "b": jax.ShapeDtypeStruct((9,), np.int64), "lr": 0.0}
    got, step = pytree_io.restore(path, like)
    assert step == 2
    _assert_tree_equal(got, tree)


def test_read_manifest_returns_sharded_doc(tmp_path):
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, {"x": np.zeros(4, np.float32)}, step=9, shards=2)
    doc = pytree_io.read_manifest(path)
    assert doc["format"] == mf.SHARDED_FORMAT
    assert len(doc["shards"]) == 2
    assert [e["name"] for e in doc["leaves"]] == ["x"]


def test_env_knob_controls_sharding(tmp_path, monkeypatch):
    monkeypatch.setenv(sharding.SHARDS_ENV, "3")
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, {"x": np.arange(10, dtype=np.int32)}, step=1)
    assert pytree_io.read_manifest(path)["format"] == mf.SHARDED_FORMAT
    assert len(pytree_io.read_manifest(path)["shards"]) == 3
    monkeypatch.setenv(sharding.SHARDS_ENV, "0")
    path2 = str(tmp_path / "ck2.scda")
    pytree_io.save(path2, {"x": np.arange(10, dtype=np.int32)}, step=1)
    assert pytree_io.read_manifest(path2)["format"] != mf.SHARDED_FORMAT


# -------------------------------------------------------- refusal paths --

def test_missing_shard_is_named(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(3), max_leaves=5)
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=2)
    victim = sharding.shard_file(path, 1, 2)
    os.remove(victim)
    problems = sharding.verify_set(path)
    assert any("missing shard file" in p
               and os.path.basename(victim) in p for p in problems)
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(path)
    assert ei.value.code == ScdaErrorCode.FS_OPEN
    assert os.path.basename(victim) in str(ei.value)


def test_rewritten_shard_refused_by_content_id(tmp_path):
    """A shard rewritten in place (same name, different content) no
    longer matches the manifest's pinned id — restores refuse loudly."""
    tree = {"a": np.arange(100, dtype=np.float32),
            "b": np.ones((50,), np.int32)}
    path = str(tmp_path / "ck.scda")
    doc = pytree_io.save(path, tree, step=1, shards=2,
                         record_hashes=True)
    victim_k = doc["leaves"][0]["shard"]
    victim = sharding.shard_file(path, victim_k, 2)
    name = doc["leaves"][0]["name"]
    pytree_io.save(victim, {name: np.zeros_like(tree[name])}, step=1,
                   shards=0, record_hashes=True)
    with pytest.raises(ScdaError) as ei:
        pytree_io.restore(path)
    assert ei.value.code == ScdaErrorCode.CORRUPT_CHECKSUM
    assert "rewritten" in str(ei.value)
    assert any("content id" in p for p in sharding.verify_set(path))


def test_truncated_shard_fails_verify(tmp_path):
    tree = _fuzz_tree(np.random.default_rng(5))
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, tree, step=1, shards=2)
    victim = sharding.shard_file(path, 0, 2)
    data = open(victim, "rb").read()
    open(victim, "wb").write(data[:len(data) // 2])
    assert sharding.verify_set(path)
    problems = ckdelta.verify_chain(path)
    assert any("shard #0" in p for p in problems)


# ------------------------------------------------------------ delta chains --

@pytest.mark.parametrize("base_shards,delta_shards",
                         [(2, 2), (2, 4), (0, 2), (2, 0)])
def test_delta_chain_over_sharded_bases(tmp_path, base_shards,
                                        delta_shards):
    """Delta chains work across shard sets, including mismatched shard
    counts (moved leaves store fully) and mixed flat/sharded chains."""
    rng = np.random.default_rng(21)
    t0 = {"w": rng.standard_normal((64, 16)).astype(np.float32),
          "b": rng.standard_normal((500,)).astype(np.float64),
          "lr": 0.5}
    t1 = {k: (v.copy() if isinstance(v, np.ndarray) else v)
          for k, v in t0.items()}
    t1["w"] = t1["w"] + 1.0

    mgr = CheckpointManager(str(tmp_path), keep=5, delta=True,
                            shards=base_shards)
    mgr.save(1, t0, blocking=True)
    mgr.shards = delta_shards
    mgr.save(2, t1, blocking=True)

    tip = mgr.path_for(2)
    doc = pytree_io.read_manifest(tip)
    if delta_shards:
        assert doc["format"] == mf.SHARDED_FORMAT
    got, step = pytree_io.restore(tip, prefetch_bytes=PF)
    assert step == 2
    _assert_tree_equal(got, t1)
    got, _ = pytree_io.restore(tip, prefetch_bytes=0)
    _assert_tree_equal(got, t1)
    assert ckdelta.verify_chain(tip) == []


def test_sharded_delta_actually_references_base(tmp_path):
    """Same shard count → unchanged leaves resolve by reference into the
    base's same-k shard (the delta shard is small)."""
    rng = np.random.default_rng(8)
    t0 = {"w": rng.standard_normal((256, 64)).astype(np.float32),
          "b": rng.standard_normal((4096,)).astype(np.float64)}
    t1 = {"w": t0["w"], "b": t0["b"] + 1.0}
    mgr = CheckpointManager(str(tmp_path), keep=5, delta=True, shards=2)
    mgr.save(1, t0, blocking=True)
    mgr.save(2, t1, blocking=True)
    doc = sharding.load_set(mgr.path_for(2))
    bases = [b["file"] for sd in doc["shard_docs"]
             for b in (sd.get("delta") or {}).get("bases", [])]
    assert any(sharding.is_shard_name(b) for b in bases)
    total = lambda p: sum(os.path.getsize(f)  # noqa: E731
                          for f in sharding.set_paths(p, 2))
    assert total(mgr.path_for(2)) < total(mgr.path_for(1)) / 2


def test_squash_sharded_chain_equals_direct_save(tmp_path):
    rng = np.random.default_rng(31)
    t0 = {"w": rng.standard_normal((128, 8)).astype(np.float32),
          "b": rng.standard_normal((100,)).astype(np.float64), "lr": 0.1}
    t1 = dict(t0, w=t0["w"] * 2.0)
    mgr = CheckpointManager(str(tmp_path), keep=5, delta=True, shards=2)
    mgr.save(1, t0, blocking=True)
    mgr.save(2, t1, blocking=True)
    dst = str(tmp_path / "sq.scda")
    ckdelta.squash(mgr.path_for(2), dst)
    oracle = str(tmp_path / "oracle.scda")
    pytree_io.save(oracle, t1, step=2, shards=0, record_hashes=True)
    assert open(dst, "rb").read() == open(oracle, "rb").read()
    assert ckdelta.checkpoint_diff(mgr.path_for(2), dst) == []


# ---------------------------------------------------------------- manager --

def test_manager_retention_drops_whole_sets(tmp_path):
    tree = {"x": np.arange(2000, dtype=np.float32)}
    mgr = CheckpointManager(str(tmp_path), keep=2, shards=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    assert mgr.all_steps() == [3, 4]
    names = set(os.listdir(tmp_path))
    for s in (1, 2):
        stem = f"step_{s:010d}"
        assert not any(n.startswith(stem) for n in names), names
    for s in (3, 4):
        assert f"step_{s:010d}.scda" in names
        assert f"step_{s:010d}-s00of02.scda" in names
    got, step = mgr.restore_latest()
    assert step == 4


def test_manager_sweeps_orphan_shards(tmp_path):
    """A crashed commit renames shards before the manifest; the next
    retention pass collects shard files whose manifest never landed."""
    tree = {"x": np.arange(100, dtype=np.float32)}
    mgr = CheckpointManager(str(tmp_path), keep=2, shards=2)
    mgr.save(1, tree, blocking=True)
    orphan = str(tmp_path / "step_0000000099-s00of02.scda")
    pytree_io.save(orphan, {"x": tree["x"]}, step=99, shards=0)
    mgr.save(2, tree, blocking=True)
    assert not os.path.exists(orphan)
    assert os.path.exists(str(tmp_path / "step_0000000001-s00of02.scda"))


def test_manager_shard_files_protected_while_referenced(tmp_path):
    """Retention keeps a sharded base set alive while a surviving delta
    references its shards: a large unchanged leaf keeps resolving into
    step 1's shard, so dropping step 1's set would brick steps 3 and 4."""
    rng = np.random.default_rng(17)
    w = rng.standard_normal((512, 32)).astype(np.float32)  # never changes
    mgr = CheckpointManager(str(tmp_path), keep=2, delta=True, shards=2,
                            delta_chain=8)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"w": w, "b": np.full((8,), float(s))},
                 blocking=True)
    # steps 3,4 retained; their chains reach back to step 1's full set
    doc = sharding.load_set(mgr.path_for(4), verify=False)
    assert any((sd.get("delta") or {}).get("bases")
               for sd in doc["shard_docs"])
    kept = sorted(n for n in os.listdir(tmp_path) if n.endswith(".scda"))
    assert any(n.startswith("step_0000000001-s") for n in kept), kept
    got, step = pytree_io.restore(mgr.path_for(4), prefetch_bytes=PF)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(got["w"]), w)
    np.testing.assert_array_equal(np.asarray(got["b"]), np.full((8,), 4.0))


def test_manager_restore_like_and_fallback(tmp_path):
    tree = {"x": np.arange(32, dtype=np.float32)}
    mgr = CheckpointManager(str(tmp_path), keep=3, shards=2)
    mgr.save(1, tree, blocking=True)
    mgr.save(2, {"x": tree["x"] * 2}, blocking=True)
    # corrupt the newest set's shard: restore falls back to step 1
    os.remove(sharding.shard_file(mgr.path_for(2), 0, 2))
    got, step = mgr.restore_latest()
    assert step == 1
    np.testing.assert_array_equal(np.asarray(got["x"]), tree["x"])


def test_sharded_manifest_is_valid_scda(tmp_path):
    """The manifest is itself a well-formed scda file: readable with the
    plain core reader, carrying the set description as a block."""
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, {"x": np.zeros(8, np.float32)}, step=4, shards=2)
    with fopen_read(None, path) as r:
        assert r.user_string == mf.SHARDS_FILE_USER_STRING
        hdr = r.read_section_header()
        assert (hdr.type, hdr.user_string) == ("I", mf.STATUS_USER_STRING)
        assert mf.parse_status_inline(r.read_inline_data()) == 4
        hdr = r.read_section_header()
        assert hdr.type == "B"
        assert hdr.user_string == mf.SHARDS_MANIFEST_USER_STRING
        doc = json.loads(r.read_block_data().decode("ascii"))
        assert doc["format"] == mf.SHARDED_FORMAT
