"""The PR-2 random-access layer: ScdaIndex, seek_section, .scdax sidecars.

Core invariant: data reached through an index seek is byte-identical to
data reached by the forward-only walk, for every section kind and every
reading partition — the index changes WHERE the cursor comes from, never
WHAT the reads return.
"""
import os

import pytest

from repro.checkpoint import manifest as mf
from repro.checkpoint import pytree_io
from repro.core import (ScdaError, ScdaIndex, ThreadComm, fopen_read,
                        fopen_write, partition, run_ranks, scan_sections)
from repro.core.errors import ScdaErrorCode

V_SIZES = [5, 0, 17, 3, 64, 1]


def write_all_kinds(path, comm=None):
    """One section of every physical kind: I, B, A, V, zB, zA, zV."""
    rng = __import__("random").Random(7)
    elems = [bytes(rng.randrange(256) for _ in range(s)) for s in V_SIZES]
    blk = b"0123456789abcdef" * 40
    arr = bytes(range(256)) * 2
    with fopen_write(comm, path, user_string=b"index test") as f:
        f.write_inline(b"inl", b"#" * 32)
        f.write_block(b"blk", blk)
        f.write_array(b"arr", arr, [64], 8)
        f.write_varray(b"var", elems, [len(elems)], V_SIZES)
        f.write_block(b"zblk", blk, encode=True)
        f.write_array(b"zarr", arr, [128], 4, encode=True)
        f.write_varray(b"zvar", elems, [len(elems)], V_SIZES, encode=True)
    return blk, arr, elems


@pytest.fixture
def archive(tmp_path):
    path = str(tmp_path / "all_kinds.scda")
    blk, arr, elems = write_all_kinds(path)
    return path, blk, arr, elems


KINDS = ["I", "B", "A", "V", "zB", "zA", "zV"]
LOGICAL = ["I", "B", "A", "V", "B", "A", "V"]


class TestScanAndSkip:
    """skip_data / scan_sections across every section kind (satellite)."""

    def test_scan_decoded_kinds(self, archive):
        path, _, _, _ = archive
        headers = scan_sections(path)
        assert [h.type for h in headers] == LOGICAL
        assert [h.decoded for h in headers] == [False] * 4 + [True] * 3

    def test_scan_raw_kinds(self, archive):
        path, _, _, _ = archive
        # decode=False sees the physical sections: each §3-encoded logical
        # section is two raw sections (I+B, I+V, A+V).
        raw = scan_sections(path, decode=False)
        assert [h.type for h in raw] == \
            ["I", "B", "A", "V", "I", "B", "I", "V", "A", "V"]
        assert not any(h.decoded for h in raw)

    def test_skip_every_kind_lands_on_next_header(self, archive):
        path, _, _, _ = archive
        with fopen_read(None, path) as r:
            starts = []
            while not r.at_eof:
                starts.append(r.cursor)
                r.read_section_header()
                r.skip_data()
            assert r.cursor == r._backend.size()
        # every recorded start parses as a section header again
        with fopen_read(None, path) as r:
            for s in starts:
                r.cursor = s
                r.read_section_header()
                r.skip_data()

    def test_scan_sections_accepts_communicator(self, archive):
        path, _, _, _ = archive
        serial = scan_sections(path)

        def scan(comm):
            return scan_sections(path, comm=comm)

        for per_rank in run_ranks(ThreadComm.group(3), scan):
            assert per_rank == serial


class TestIndex:
    def test_entries_match_scan(self, archive):
        path, _, _, _ = archive
        idx = ScdaIndex.build(path)
        assert [e.kind for e in idx] == KINDS
        assert [e.header() for e in idx] == scan_sections(path)
        # entries tile the file exactly
        assert idx.entries[0].start == 128
        for a, b in zip(idx.entries, idx.entries[1:]):
            assert a.end == b.start
        assert idx.entries[-1].end == idx.file_size == os.path.getsize(path)

    def test_find(self, archive):
        path, _, _, _ = archive
        idx = ScdaIndex.build(path)
        assert idx.find(b"zarr") == 5
        assert idx.find(b"nope") == -1
        assert idx.find(b"blk", occurrence=1) == -1

    def test_seek_reads_byte_identical(self, archive):
        path, blk, arr, elems = archive
        with fopen_read(None, path) as r:
            # visit sections in a deliberately non-forward order
            assert r.seek_section(4).E == len(blk)
            assert r.read_block_data() == blk  # zB: transparently inflated

            assert r.seek_section(2).N == 64
            assert b"".join(r.read_array_data([64])) == arr

            hdr = r.seek_section(6)
            sizes = r.read_varray_sizes([hdr.N])
            assert sizes == V_SIZES
            assert r.read_varray_data([hdr.N], sizes) == elems

            hdr = r.seek_section(3)
            assert r.read_varray_elements([2, 4]) == [elems[2], elems[4]]
            r.skip_data()

            assert r.seek_section(0).type == "I"
            assert r.read_inline_data() == b"#" * 32

            assert r.seek_section(5).N == 128  # zA
            assert b"".join(r.read_array_data([128])) == arr

    def test_seek_windowed_reads_match_forward(self, archive):
        path, _, arr, _ = archive
        with fopen_read(None, path) as r:
            hdr = r.seek_section(2)
            windows = [(0, 3), (10, 5), (63, 1)]
            got = r.read_array_windows(windows, hdr.E)
        for (start, n), data in zip(windows, got):
            assert data == arr[start * 8:(start + n) * 8]

    def test_open_section_by_user_string(self, archive):
        path, blk, _, _ = archive
        with fopen_read(None, path) as r:
            hdr = r.open_section(b"zblk")
            assert hdr.decoded and r.read_block_data() == blk
            with pytest.raises(ScdaError):
                r.open_section(b"missing")

    def test_seek_out_of_range(self, archive):
        path, _, _, _ = archive
        with fopen_read(None, path) as r:
            with pytest.raises(ScdaError):
                r.seek_section(99)

    def test_seek_discards_pending(self, archive):
        path, blk, _, _ = archive
        with fopen_read(None, path) as r:
            idx = r.index()  # build before any section is pending
            r.seek_section(2)  # pending A, data never consumed
            assert r.seek_section(1).E == len(blk)
            assert r.read_block_data() == blk
            assert idx is r.index()

    def test_seek_with_pending_on_fresh_reader(self, archive):
        """Seek-after-browse must not depend on whether an index was
        already cached: the lazy build preserves the pending section."""
        path, blk, _, _ = archive
        with fopen_read(None, path) as r:
            r.read_section_header()  # browse, never consume
            assert r.seek_section(1).E == len(blk)  # triggers index build
            assert r.read_block_data() == blk

    def test_index_build_preserves_walk_state(self, archive):
        path, blk, _, _ = archive
        with fopen_read(None, path) as r:
            r.read_section_header()
            r.skip_data()
            hdr = r.read_section_header()  # pending B
            r.index()                      # mid-walk build
            assert r.read_block_data() == blk  # walk continues untouched
            assert hdr.E == len(blk)


def assert_seek_equals_forward(path, P):
    """Byte-identity: seek-based partitioned reads == serial forward reads."""
    serial = {}
    with fopen_read(None, path) as r:
        i = 0
        while not r.at_eof:
            hdr = r.read_section_header()
            if hdr.type == "I":
                serial[i] = r.read_inline_data()
            elif hdr.type == "B":
                serial[i] = r.read_block_data()
            elif hdr.type == "A":
                serial[i] = b"".join(r.read_array_data([hdr.N]))
            else:
                sizes = r.read_varray_sizes([hdr.N])
                serial[i] = b"".join(r.read_varray_data([hdr.N], sizes))
            i += 1
    nsec = len(serial)

    def workload(comm):
        out = {}
        with fopen_read(comm, path) as r:
            for i in reversed(range(nsec)):  # stress non-forward order
                hdr = r.seek_section(i)
                if hdr.type == "I":
                    out[i] = r.read_inline_data()
                elif hdr.type == "B":
                    out[i] = r.read_block_data()
                elif hdr.type == "A":
                    counts = partition.uniform(hdr.N, comm.size)
                    out[i] = b"".join(r.read_array_data(counts))
                else:
                    counts = partition.uniform(hdr.N, comm.size)
                    sizes = r.read_varray_sizes(counts)
                    out[i] = b"".join(r.read_varray_data(counts, sizes))
        return out

    per_rank = run_ranks(ThreadComm.group(P), workload)
    for i in range(nsec):
        joined = b"".join(rank[i] for rank in per_rank
                          if rank[i] is not None)
        # inline/block reads return full data on every rank
        expect = serial[i] * (P if i in (0, 1, 4) else 1)
        assert joined == expect, f"section {i} differs under P={P}"


@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_index_vs_forward_byte_identity(tmp_path, P):
    """Satellite: index-vs-forward byte-identity under ThreadComm P∈{1,2,4,8}."""
    path = str(tmp_path / "p.scda")
    write_all_kinds(path)
    assert_seek_equals_forward(path, P)


class TestSidecar:
    def test_round_trip(self, archive):
        path, _, _, _ = archive
        idx = ScdaIndex.build(path)
        sp = idx.write_sidecar()
        assert sp == path + ".scdax"
        # the sidecar is itself a valid scda file
        side = scan_sections(sp)
        assert [h.type for h in side] == ["I", "B"]
        loaded = ScdaIndex.load_sidecar(path)
        assert loaded.entries == idx.entries
        assert loaded.file_size == idx.file_size
        assert loaded.user_string == idx.user_string
        loaded.verify(deep=True)

    def test_stale_sidecar_detected(self, archive):
        path, _, _, _ = archive
        ScdaIndex.build(path).write_sidecar()
        with open(path, "ab") as fh:
            fh.write(b"tail")
        with pytest.raises(ScdaError) as ei:
            ScdaIndex.load_sidecar(path)
        assert ei.value.code == ScdaErrorCode.CORRUPT_TRUNCATED

    def test_same_size_rewrite_caught_on_seek(self, tmp_path):
        """A same-size rewrite defeats the size probe; the per-seek header
        check must still refuse to serve stale metadata."""
        path = str(tmp_path / "f.scda")
        with fopen_write(None, path) as f:
            f.write_block(b"first", b"x" * 100)
        idx = ScdaIndex.build(path)
        idx.write_sidecar()
        with fopen_write(None, path) as f:
            f.write_block(b"other", b"y" * 100)  # same geometry, new name
        loaded = ScdaIndex.load_sidecar(path)  # size probe passes
        with fopen_read(None, path) as r:
            r.set_index(loaded)
            with pytest.raises(ScdaError) as ei:
                r.seek_section(0)
            assert ei.value.code == ScdaErrorCode.CORRUPT_ENCODING
        with pytest.raises(ScdaError):
            loaded.verify(deep=True)

    def test_cached_falls_back_and_rewrites(self, archive):
        path, _, _, _ = archive
        assert not os.path.exists(path + ".scdax")
        idx = ScdaIndex.cached(path)
        assert os.path.exists(path + ".scdax")  # written on miss
        again = ScdaIndex.cached(path)
        assert again.entries == idx.entries


class TestLazyRestore:
    def test_restore_leaf_matches_full(self, tmp_path):
        np = pytest.importorskip("numpy")
        path = str(tmp_path / "ck.scda")
        tree = {"w": np.arange(48, dtype=np.float64).reshape(6, 8),
                "b": np.full((17,), 3, np.int32), "lr": 0.5}
        pytree_io.save(path, tree, step=11)
        full, step = pytree_io.restore(path)
        assert step == 11
        for name in ("w", "b"):
            lazy = pytree_io.restore_leaf(path, name)
            np.testing.assert_array_equal(lazy, full[name])
        assert pytree_io.restore_leaf(path, "lr") == 0.5
        with pytest.raises(ScdaError):
            pytree_io.restore_leaf(path, "nope")

    def test_restore_leaf_compressed_selective(self, tmp_path):
        np = pytest.importorskip("numpy")
        path = str(tmp_path / "ckz.scda")
        tree = {"w": np.arange(4096, dtype=np.float32),
                "b": np.zeros((2048,), np.float32)}
        pytree_io.save(path, tree, compressed=True, chunk_bytes=1 << 10)
        np.testing.assert_array_equal(
            pytree_io.restore_leaf(path, "w"), tree["w"])

    def test_restore_leaf_uses_fresh_sidecar(self, tmp_path):
        np = pytest.importorskip("numpy")
        path = str(tmp_path / "ck.scda")
        tree = {"w": np.arange(10, dtype=np.float32)}
        pytree_io.save(path, tree)
        ScdaIndex.build(path).write_sidecar()
        np.testing.assert_array_equal(
            pytree_io.restore_leaf(path, "w"), tree["w"])

    def test_leaf_user_string_round_trip(self):
        assert mf.leaf_user_string(7) == b"leaf 000007"
