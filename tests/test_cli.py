"""scdatool: ls / cat / fsck / index / copy round-trips, and fsck's
exit status on every injected corruption class (acceptance criterion)."""
import os

import pytest

from repro.core import ScdaIndex, fopen_write, scan_sections
from repro.tools.cli import main
from repro.tools.fsck import fsck_file

V_SIZES = [5, 0, 17, 3]
BLK = b"0123456789abcdef" * 40
ARR = bytes(range(256))
ELEMS = [bytes((i * 37 + j) % 256 for j in range(s))
         for i, s in enumerate(V_SIZES)]


def write_archive(path):
    with fopen_write(None, path, user_string=b"cli test") as f:
        f.write_inline(b"inl", b"#" * 32)
        f.write_block(b"blk", BLK)
        f.write_array(b"arr", ARR, [32], 8)
        f.write_varray(b"var", ELEMS, [len(ELEMS)], V_SIZES)
        f.write_block(b"zblk", BLK, encode=True)
        f.write_array(b"zarr", ARR, [64], 4, encode=True)
        f.write_varray(b"zvar", ELEMS, [len(ELEMS)], V_SIZES, encode=True)


@pytest.fixture
def archive(tmp_path):
    path = str(tmp_path / "a.scda")
    write_archive(path)
    return path


class TestLs:
    def test_lists_all_sections(self, archive, capsys):
        assert main(["ls", archive]) == 0
        out = capsys.readouterr().out
        for user in ("inl", "blk", "arr", "var", "zblk", "zarr", "zvar"):
            assert user in out
        assert "7 sections" in out


class TestCat:
    def test_block_by_name_and_number(self, archive, capfdbinary):
        assert main(["cat", archive, "blk"]) == 0
        assert capfdbinary.readouterr().out == BLK
        assert main(["cat", archive, "1"]) == 0
        assert capfdbinary.readouterr().out == BLK

    def test_decoded_payloads(self, archive, capfdbinary):
        assert main(["cat", archive, "zblk"]) == 0
        assert capfdbinary.readouterr().out == BLK
        assert main(["cat", archive, "zarr"]) == 0
        assert capfdbinary.readouterr().out == ARR
        assert main(["cat", archive, "zvar"]) == 0
        assert capfdbinary.readouterr().out == b"".join(ELEMS)

    def test_varray_element(self, archive, capfdbinary):
        assert main(["cat", archive, "var", "--element", "2"]) == 0
        assert capfdbinary.readouterr().out == ELEMS[2]

    def test_element_on_non_varray_errors(self, archive, capfdbinary):
        assert main(["cat", archive, "blk", "--element", "0"]) == 1
        assert capfdbinary.readouterr().out == b""  # nothing dumped

    def test_extent_is_raw_bytes(self, archive, capfdbinary):
        idx = ScdaIndex.build(archive)
        e = idx.entries[idx.find(b"zblk")]
        assert main(["cat", archive, "zblk", "--extent"]) == 0
        with open(archive, "rb") as fh:
            fh.seek(e.start)
            assert capfdbinary.readouterr().out == fh.read(e.end - e.start)

    def test_unknown_section(self, archive, capsys):
        assert main(["cat", archive, "missing"]) == 1


class TestFsck:
    def test_clean(self, archive, capsys):
        assert main(["fsck", archive]) == 0
        assert "clean" in capsys.readouterr().out

    def test_missing_file(self, tmp_path, capsys):
        assert main(["fsck", str(tmp_path / "nope.scda")]) == 1


def _mutate(path, fn):
    data = bytearray(open(path, "rb").read())
    fn(data, ScdaIndex.build(path))
    with open(path, "wb") as fh:
        fh.write(bytes(data))


def corrupt_magic(b, idx):
    b[0] = ord("X")


def corrupt_section_type(b, idx):
    b[idx.entries[0].start] = ord("Q")


def corrupt_count_letter(b, idx):
    e = idx.entries[idx.find(b"blk")]
    b[e.start + 64] = ord("N")  # the B section's 'E' count entry


def corrupt_count_digits(b, idx):
    e = idx.entries[idx.find(b"blk")]
    b[e.start + 66] = ord("x")


def corrupt_varray_entry_letter(b, idx):
    e = idx.entries[idx.find(b"var")]
    b[e.entries_start] = ord("X")  # first per-element 'E' entry


def corrupt_truncate(b, idx):
    del b[len(b) - 40:]


def corrupt_compression_framing(b, idx):
    e = idx.entries[idx.find(b"zblk")]
    b[e.data_start + 5] = 0x01  # not a base64 alphabet byte


def corrupt_trailing_garbage(b, idx):
    b.extend(b"\x00" * 100)


CORRUPTIONS = [corrupt_magic, corrupt_section_type, corrupt_count_letter,
               corrupt_count_digits, corrupt_varray_entry_letter,
               corrupt_truncate, corrupt_compression_framing,
               corrupt_trailing_garbage]


@pytest.mark.parametrize("mutate", CORRUPTIONS,
                         ids=lambda f: f.__name__)
def test_fsck_nonzero_on_corruption(tmp_path, capsys, mutate):
    """Acceptance: fsck exits non-zero on each injected corruption class."""
    path = str(tmp_path / "bad.scda")
    write_archive(path)
    _mutate(path, mutate)
    assert main(["fsck", "-q", path]) == 1
    findings = fsck_file(path)
    assert any(f.severity == "error" for f in findings)


def test_fsck_fast_skips_payload_checks(tmp_path):
    """--fast validates structure only: framing corruption passes, a
    malformed entry table still fails."""
    path = str(tmp_path / "f.scda")
    write_archive(path)
    _mutate(path, corrupt_compression_framing)
    assert main(["fsck", "--fast", "-q", path]) == 0
    assert main(["fsck", "-q", path]) == 1


class TestIndexCommand:
    def test_write_and_check(self, archive, capsys):
        assert main(["index", archive]) == 0
        assert os.path.exists(archive + ".scdax")
        assert main(["index", "--check", archive]) == 0

    def test_check_detects_stale(self, archive, capsys):
        assert main(["index", archive]) == 0
        with open(archive, "ab") as fh:
            fh.write(b"tail")
        assert main(["index", "--check", archive]) == 1

    def test_fsck_reports_stale_sidecar(self, tmp_path, capsys):
        path = str(tmp_path / "s.scda")
        write_archive(path)
        assert main(["index", path]) == 0
        write_archive(path)  # same size, new mtime — deep verify catches
        os.truncate(path, os.path.getsize(path) - 32)
        assert main(["fsck", "-q", path]) == 1


class TestCopy:
    def _logical(self, path):
        out = []
        for h in scan_sections(path):
            out.append((h.type, h.user_string, h.N, h.E))
        return out

    def test_copy_preserves_bytes(self, archive, tmp_path, capsys):
        dst = str(tmp_path / "copy.scda")
        assert main(["copy", archive, dst]) == 0
        with open(archive, "rb") as a, open(dst, "rb") as b:
            assert a.read() == b.read()  # encoding preserved → identical

    def test_recompress_and_decompress_round_trip(self, archive, tmp_path,
                                                  capfdbinary):
        rz = str(tmp_path / "rz.scda")
        rw = str(tmp_path / "rw.scda")
        assert main(["copy", "--recompress", "--index", archive, rz]) == 0
        assert main(["copy", "--decompress", rz, rw]) == 0
        capfdbinary.readouterr()
        assert os.path.exists(rz + ".scdax")
        assert not fsck_file(rz) and not fsck_file(rw)
        # every non-inline section of rz is §3-encoded, none of rw is
        assert all(h.decoded for h in scan_sections(rz) if h.type != "I")
        assert not any(h.decoded for h in scan_sections(rw))
        # logical shape survives both rewrites
        assert self._logical(rw) == self._logical(archive)
        # and payloads round-trip exactly
        for section, want in (("blk", BLK), ("zvar", b"".join(ELEMS))):
            assert main(["cat", rw, section]) == 0
            assert capfdbinary.readouterr().out == want
