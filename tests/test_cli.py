"""scdatool: ls / cat / fsck / index / copy round-trips, and fsck's
exit status on every injected corruption class (acceptance criterion)."""
import os

import pytest

from repro.core import ScdaIndex, fopen_write, scan_sections
from repro.tools.cli import main
from repro.tools.fsck import fsck_file

V_SIZES = [5, 0, 17, 3]
BLK = b"0123456789abcdef" * 40
ARR = bytes(range(256))
ELEMS = [bytes((i * 37 + j) % 256 for j in range(s))
         for i, s in enumerate(V_SIZES)]


def write_archive(path):
    with fopen_write(None, path, user_string=b"cli test") as f:
        f.write_inline(b"inl", b"#" * 32)
        f.write_block(b"blk", BLK)
        f.write_array(b"arr", ARR, [32], 8)
        f.write_varray(b"var", ELEMS, [len(ELEMS)], V_SIZES)
        f.write_block(b"zblk", BLK, encode=True)
        f.write_array(b"zarr", ARR, [64], 4, encode=True)
        f.write_varray(b"zvar", ELEMS, [len(ELEMS)], V_SIZES, encode=True)


@pytest.fixture
def archive(tmp_path):
    path = str(tmp_path / "a.scda")
    write_archive(path)
    return path


class TestLs:
    def test_lists_all_sections(self, archive, capsys):
        assert main(["ls", archive]) == 0
        out = capsys.readouterr().out
        for user in ("inl", "blk", "arr", "var", "zblk", "zarr", "zvar"):
            assert user in out
        assert "7 sections" in out


class TestCat:
    def test_block_by_name_and_number(self, archive, capfdbinary):
        assert main(["cat", archive, "blk"]) == 0
        assert capfdbinary.readouterr().out == BLK
        assert main(["cat", archive, "1"]) == 0
        assert capfdbinary.readouterr().out == BLK

    def test_decoded_payloads(self, archive, capfdbinary):
        assert main(["cat", archive, "zblk"]) == 0
        assert capfdbinary.readouterr().out == BLK
        assert main(["cat", archive, "zarr"]) == 0
        assert capfdbinary.readouterr().out == ARR
        assert main(["cat", archive, "zvar"]) == 0
        assert capfdbinary.readouterr().out == b"".join(ELEMS)

    def test_varray_element(self, archive, capfdbinary):
        assert main(["cat", archive, "var", "--element", "2"]) == 0
        assert capfdbinary.readouterr().out == ELEMS[2]

    def test_element_on_non_varray_errors(self, archive, capfdbinary):
        assert main(["cat", archive, "blk", "--element", "0"]) == 1
        assert capfdbinary.readouterr().out == b""  # nothing dumped

    def test_extent_is_raw_bytes(self, archive, capfdbinary):
        idx = ScdaIndex.build(archive)
        e = idx.entries[idx.find(b"zblk")]
        assert main(["cat", archive, "zblk", "--extent"]) == 0
        with open(archive, "rb") as fh:
            fh.seek(e.start)
            assert capfdbinary.readouterr().out == fh.read(e.end - e.start)

    def test_unknown_section(self, archive, capsys):
        assert main(["cat", archive, "missing"]) == 1


class TestFsck:
    def test_clean(self, archive, capsys):
        assert main(["fsck", archive]) == 0
        assert "clean" in capsys.readouterr().out

    def test_missing_file(self, tmp_path, capsys):
        assert main(["fsck", str(tmp_path / "nope.scda")]) == 1


def _mutate(path, fn):
    data = bytearray(open(path, "rb").read())
    fn(data, ScdaIndex.build(path))
    with open(path, "wb") as fh:
        fh.write(bytes(data))


def corrupt_magic(b, idx):
    b[0] = ord("X")


def corrupt_section_type(b, idx):
    b[idx.entries[0].start] = ord("Q")


def corrupt_count_letter(b, idx):
    e = idx.entries[idx.find(b"blk")]
    b[e.start + 64] = ord("N")  # the B section's 'E' count entry


def corrupt_count_digits(b, idx):
    e = idx.entries[idx.find(b"blk")]
    b[e.start + 66] = ord("x")


def corrupt_varray_entry_letter(b, idx):
    e = idx.entries[idx.find(b"var")]
    b[e.entries_start] = ord("X")  # first per-element 'E' entry


def corrupt_truncate(b, idx):
    del b[len(b) - 40:]


def corrupt_compression_framing(b, idx):
    e = idx.entries[idx.find(b"zblk")]
    b[e.data_start + 5] = 0x01  # not a base64 alphabet byte


def corrupt_trailing_garbage(b, idx):
    b.extend(b"\x00" * 100)


CORRUPTIONS = [corrupt_magic, corrupt_section_type, corrupt_count_letter,
               corrupt_count_digits, corrupt_varray_entry_letter,
               corrupt_truncate, corrupt_compression_framing,
               corrupt_trailing_garbage]


@pytest.mark.parametrize("mutate", CORRUPTIONS,
                         ids=lambda f: f.__name__)
def test_fsck_nonzero_on_corruption(tmp_path, capsys, mutate):
    """Acceptance: fsck exits non-zero on each injected corruption class."""
    path = str(tmp_path / "bad.scda")
    write_archive(path)
    _mutate(path, mutate)
    assert main(["fsck", "-q", path]) == 1
    findings = fsck_file(path)
    assert any(f.severity == "error" for f in findings)


def test_fsck_fast_skips_payload_checks(tmp_path):
    """--fast validates structure only: framing corruption passes, a
    malformed entry table still fails."""
    path = str(tmp_path / "f.scda")
    write_archive(path)
    _mutate(path, corrupt_compression_framing)
    assert main(["fsck", "--fast", "-q", path]) == 0
    assert main(["fsck", "-q", path]) == 1


class TestIndexCommand:
    def test_write_and_check(self, archive, capsys):
        assert main(["index", archive]) == 0
        assert os.path.exists(archive + ".scdax")
        assert main(["index", "--check", archive]) == 0

    def test_check_detects_stale(self, archive, capsys):
        assert main(["index", archive]) == 0
        with open(archive, "ab") as fh:
            fh.write(b"tail")
        assert main(["index", "--check", archive]) == 1

    def test_check_checksums_requires_recorded_crcs(self, archive, capsys):
        # a checksum-free sidecar is fresh, but --check --checksums
        # must refuse it (verify would fail on every section)
        assert main(["index", archive]) == 0
        assert main(["index", "--check", "--checksums", archive]) == 1
        assert "no payload checksums" in capsys.readouterr().err
        assert main(["index", "--checksums", archive]) == 0
        assert main(["index", "--check", "--checksums", archive]) == 0
        assert "fresh" in capsys.readouterr().out

    def test_fsck_reports_stale_sidecar(self, tmp_path, capsys):
        path = str(tmp_path / "s.scda")
        write_archive(path)
        assert main(["index", path]) == 0
        write_archive(path)  # same size, new mtime — deep verify catches
        os.truncate(path, os.path.getsize(path) - 32)
        assert main(["fsck", "-q", path]) == 1


class TestVerify:
    """scdatool verify: archive integrity against the sidecar checksum
    manifest, without a reference copy (ROADMAP open item)."""

    def test_index_checksums_then_verify_clean(self, archive, capsys):
        assert main(["index", "--checksums", archive]) == 0
        assert main(["verify", archive]) == 0
        assert "verified" in capsys.readouterr().out

    def test_checksums_are_backward_compatible_extra_key(self, archive):
        assert main(["index", "--checksums", archive]) == 0
        idx = ScdaIndex.load_sidecar(archive)
        assert all(e.crc32 is not None for e in idx)
        # a fresh (checksum-free) scan still deep-verifies against it:
        # crc32 is excluded from entry equality
        idx.verify(deep=True)
        # and the plain index command still reads/writes the sidecar
        assert main(["index", "--check", archive]) == 0

    def test_verify_detects_payload_corruption(self, archive, capsys):
        assert main(["index", "--checksums", archive]) == 0
        idx = ScdaIndex.load_sidecar(archive)
        e = next(en for en in idx if en.kind == "A")
        with open(archive, "r+b") as fh:  # flip one raw payload byte
            fh.seek(e.data_start + 5)
            c = fh.read(1)
            fh.seek(e.data_start + 5)
            fh.write(bytes([c[0] ^ 0xFF]))
        assert main(["verify", archive]) == 1
        out = capsys.readouterr().out
        assert "CRC32" in out and "FAILED" in out

    def test_verify_detects_encoded_corruption(self, archive, capsys):
        assert main(["index", "--checksums", archive]) == 0
        idx = ScdaIndex.load_sidecar(archive)
        e = next(en for en in idx if en.kind == "zV")
        with open(archive, "r+b") as fh:  # clobber inside the §3 stream
            fh.seek(e.v_data_start + 2)
            fh.write(b"!!!!")
        assert main(["verify", archive]) == 1
        out = capsys.readouterr().out
        assert "FAILED" in out

    def test_verify_without_sidecar_fails_with_hint(self, archive,
                                                    capsys):
        assert main(["verify", archive]) == 1
        assert "--checksums" in capsys.readouterr().err

    def test_verify_without_checksums_fails(self, archive, capsys):
        assert main(["index", archive]) == 0  # sidecar, but no CRCs
        assert main(["verify", archive]) == 1
        assert "no checksum recorded" in capsys.readouterr().out

    def test_verify_stale_sidecar_fails(self, archive, capsys):
        assert main(["index", "--checksums", archive]) == 0
        with open(archive, "ab") as fh:
            fh.write(b"tail")
        assert main(["verify", archive]) == 1

    def test_checksums_stable_across_reencoding(self, archive, tmp_path):
        """Payload CRCs are logical: a recompressed copy carries the same
        checksums (consistent with diff's leaf-wise equality)."""
        rz = str(tmp_path / "rz.scda")
        assert main(["copy", "--recompress", archive, rz]) == 0
        a = ScdaIndex.build(archive).with_checksums()
        b = ScdaIndex.build(rz).with_checksums()
        assert [e.crc32 for e in a] == [e.crc32 for e in b]


class TestCopy:
    def _logical(self, path):
        out = []
        for h in scan_sections(path):
            out.append((h.type, h.user_string, h.N, h.E))
        return out

    def test_copy_preserves_bytes(self, archive, tmp_path, capsys):
        dst = str(tmp_path / "copy.scda")
        assert main(["copy", archive, dst]) == 0
        with open(archive, "rb") as a, open(dst, "rb") as b:
            assert a.read() == b.read()  # encoding preserved → identical

    def test_recompress_and_decompress_round_trip(self, archive, tmp_path,
                                                  capfdbinary):
        rz = str(tmp_path / "rz.scda")
        rw = str(tmp_path / "rw.scda")
        assert main(["copy", "--recompress", "--index", archive, rz]) == 0
        assert main(["copy", "--decompress", rz, rw]) == 0
        capfdbinary.readouterr()
        assert os.path.exists(rz + ".scdax")
        assert not fsck_file(rz) and not fsck_file(rw)
        # every non-inline section of rz is §3-encoded, none of rw is
        assert all(h.decoded for h in scan_sections(rz) if h.type != "I")
        assert not any(h.decoded for h in scan_sections(rw))
        # logical shape survives both rewrites
        assert self._logical(rw) == self._logical(archive)
        # and payloads round-trip exactly
        for section, want in (("blk", BLK), ("zvar", b"".join(ELEMS))):
            assert main(["cat", rw, section]) == 0
            assert capfdbinary.readouterr().out == want


class TestDiff:
    def test_identical_archives_match(self, archive, tmp_path, capsys):
        other = str(tmp_path / "b.scda")
        write_archive(other)
        assert main(["diff", archive, other]) == 0
        assert "match leaf-wise" in capsys.readouterr().out

    def test_recompressed_copy_is_leafwise_equal(self, archive, tmp_path,
                                                 capsys):
        """Different on-disk encoding, identical logical content: the
        decoded fallback must report equality."""
        # a copy written with the MIME line-break style differs byte-wise
        # in every §3-encoded section but is logically identical
        from repro.core import fopen_read, fopen_write, spec
        dst = str(tmp_path / "mime.scda")
        with fopen_read(None, archive) as r:
            idx = r.index()
            with fopen_write(None, dst, user_string=r.user_string,
                             style=spec.MIME) as w:
                for i, e in enumerate(idx):
                    hdr = r.seek_section(i)
                    if hdr.type == "I":
                        w.write_inline(hdr.user_string, r.read_inline_data())
                    elif hdr.type == "B":
                        w.write_block(hdr.user_string, r.read_block_data(),
                                      encode=e.decoded)
                    elif hdr.type == "A":
                        w.write_array(hdr.user_string,
                                      r.read_array_data([hdr.N]),
                                      [hdr.N], hdr.E, indirect=True,
                                      encode=e.decoded)
                    else:
                        sizes = r.read_varray_sizes([hdr.N])
                        w.write_varray(hdr.user_string,
                                       r.read_varray_data([hdr.N], sizes),
                                       [hdr.N], sizes, encode=e.decoded)
        with open(archive, "rb") as a, open(dst, "rb") as b:
            assert a.read() != b.read()  # raw bytes really do differ
        assert main(["diff", archive, dst]) == 0
        assert "match leaf-wise" in capsys.readouterr().out

    def test_payload_difference_exits_nonzero(self, archive, tmp_path,
                                              capsys):
        other = str(tmp_path / "b.scda")
        with fopen_write(None, other, user_string=b"cli test") as f:
            f.write_inline(b"inl", b"#" * 32)
            f.write_block(b"blk", BLK)
            mutated = bytearray(ARR)
            mutated[17] ^= 0xFF
            f.write_array(b"arr", bytes(mutated), [32], 8)
            f.write_varray(b"var", ELEMS, [len(ELEMS)], V_SIZES)
            f.write_block(b"zblk", BLK, encode=True)
            f.write_array(b"zarr", ARR, [64], 4, encode=True)
            f.write_varray(b"zvar", ELEMS, [len(ELEMS)], V_SIZES,
                           encode=True)
        assert main(["diff", archive, other]) == 1
        out = capsys.readouterr().out
        assert "section 2 ('arr')" in out and "payload differs" in out

    def test_header_and_count_differences(self, archive, tmp_path, capsys):
        shorter = str(tmp_path / "short.scda")
        with fopen_write(None, shorter, user_string=b"cli test") as f:
            f.write_inline(b"inl", b"#" * 32)
            f.write_block(b"other name", BLK)
        assert main(["diff", archive, shorter]) == 1
        assert "section count differs" in capsys.readouterr().out
        assert main(["diff", shorter, archive]) == 1

    def test_all_lists_every_difference(self, archive, tmp_path, capsys):
        other = str(tmp_path / "b.scda")
        with fopen_write(None, other, user_string=b"cli test") as f:
            f.write_inline(b"inl", b"@" * 32)           # diff 1
            f.write_block(b"blk", BLK[:-1] + b"X")      # diff 2
            f.write_array(b"arr", ARR, [32], 8)
            f.write_varray(b"var", ELEMS, [len(ELEMS)], V_SIZES)
            f.write_block(b"zblk", BLK, encode=True)
            f.write_array(b"zarr", ARR, [64], 4, encode=True)
            f.write_varray(b"zvar", ELEMS, [len(ELEMS)], V_SIZES,
                           encode=True)
        assert main(["diff", archive, other, "--all"]) == 1
        out = capsys.readouterr().out
        assert "section 0" in out and "section 1" in out
        assert "2 differences listed" in out

    def test_encoded_content_difference_found(self, archive, tmp_path,
                                              capsys):
        """A difference hidden inside compressed payloads is detected."""
        other = str(tmp_path / "b.scda")
        mutated = list(ELEMS)
        mutated[2] = bytes(b ^ 1 for b in mutated[2])
        with fopen_write(None, other, user_string=b"cli test") as f:
            f.write_inline(b"inl", b"#" * 32)
            f.write_block(b"blk", BLK)
            f.write_array(b"arr", ARR, [32], 8)
            f.write_varray(b"var", ELEMS, [len(ELEMS)], V_SIZES)
            f.write_block(b"zblk", BLK, encode=True)
            f.write_array(b"zarr", ARR, [64], 4, encode=True)
            f.write_varray(b"zvar", mutated, [len(ELEMS)], V_SIZES,
                           encode=True)
        assert main(["diff", archive, other]) == 1
        out = capsys.readouterr().out
        assert "section 6 ('zvar')" in out and "element 2" in out


# --------------------------------------------------------------------------
# Error paths: bad inputs exit non-zero with a diagnostic, never a
# traceback (main() catches ScdaError/OSError/ValueError; an uncaught
# exception would fail these tests by propagating out of main()).
# --------------------------------------------------------------------------

class TestErrorPaths:
    @pytest.fixture
    def empty_file(self, tmp_path):
        path = str(tmp_path / "empty.scda")
        open(path, "wb").close()
        return path

    @pytest.fixture
    def garbage_file(self, tmp_path):
        path = str(tmp_path / "garbage.scda")
        with open(path, "wb") as f:
            f.write(b"\x89PNG not an scda file " * 20)
        return path

    @pytest.mark.parametrize("cmd", [["ls"], ["index"], ["verify"],
                                     ["cat", "{}", "0"]])
    def test_zero_length_input(self, empty_file, capsys, cmd):
        argv = [a.format(empty_file) if "{}" in a else a for a in cmd]
        if "{}" not in "".join(cmd):
            argv = argv + [empty_file]
        assert main(argv) == 1
        err = capsys.readouterr().err
        assert "scdatool:" in err

    @pytest.mark.parametrize("cmd", [["ls"], ["index"], ["verify"]])
    def test_non_scda_input(self, garbage_file, capsys, cmd):
        assert main(cmd + [garbage_file]) == 1
        assert "scdatool:" in capsys.readouterr().err

    def test_fsck_zero_length_and_garbage(self, empty_file, garbage_file,
                                          capsys):
        assert main(["fsck", empty_file]) == 1
        assert "CORRUPT" in capsys.readouterr().out
        assert main(["fsck", garbage_file]) == 1
        assert "CORRUPT" in capsys.readouterr().out

    def test_missing_file(self, tmp_path, capsys):
        missing = str(tmp_path / "nope.scda")
        for cmd in (["ls"], ["index"], ["verify"]):
            assert main(cmd + [missing]) == 1
            assert "scdatool:" in capsys.readouterr().err


class TestShardedManifestPaths:
    """scdatool accepts a sharded-set manifest path (tentpole CLI
    surface) and names the absent shard when the set is broken."""

    @pytest.fixture
    def sharded(self, tmp_path):
        import numpy as np
        from repro.checkpoint import pytree_io
        path = str(tmp_path / "ck.scda")
        pytree_io.save(path, {"a": np.arange(64, dtype=np.float32),
                              "b": np.ones((10,), np.int32), "lr": 0.5},
                       step=3, shards=2)
        return path

    def test_ls_summarizes_set(self, sharded, capsys):
        assert main(["ls", sharded]) == 0
        out = capsys.readouterr().out
        assert "sharded checkpoint" in out and "of02.scda" in out

    def test_verify_and_fsck_cover_the_set(self, sharded, capsys):
        assert main(["index", "--checksums", sharded]) == 0
        capsys.readouterr()
        assert main(["verify", sharded]) == 0
        out = capsys.readouterr().out
        assert out.count("verified") == 3  # manifest + both shards
        assert main(["fsck", sharded]) == 0
        assert "clean" in capsys.readouterr().out

    def test_missing_shard_named_in_diagnostics(self, sharded, capsys):
        from repro.checkpoint import sharding
        victim = sharding.shard_file(sharded, 1, 2)
        os.remove(victim)
        name = os.path.basename(victim)
        assert main(["fsck", sharded]) == 1
        out = capsys.readouterr().out
        assert "missing shard file" in out and name in out
        assert main(["verify", "--chain", sharded]) == 1
        out = capsys.readouterr().out
        assert "missing shard file" in out and name in out

    def test_truncated_shard_fails_fsck(self, sharded, capsys):
        from repro.checkpoint import sharding
        victim = sharding.shard_file(sharded, 0, 2)
        data = open(victim, "rb").read()
        open(victim, "wb").write(data[: len(data) - 7])
        assert main(["fsck", sharded]) == 1
        assert "CORRUPT" in capsys.readouterr().out
