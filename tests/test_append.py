"""Appendable archives: fopen mode 'a', incremental index refresh, and the
streaming journal subsystem.

The core contract under test is serial equivalence ACROSS the append
boundary: a file produced by write → close → ``fopen_append`` → write →
close must be byte-identical to the same sections written in one serial
session, under any partition P ∈ {1, 2, 4, 8} on either side of the
boundary, raw and §3-compressed alike.  On top of that: tail validation
fails loudly (with exact offsets) on truncated/garbage tails, the
``.scdax`` sidecar refresh is incremental and atomic, and the journal
layer streams telemetry into the same file a checkpoint lives in.
"""
import json
import os
import random

import numpy as np
import pytest

from repro.core import (ScdaError, ScdaErrorCode, ScdaIndex, ScdaWriter,
                        SerialComm, ThreadComm, fopen_append, fopen_read,
                        fopen_write, run_ranks, spec)
from repro.core.reader import ScdaReader
from repro.journal import (JOURNAL_USER_STRING, ScdaJournal, read_records)
from repro.tools.fsck import fsck_file


# --------------------------------------------------------------------------
# Random section scripts (deterministic fuzz without a hypothesis dep)
# --------------------------------------------------------------------------

def _rand_partition(seed, n, P):
    rng = random.Random(repr(seed))
    cuts = sorted(rng.randint(0, n) for _ in range(P - 1))
    return [b - a for a, b in zip([0] + cuts, cuts + [n])]


def _random_sections(rng, n):
    secs = []
    for i in range(n):
        t = rng.choice("IBAV")
        if t == "I":
            secs.append(("I", rng.randbytes(32)))
        elif t == "B":
            secs.append(("B", rng.randbytes(rng.randint(0, 200)),
                         rng.random() < 0.5))
        elif t == "A":
            enc = rng.random() < 0.5
            E = rng.randint(1, 16)
            N = rng.randint(1, 40) if enc else rng.randint(0, 40)
            secs.append(("A", rng.randbytes(N * E), N, E, enc))
        else:
            enc = rng.random() < 0.5
            k = rng.randint(1, 8) if enc else rng.randint(0, 8)
            sizes = [rng.randint(0, 100) for _ in range(k)]
            secs.append(("V", [rng.randbytes(s) for s in sizes], enc))
    return secs


def _emit(f, i, sec):
    """Write one scripted section collectively (any communicator size)."""
    comm, kind = f.comm, sec[0]
    user = b"sec %04d" % i
    if kind == "I":
        f.write_inline(user, sec[1] if comm.rank == 0 else None)
    elif kind == "B":
        f.write_block(user, sec[1] if comm.rank == 0 else None,
                      encode=sec[2])
    elif kind == "A":
        _, data, N, E, enc = sec
        counts = _rand_partition((i, comm.size), N, comm.size)
        off = sum(counts[:comm.rank]) * E
        local = data[off:off + counts[comm.rank] * E]
        f.write_array(user, local, counts, E, encode=enc)
    else:
        _, elements, enc = sec
        counts = _rand_partition((i, comm.size, "v"), len(elements),
                                 comm.size)
        off = sum(counts[:comm.rank])
        local = elements[off:off + counts[comm.rank]]
        f.write_varray(user, local, counts, [len(e) for e in local],
                       encode=enc)


def _write_all(path, secs, comm=None, first=0):
    with fopen_write(comm, path, user_string=b"user",
                     vendor=b"vendor") as f:
        for i, sec in enumerate(secs):
            _emit(f, first + i, sec)


def _parallel(P, path, secs, first, opener):
    def workload(comm):
        with opener(comm, path) as f:
            for i, sec in enumerate(secs):
                _emit(f, first + i, sec)
    run_ranks(ThreadComm.group(P), workload)


# --------------------------------------------------------------------------
# fopen_append — the tentpole
# --------------------------------------------------------------------------

class TestFopenAppend:
    def test_serial_byte_identity(self, tmp_path):
        rng = random.Random(7)
        secs = _random_sections(rng, 8)
        one, two = str(tmp_path / "one.scda"), str(tmp_path / "two.scda")
        _write_all(one, secs)
        _write_all(two, secs[:3])
        with fopen_append(None, two) as f:
            assert f.base_sections == 3
            assert f.base_size == os.path.getsize(two)
            assert (f.version, f.vendor, f.user_string) == \
                (spec.FORMAT_VERSION, b"vendor", b"user")
            for i, sec in enumerate(secs[3:]):
                _emit(f, 3 + i, sec)
        assert open(one, "rb").read() == open(two, "rb").read()

    @pytest.mark.parametrize("P", [1, 2, 4, 8])
    def test_partition_independence_across_boundary(self, tmp_path, P):
        """Fuzzed: prefix written at P ranks, suffix APPENDED at P ranks,
        bytes equal the one-session serial oracle (raw + compressed)."""
        for seed in (11, 23):
            rng = random.Random(seed)
            secs = _random_sections(rng, 6)
            oracle = str(tmp_path / f"oracle_{P}_{seed}.scda")
            grown = str(tmp_path / f"grown_{P}_{seed}.scda")
            _write_all(oracle, secs)
            _parallel(P, grown, secs[:3], 0, fopen_write_user)
            _parallel(P, grown, secs[3:], 3,
                      lambda comm, path: fopen_append(comm, path))
            assert open(oracle, "rb").read() == open(grown, "rb").read(), \
                f"P={P} seed={seed}"

    def test_mixed_partitions_across_boundary(self, tmp_path):
        """The appending partition need not match the writing one."""
        rng = random.Random(3)
        secs = _random_sections(rng, 6)
        oracle = str(tmp_path / "oracle.scda")
        grown = str(tmp_path / "grown.scda")
        _write_all(oracle, secs)
        _parallel(4, grown, secs[:3], 0, fopen_write_user)
        _parallel(2, grown, secs[3:], 3,
                  lambda comm, path: fopen_append(comm, path))
        assert open(oracle, "rb").read() == open(grown, "rb").read()

    def test_multiple_appends(self, tmp_path):
        rng = random.Random(5)
        secs = _random_sections(rng, 9)
        one, two = str(tmp_path / "one.scda"), str(tmp_path / "two.scda")
        _write_all(one, secs)
        _write_all(two, secs[:3])
        for lo in (3, 6):
            with fopen_append(None, two) as f:
                for i, sec in enumerate(secs[lo:lo + 3]):
                    _emit(f, lo + i, sec)
        assert open(one, "rb").read() == open(two, "rb").read()

    def test_append_to_bare_header(self, tmp_path):
        one, two = str(tmp_path / "one.scda"), str(tmp_path / "two.scda")
        secs = [("B", b"payload", False)]
        _write_all(one, secs)
        _write_all(two, [])
        with fopen_append(None, two) as f:
            assert f.base_sections == 0
            _emit(f, 0, secs[0])
        assert open(one, "rb").read() == open(two, "rb").read()

    def test_mime_style_preserved(self, tmp_path):
        one, two = str(tmp_path / "one.scda"), str(tmp_path / "two.scda")
        for path, upto in ((one, 2), (two, 1)):
            with fopen_write(None, path, user_string=b"m",
                             style=spec.MIME) as f:
                for i in range(upto):
                    f.write_block(b"b%d" % i, b"data %d" % i)
        with fopen_append(None, two) as f:
            assert f.style == spec.MIME
            f.write_block(b"b1", b"data 1")
        assert open(one, "rb").read() == open(two, "rb").read()

    def test_save_engine_fast_path_across_boundary(self, tmp_path):
        """Appended sections may ride the overlapped save engine's
        planner + background writeback; bytes still match the oracle."""
        data = os.urandom(1 << 16)
        one, two = str(tmp_path / "one.scda"), str(tmp_path / "two.scda")
        with fopen_write(None, one, user_string=b"user") as f:
            f.write_block(b"head", b"prefix")
            f.write_array_windows(b"leaf", [(0, data)], N=len(data), E=1)
        with fopen_write(None, two, user_string=b"user") as f:
            f.write_block(b"head", b"prefix")
        with fopen_append(None, two) as f:
            frags, f.cursor = f.plan_array_windows(
                b"leaf", [(0, data)], N=len(data), E=1)
            f._backend.submit_write_gather(frags, 1 << 20)
        assert open(one, "rb").read() == open(two, "rb").read()

    # -- tail validation failures -----------------------------------------
    def test_missing_file(self, tmp_path):
        with pytest.raises(ScdaError) as ei:
            fopen_append(None, str(tmp_path / "nope.scda"))
        assert ei.value.code == ScdaErrorCode.FS_OPEN

    def test_bad_magic(self, tmp_path):
        p = str(tmp_path / "bad.scda")
        with open(p, "wb") as fh:
            fh.write(b"NOTSCDA" + b"x" * 121)
        with pytest.raises(ScdaError) as ei:
            fopen_append(None, p)
        assert ei.value.code == ScdaErrorCode.CORRUPT_MAGIC

    def test_truncated_tail(self, tmp_path):
        p = str(tmp_path / "t.scda")
        _write_all(p, [("B", b"x" * 100, False)])
        with open(p, "r+b") as fh:
            fh.truncate(os.path.getsize(p) - 40)
        with pytest.raises(ScdaError) as ei:
            fopen_append(None, p)
        assert ei.value.code == ScdaErrorCode.CORRUPT_TRUNCATED
        assert ei.value.offset is not None

    def test_garbage_tail_exact_offset(self, tmp_path):
        p = str(tmp_path / "g.scda")
        _write_all(p, [("B", b"x" * 100, False)])
        boundary = os.path.getsize(p)
        with open(p, "ab") as fh:
            fh.write(b"\x00garbage past the last section\x00" * 4)
        with pytest.raises(ScdaError) as ei:
            fopen_append(None, p)
        assert ei.value.code.name.startswith("CORRUPT")
        assert ei.value.offset == boundary

    def test_garbage_tail_with_stale_sidecar(self, tmp_path):
        """A sidecar stale against the garbage-grown file must not let the
        garbage through, nor break the loud failure."""
        p = str(tmp_path / "g.scda")
        _write_all(p, [("B", b"x" * 100, False)])
        ScdaIndex.build(p).write_sidecar()
        with open(p, "ab") as fh:
            fh.write(b"!" * 80)
        with pytest.raises(ScdaError):
            fopen_append(None, p)

    def test_recover_truncates_torn_tail(self, tmp_path):
        one, two = str(tmp_path / "one.scda"), str(tmp_path / "two.scda")
        secs = [("B", b"first", False), ("B", b"second", False)]
        _write_all(one, secs)
        _write_all(two, secs[:1])
        with open(two, "ab") as fh:
            fh.write(b"torn partial section write")
        with fopen_append(None, two, recover=True) as f:
            assert f.base_sections == 1
            _emit(f, 1, secs[1])
        assert open(one, "rb").read() == open(two, "rb").read()

    def test_recover_never_eats_the_file_header(self, tmp_path):
        p = str(tmp_path / "hdr.scda")
        with open(p, "wb") as fh:
            fh.write(b"scdata0 truncated-mid-header")
        with pytest.raises(ScdaError):
            fopen_append(None, p, recover=True)

    def test_bad_mode_rejected(self, tmp_path):
        with pytest.raises(ScdaError) as ei:
            ScdaWriter(SerialComm(), str(tmp_path / "x.scda"), mode="r+")
        assert ei.value.code == ScdaErrorCode.ARG_MODE

    # -- sidecar fast path -------------------------------------------------
    def test_sidecar_skips_full_walk(self, tmp_path, monkeypatch):
        p = str(tmp_path / "many.scda")
        _write_all(p, [("B", b"x%d" % i, False) for i in range(20)])
        ScdaIndex.build(p).write_sidecar()
        calls = []
        orig = ScdaReader.read_section_header

        def counting(self, decode=True):
            if self.path == p:  # the sidecar is itself an scda file
                calls.append(1)
            return orig(self, decode)

        monkeypatch.setattr(ScdaReader, "read_section_header", counting)
        with fopen_append(None, p) as f:
            assert f.base_sections == 20
        assert len(calls) == 0  # sidecar: only the last section re-checked
        os.remove(p + ".scdax")
        with fopen_append(None, p) as f:
            assert f.base_sections == 20
        assert len(calls) == 20  # no sidecar: full header walk

    def test_appended_archive_fscks_clean(self, tmp_path):
        p = str(tmp_path / "clean.scda")
        rng = random.Random(1)
        secs = _random_sections(rng, 6)
        _write_all(p, secs[:3])
        with fopen_append(None, p) as f:
            for i, sec in enumerate(secs[3:]):
                _emit(f, 3 + i, sec)
        assert fsck_file(p) == []


def fopen_write_user(comm, path):
    return fopen_write(comm, path, user_string=b"user", vendor=b"vendor")


# --------------------------------------------------------------------------
# ScdaIndex.extend — incremental, atomic sidecar refresh
# --------------------------------------------------------------------------

class TestIndexExtend:
    def _grown(self, tmp_path, n1=3, n2=3):
        p = str(tmp_path / "g.scda")
        rng = random.Random(42)
        secs = _random_sections(rng, n1 + n2)
        _write_all(p, secs[:n1])
        idx = ScdaIndex.build(p)
        with fopen_append(None, p) as f:
            for i, sec in enumerate(secs[n1:]):
                _emit(f, n1 + i, sec)
        return p, idx

    def test_extend_matches_fresh_build(self, tmp_path):
        p, idx = self._grown(tmp_path)
        ext, fresh = idx.extend(), ScdaIndex.build(p)
        assert ext.entries == fresh.entries
        assert ext.file_size == fresh.file_size
        assert ext.entries[:3] == idx.entries  # prefix preserved verbatim

    def test_extend_fresh_is_self(self, tmp_path):
        p, idx = self._grown(tmp_path, n2=0)
        assert idx.staleness() == "fresh"
        assert idx.extend() is idx

    def test_staleness_classification(self, tmp_path):
        p, idx = self._grown(tmp_path)
        assert idx.staleness() == "grew"
        with open(p, "r+b") as fh:
            fh.truncate(idx.file_size - 1)
        assert idx.staleness() == "rewritten"
        os.remove(p)
        assert idx.staleness() == "rewritten"

    def test_extend_after_rewrite_rebuilds(self, tmp_path):
        p, idx = self._grown(tmp_path)
        with fopen_write(None, p, user_string=b"other") as f:
            f.write_block(b"fresh", b"rewritten content")
        ext = idx.extend()
        assert ext.entries == ScdaIndex.build(p).entries
        assert len(ext.entries) == 1

    def test_extend_same_size_grow_with_changed_prefix_rebuilds(
            self, tmp_path):
        """A larger file whose last indexed section no longer matches is a
        rewrite, not a grow — extend must notice via the header check."""
        p, idx = self._grown(tmp_path, n1=2, n2=0)
        size = os.path.getsize(p)
        with fopen_write(None, p, user_string=b"user") as f:
            f.write_block(b"zz", os.urandom(400))  # different, larger
        assert os.path.getsize(p) > size
        ext = idx.extend()
        assert ext.entries == ScdaIndex.build(p).entries

    def test_extend_preserves_checksums_and_adds_new(self, tmp_path):
        p = str(tmp_path / "c.scda")
        _write_all(p, [("B", b"one", False)])
        idx = ScdaIndex.build(p).with_checksums()
        idx.write_sidecar()
        with fopen_append(None, p) as f:
            f.write_block(b"two", b"appended", encode=True)
        refreshed = ScdaIndex.refresh_sidecar(p)
        assert refreshed.has_checksums()
        assert refreshed.entries[0].crc32 == idx.entries[0].crc32
        assert ScdaIndex.load_sidecar(p).verify_checksums() == []

    def test_refresh_sidecar_absent_is_none(self, tmp_path):
        p, _ = self._grown(tmp_path)
        assert ScdaIndex.refresh_sidecar(p) is None
        assert not os.path.exists(p + ".scdax")

    def test_refresh_sidecar_atomic_no_tmp_left(self, tmp_path):
        p, idx = self._grown(tmp_path)
        idx.write_sidecar()  # stale: recorded before the append
        ScdaIndex.refresh_sidecar(p)
        assert not os.path.exists(p + ".scdax.tmp")
        assert ScdaIndex.load_sidecar(p).entries == \
            ScdaIndex.build(p).entries

    def test_cached_takes_suffix_scan(self, tmp_path, monkeypatch):
        p, idx = self._grown(tmp_path, n1=10, n2=2)
        idx.write_sidecar()  # describes only the 10-section prefix
        calls = []
        orig = ScdaReader.read_section_header

        def counting(self, decode=True):
            if self.path == p:  # the sidecar is itself an scda file
                calls.append(1)
            return orig(self, decode)

        monkeypatch.setattr(ScdaReader, "read_section_header", counting)
        got = ScdaIndex.cached(p)
        scanned = len(calls)
        assert got.entries == ScdaIndex.build(p).entries
        assert len(got.entries) == 12
        assert scanned == 2  # only the appended suffix was parsed

    # -- satellite: out-of-band append staleness ---------------------------
    def test_out_of_band_append_fails_loudly_and_extend_recovers(
            self, tmp_path):
        p = str(tmp_path / "oob.scda")
        _write_all(p, [("B", b"base", False)])
        ScdaIndex.build(p).write_sidecar()
        # grow the file WITHOUT refreshing .scdax
        with fopen_append(None, p) as f:
            f.write_block(b"extra", b"out of band")
        with pytest.raises(ScdaError) as ei:
            ScdaIndex.load_sidecar(p)
        assert ei.value.code == ScdaErrorCode.CORRUPT_TRUNCATED
        assert "grew" in str(ei.value)
        stale = ScdaIndex.load_sidecar(p, verify=False)
        recovered = stale.extend()
        assert recovered.entries == ScdaIndex.build(p).entries

    def test_stale_index_never_serves_wrong_bytes(self, tmp_path):
        """Force-adopting a stale sidecar after a REWRITE still fails at
        the per-seek header check (the existing loud-failure contract,
        re-asserted across the new grow/rewrite distinction)."""
        p = str(tmp_path / "rw.scda")
        _write_all(p, [("B", b"base", False)])
        ScdaIndex.build(p).write_sidecar()
        stale = ScdaIndex.load_sidecar(p)
        with fopen_write(None, p, user_string=b"user") as f:
            f.write_varray(b"vvv", [b"abc"], [1], [3])
            f.write_block(b"bbb", b"tail")
        with fopen_read(None, p) as r:
            r.set_index(stale)
            with pytest.raises(ScdaError) as ei:
                r.seek_section(0)
            assert ei.value.code == ScdaErrorCode.CORRUPT_ENCODING


# --------------------------------------------------------------------------
# Journal subsystem
# --------------------------------------------------------------------------

class TestJournal:
    def _archive(self, tmp_path, name="j.scda"):
        p = str(tmp_path / name)
        _write_all(p, [("B", b"payload", False)])
        return p

    def test_log_flush_read_roundtrip(self, tmp_path):
        p = self._archive(tmp_path)
        j = ScdaJournal(p, flush_records=0)
        j.log(1, {"loss": 2.5, "opt": {"lr": 1e-3, "beta": [0.9, 0.999]}})
        j.log(2, {"loss": np.float32(1.25), "n": np.int64(7)})
        assert j.pending == 2
        assert j.flush() == 2
        assert j.pending == 0
        recs = read_records(p)
        assert [r["step"] for r in recs] == [1, 2]
        assert recs[0]["data"] == {"loss": 2.5, "opt/beta/0": 0.9,
                                   "opt/beta/1": 0.999, "opt/lr": 1e-3}
        assert recs[1]["data"] == {"loss": 1.25, "n": 7}

    def test_each_flush_is_one_section(self, tmp_path):
        p = self._archive(tmp_path)
        j = ScdaJournal(p, flush_records=0)
        for batch in ((1, 2), (3,)):
            for s in batch:
                j.log(s, {"v": s})
            j.flush()
        idx = ScdaIndex.build(p)
        journal_secs = [e for e in idx
                        if e.user_string == JOURNAL_USER_STRING]
        assert [e.N for e in journal_secs] == [2, 1]

    def test_autoflush_threshold(self, tmp_path):
        p = self._archive(tmp_path)
        j = ScdaJournal(p, flush_records=3)
        j.log(1, {"a": 1})
        j.log(2, {"a": 2})
        assert read_records(p) == []
        j.log(3, {"a": 3})
        assert len(read_records(p)) == 3 and j.pending == 0

    def test_env_knob(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_SCDA_JOURNAL_FLUSH", "2")
        p = self._archive(tmp_path)
        j = ScdaJournal(p)
        assert j.flush_records == 2
        j.log(1, {"a": 1})
        j.log(2, {"a": 2})
        assert len(read_records(p)) == 2

    def test_no_target_buffers(self, tmp_path):
        j = ScdaJournal(None, flush_records=1)
        j.log(1, {"a": 1})  # would auto-flush if it had a target
        assert j.flush() == 0 and j.pending == 1
        p = self._archive(tmp_path)
        j.retarget(p)
        assert j.flush() == 1
        assert len(read_records(p)) == 1

    def test_non_scalar_rejected(self, tmp_path):
        j = ScdaJournal(self._archive(tmp_path))
        with pytest.raises(ScdaError) as ei:
            j.log(1, {"w": np.zeros(4)})
        assert ei.value.code == ScdaErrorCode.ARG_SEQUENCE

    def test_flush_refreshes_sidecar(self, tmp_path):
        p = self._archive(tmp_path)
        ScdaIndex.build(p).write_sidecar()
        j = ScdaJournal(p, flush_records=0)
        j.log(1, {"a": 1})
        j.flush()
        idx = ScdaIndex.load_sidecar(p)  # would raise if stale
        assert idx.entries[-1].user_string == JOURNAL_USER_STRING

    def test_torn_flush_self_heals(self, tmp_path):
        p = self._archive(tmp_path)
        j = ScdaJournal(p, flush_records=0, update_sidecar=False)
        j.log(1, {"a": 1})
        j.flush()
        with open(p, "r+b") as fh:
            fh.truncate(os.path.getsize(p) - 9)  # tear the flushed section
        j.log(2, {"a": 2})
        j.flush()
        assert [r["step"] for r in read_records(p)] == [2]
        assert fsck_file(p) == []

    def test_recompressed_journal_still_reads(self, tmp_path):
        """`copy --recompress` turns journal sections into zV; records
        must decode transparently, not vanish."""
        from repro.tools.cli import main
        p = self._archive(tmp_path)
        with ScdaJournal(p, flush_records=0) as j:
            j.log(1, {"loss": 0.5})
            j.log(2, {"loss": 0.25})
        z = str(tmp_path / "z.scda")
        assert main(["copy", "--recompress", p, z]) == 0
        assert [r["step"] for r in read_records(z)] == [1, 2]

    def test_concurrent_log_and_flush(self, tmp_path):
        """The manager flushes from its async save thread while training
        keeps logging: no record may be dropped, no flush may tear the
        file (the journal lock serializes appends)."""
        import threading
        p = self._archive(tmp_path)
        j = ScdaJournal(p, flush_records=5, update_sidecar=False)
        per_thread, threads = 40, 4

        def hammer(tid):
            for k in range(per_thread):
                j.log(tid * per_thread + k, {"t": tid})

        ts = [threading.Thread(target=hammer, args=(t,))
              for t in range(threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        j.flush()
        recs = read_records(p)
        assert len(recs) == per_thread * threads
        assert sorted(r["step"] for r in recs) == \
            list(range(per_thread * threads))
        assert fsck_file(p) == []

    def test_journaled_archive_fsck_clean(self, tmp_path):
        p = self._archive(tmp_path)
        with ScdaJournal(p, flush_records=2) as j:
            for s in range(5):
                j.log(s, {"loss": 1.0 / (s + 1)})
        assert len(read_records(p)) == 5  # context exit flushed the tail
        assert fsck_file(p) == []


class TestManagerJournal:
    def test_flush_on_commit(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = {"w": np.arange(8, dtype=np.float32)}
        j = mgr.journal()
        j.log(1, {"loss": 2.0})
        j.log(2, {"loss": 1.0})
        assert j.pending == 2  # no committed file yet: records buffer
        mgr.save(2, tree, blocking=True)
        assert j.pending == 0
        recs = read_records(mgr.path_for(2))
        assert [r["step"] for r in recs] == [1, 2]
        # telemetry follows the NEXT commit into the new file
        j.log(3, {"loss": 0.5})
        mgr.save(4, tree, blocking=True)
        assert [r["step"] for r in read_records(mgr.path_for(4))] == [3]
        # the journaled checkpoints still restore + fsck + seek cleanly
        out, step = mgr.restore_latest()
        np.testing.assert_array_equal(out["w"], tree["w"])
        assert step == 4
        assert fsck_file(mgr.path_for(4)) == []
        ScdaIndex.load_sidecar(mgr.path_for(4))  # sidecar kept fresh

    def test_non_root_journal_is_inert(self, tmp_path):
        """Replicated training code logs on every rank; only rank 0's
        journal may buffer or append (no double records, no unbounded
        non-root buffers)."""
        from repro.checkpoint import CheckpointManager
        P = 2
        comms = ThreadComm.group(P)

        def workload(comm):
            mgr = CheckpointManager(str(tmp_path), keep=3, comm=comm)
            j = mgr.journal()
            j.log(1, {"loss": 2.0})  # every rank logs the replicated value
            assert j.pending == (1 if comm.rank == 0 else 0)
            mgr.save(1, {"w": np.ones(8, np.float32)}, blocking=True)
            assert j.pending == 0
            return mgr.path_for(1)

        paths = run_ranks(comms, workload)
        recs = read_records(paths[0])
        assert [r["step"] for r in recs] == [1]  # exactly once

    def test_journal_binds_to_latest_existing(self, tmp_path):
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(str(tmp_path), keep=3)
        mgr.save(7, {"w": np.ones(4, np.float32)}, blocking=True)
        mgr2 = CheckpointManager(str(tmp_path), keep=3)  # fresh process
        j = mgr2.journal()
        j.log(8, {"loss": 0.1})
        j.flush()
        assert [r["step"] for r in read_records(mgr2.path_for(7))] == [8]


# --------------------------------------------------------------------------
# scdatool append / tail + fsck exact offsets
# --------------------------------------------------------------------------

class TestCliAppendTail:
    def _two_archives(self, tmp_path):
        from repro.tools.cli import main
        a, b = str(tmp_path / "a.scda"), str(tmp_path / "b.scda")
        rng = random.Random(9)
        _write_all(a, _random_sections(rng, 3))
        _write_all(b, _random_sections(rng, 4))
        return main, a, b

    def test_append_then_fsck_verify(self, tmp_path, capsys):
        main, a, b = self._two_archives(tmp_path)
        ScdaIndex.build(a).with_checksums().write_sidecar()
        assert main(["append", a, b]) == 0
        out = capsys.readouterr().out
        assert "appended 4 sections" in out and "3 -> 7" in out
        assert main(["fsck", a]) == 0
        assert main(["verify", a]) == 0  # incremental CRCs cover the suffix
        assert len(ScdaIndex.load_sidecar(a).entries) == 7

    def test_append_no_sidecar_stays_sidecarless(self, tmp_path, capsys):
        main, a, b = self._two_archives(tmp_path)
        assert main(["append", a, b]) == 0
        assert not os.path.exists(a + ".scdax")
        assert main(["append", "--index", a, b]) == 0
        assert os.path.exists(a + ".scdax")
        assert main(["fsck", a]) == 0

    def test_append_matches_serial_copy(self, tmp_path):
        """append == copy of the concatenation, leaf-wise."""
        from repro.tools.cli import main
        rng = random.Random(13)
        s1, s2 = _random_sections(rng, 2), _random_sections(rng, 2)
        a = str(tmp_path / "a.scda")
        oracle = str(tmp_path / "oracle.scda")
        _write_all(a, s1)
        # The pump preserves SRC's own user strings, so the oracle numbers
        # each script from 0 (not consecutively across the two).
        with fopen_write(None, oracle, user_string=b"user",
                         vendor=b"vendor") as f:
            for i, sec in enumerate(s1):
                _emit(f, i, sec)
            for i, sec in enumerate(s2):
                _emit(f, i, sec)
        src = str(tmp_path / "src.scda")
        _write_all(src, s2)
        assert main(["append", a, src]) == 0
        assert main(["diff", a, oracle]) == 0

    def test_tail_prints_json_lines(self, tmp_path, capsys):
        from repro.tools.cli import main
        p = str(tmp_path / "t.scda")
        _write_all(p, [("B", b"x", False)])
        with ScdaJournal(p, flush_records=0) as j:
            j.log(1, {"loss": 0.5})
            j.log(2, {"loss": 0.25})
        assert main(["tail", p]) == 0
        lines = [json.loads(ln) for ln
                 in capsys.readouterr().out.strip().splitlines()]
        assert [r["step"] for r in lines] == [1, 2]
        assert lines[1]["data"]["loss"] == 0.25

    def test_tail_without_journal(self, tmp_path, capsys):
        from repro.tools.cli import main
        p = str(tmp_path / "nj.scda")
        _write_all(p, [("B", b"x", False)])
        assert main(["tail", p]) == 0
        assert capsys.readouterr().out.strip() == ""

    def test_append_recover_flag(self, tmp_path, capsys):
        from repro.tools.cli import main
        a = str(tmp_path / "a.scda")
        src = str(tmp_path / "s.scda")
        secs = [("B", b"one", False)]
        _write_all(a, secs)
        _write_all(src, secs)
        with open(a, "ab") as fh:
            fh.write(b"torn")
        assert main(["append", a, src]) == 1  # refuses by default
        assert main(["append", "--recover", a, src]) == 0
        assert main(["fsck", a]) == 0


class TestFsckExactOffset:
    def _base(self, tmp_path):
        p = str(tmp_path / "f.scda")
        _write_all(p, [("B", b"valid payload", False)])
        return p, os.path.getsize(p)

    def test_short_garbage_offset_is_eof(self, tmp_path):
        p, boundary = self._base(tmp_path)
        with open(p, "ab") as fh:
            fh.write(b"short!")
        f = fsck_file(p)
        assert f and f[0].severity == "error"
        assert f[0].offset == boundary + 6  # EOF mid-header read
        assert "validation failed at byte" in f[0].message

    def test_garbage_header_offset_is_boundary(self, tmp_path):
        p, boundary = self._base(tmp_path)
        with open(p, "ab") as fh:
            fh.write(b"\x00" * 64)
        f = fsck_file(p)
        assert f and f[0].offset == boundary

    def test_plausible_header_bad_entry_offset_is_entry(self, tmp_path):
        """Garbage that parses as an A header but carries a malformed
        count entry anchors at the ENTRY, not the section start."""
        p, boundary = self._base(tmp_path)
        with open(p, "ab") as fh:
            fh.write(spec.section_header(b"A", b"fake"))
            fh.write(b"N zz" + b"-" * 27 + b"\n")
        f = fsck_file(p)
        assert f and f[0].offset == boundary + spec.SECTION_HEADER_BYTES
        assert str(boundary + 64) in f[0].message

    def test_truncated_payload_offset_is_file_end(self, tmp_path):
        p = str(tmp_path / "trunc.scda")
        _write_all(p, [("A", os.urandom(4096), 4096, 1, False)])
        size = os.path.getsize(p) - 100
        with open(p, "r+b") as fh:
            fh.truncate(size)
        f = fsck_file(p)
        assert f and f[0].offset == size
