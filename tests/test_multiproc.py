"""Parity under real spawned processes — the multiproc CI lane.

Everything the thread-rank suites prove (serial-equivalent bytes under P
concurrent writers, reader-side partition freedom) re-proven with
``multiprocessing`` spawn workers: separate interpreters, separate file
descriptors, collectives over queues — the closest a test gets to MPI
ranks without MPI.  Marked ``multiproc`` and excluded from the default
run (``pytest -m multiproc`` selects it; CI gives it its own job).
"""
import hashlib
import os
import sys

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "helpers"))

from mp_comm import run_mp_ranks  # noqa: E402

pytestmark = pytest.mark.multiproc

PS = [2, 4, 8]


def _file_sha(path):
    return hashlib.sha256(open(path, "rb").read()).hexdigest()


def _tree(seed=0):
    """Deterministic pytree — parent and every spawned rank rebuild the
    identical (replicated) state, as data-parallel training would."""
    rng = np.random.default_rng(1234 + seed)
    return {
        "w": rng.standard_normal((64, 33)).astype(np.float32),
        "b": rng.standard_normal((257,)).astype(np.float64),
        "m": rng.integers(0, 200, (31, 5, 7)).astype(np.int32),
        "empty": np.zeros((0, 4), np.float32),
        "scalar": np.float32(2.5),
        "lr": 0.125,
    }


# -- workers (module-level: spawn pickles them by reference) -----------------

def _w_core_array(comm, path, payload_hex, counts, E):
    """Core-level collective write: each rank writes its slice."""
    from repro.core import fopen_write, partition
    data = bytes.fromhex(payload_hex)
    offs = partition.offsets(counts)
    lo, hi = offs[comm.rank] * E, offs[comm.rank + 1] * E
    with fopen_write(comm, path, b"user", b"vendor") as f:
        f.write_array(b"arr", data[lo:hi], counts, E)


def _w_ckpt_save(comm, path, seed, shards):
    from repro.checkpoint import pytree_io
    pytree_io.save(path, _tree(seed), step=seed, comm=comm, shards=shards)


def _w_ckpt_restore(comm, path, seed):
    from repro.checkpoint import pytree_io
    expect = _tree(seed)
    got, step = pytree_io.restore(path, comm=comm)
    ok = step == seed and got["lr"] == expect["lr"]
    for k in ("w", "b", "m", "empty", "scalar"):
        ok = ok and np.array_equal(np.asarray(got[k]), np.asarray(expect[k]))
    leaf = pytree_io.restore_leaf(path, "b", comm=comm)
    ok = ok and np.array_equal(np.asarray(leaf), expect["b"])
    return bool(ok)


# -- tests -------------------------------------------------------------------

@pytest.mark.parametrize("P", PS)
def test_core_array_write_parity(tmp_path, P):
    """P real processes pwriting one shared array section == serial."""
    from repro.core import encode
    N, E = 24, 16
    data = os.urandom(N * E)
    counts = [N // P] * P
    counts[-1] += N - sum(counts)
    oracle = encode.encode_file(b"vendor", b"user", [
        encode.encode_array(b"arr", data, N, E)])
    path = str(tmp_path / "mp_core.scda")
    run_mp_ranks(_w_core_array, P,
                 args=(path, data.hex(), counts, E))
    assert open(path, "rb").read() == oracle


@pytest.mark.parametrize("P", PS)
def test_checkpoint_save_parity_flat(tmp_path, P):
    """A collective P-process checkpoint save == the serial oracle."""
    from repro.checkpoint import pytree_io
    oracle = str(tmp_path / "oracle.scda")
    pytree_io.save(oracle, _tree(7), step=7, shards=0)
    path = str(tmp_path / "mp.scda")
    run_mp_ranks(_w_ckpt_save, P, args=(path, 7, 0))
    assert _file_sha(path) == _file_sha(oracle)


@pytest.mark.parametrize("P", PS)
def test_checkpoint_save_parity_sharded(tmp_path, P):
    """P-process sharded save: every shard AND the manifest byte-equal
    to the single-process write of the same set.  Same basename in two
    directories — the manifest embeds the shard file names it derives
    from its own stem, so the stems must match for byte identity."""
    from repro.checkpoint import pytree_io, sharding
    (tmp_path / "serial").mkdir()
    (tmp_path / "mp").mkdir()
    oracle = str(tmp_path / "serial" / "ck.scda")
    pytree_io.save(oracle, _tree(9), step=9, shards=2)
    path = str(tmp_path / "mp" / "ck.scda")
    run_mp_ranks(_w_ckpt_save, P, args=(path, 9, 2))
    for o, m in zip(sharding.set_paths(oracle, 2),
                    sharding.set_paths(path, 2)):
        assert _file_sha(m) == _file_sha(o), (o, m)


@pytest.mark.parametrize("P", PS)
def test_restore_under_process_ranks(tmp_path, P):
    """Readers use any process count regardless of the writer's: a
    2-shard set written serially restores correctly on P real ranks."""
    from repro.checkpoint import pytree_io
    path = str(tmp_path / "ck.scda")
    pytree_io.save(path, _tree(3), step=3, shards=2)
    assert run_mp_ranks(_w_ckpt_restore, P,
                        args=(path, 3)) == [True] * P
