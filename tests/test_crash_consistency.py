"""Power-cut replay over recorded commits (the crash-consistency harness).

Records the complete op log of one ``CheckpointManager.save()`` — every
write, fsync, rename, and directory fsync — then re-materializes crash
states (``tests/helpers/crashsim.py``) and asserts the two halves of the
durability contract:

1. **Any crash prefix, any legal reordering**: ``restore_latest()``
   returns the *previous* checkpoint or the *new* one, bit-for-bit —
   never an error, never wrong tensors.

2. **The complete op log**: once ``save()`` returned, the new checkpoint
   must be the restore result under EVERY volatile choice — dropping all
   un-fsynced effects included.  This is the assertion that catches a
   missing directory fsync: without it the commit rename itself is
   volatile and a power cut "un-commits" a save that reported success.

The quick (PR) lane replays a bounded, deterministic prefix sample that
always includes the commit-critical boundaries; set
``REPRO_CRASH_EXHAUSTIVE=1`` (the nightly lane) to replay every prefix.
"""
import os
import sys

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "helpers"))
import crashsim  # noqa: E402

EXHAUSTIVE = os.environ.get("REPRO_CRASH_EXHAUSTIVE", "") == "1"
#: quick-lane bounds (nightly replays everything)
QUICK_PREFIXES = 14
QUICK_VARIANTS = 1 if not EXHAUSTIVE else 2


def _tree(seed: int):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((17, 5)).astype(np.float32),
        "b": rng.standard_normal((5,)).astype(np.float32),
        "step": np.array(seed, dtype=np.int64),
    }


def _assert_tree_equal(got, want, ctx: str) -> None:
    assert set(got) == set(want), ctx
    for k in want:
        assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), \
            f"{ctx}: leaf {k!r} differs"


def _check_invariant(rec, directory, prev, new, mgr_kwargs) -> None:
    """Replay crash states of ``rec`` and assert both contract halves."""
    prev_step, prev_tree = prev
    new_step, new_tree = new
    prefixes = None if EXHAUSTIVE else \
        crashsim.sampled_prefixes(rec, QUICK_PREFIXES, seed=7)
    try:
        for k, variant, files in crashsim.iter_crash_states(
                rec, seed=11, prefixes=prefixes, variants=QUICK_VARIANTS):
            crashsim.materialize(directory, files)
            ctx = f"prefix {k}/{len(rec.ops)} variant {variant}"
            mgr = CheckpointManager(directory, **mgr_kwargs)
            out, step = mgr.restore_latest()
            assert step in (prev_step, new_step), \
                f"{ctx}: restored step {step}"
            _assert_tree_equal(out, prev_tree if step == prev_step
                               else new_tree, ctx)
            if k == len(rec.ops):
                # Contract half 2: a completed save() IS durable.
                assert step == new_step, \
                    f"{ctx}: complete commit rolled back to step {step}"
    finally:
        crashsim.materialize(directory, rec.final)


@pytest.fixture(autouse=True)
def _serial_write_path(monkeypatch):
    """Serial writes keep op logs small and schedules reproducible; the
    pipelined path's faults are covered by test_faults.py."""
    monkeypatch.setenv("REPRO_SCDA_WRITE_PIPELINE", "0")
    monkeypatch.delenv("REPRO_SCDA_FAULTS", raising=False)


def test_powercut_replay_flat(tmp_path):
    d = str(tmp_path / "ckpts")
    mgr = CheckpointManager(d, keep=4, shards=0, delta=False)
    mgr.save(1, _tree(1), blocking=True)
    rec = crashsim.record_commit(
        d, lambda: mgr.save(2, _tree(2), blocking=True))
    assert len(rec.ops) > 0 and any(o.op == "fsync_dir" for o in rec.ops)
    _check_invariant(rec, d, (1, _tree(1)), (2, _tree(2)),
                     dict(keep=4, shards=0, delta=False))


def test_powercut_replay_sharded(tmp_path):
    d = str(tmp_path / "ckpts")
    mgr = CheckpointManager(d, keep=4, shards=4, delta=False)
    mgr.save(1, _tree(1), blocking=True)
    rec = crashsim.record_commit(
        d, lambda: mgr.save(2, _tree(2), blocking=True))
    # shards rename before the manifest; both renames are dir-fsynced
    renames = [o for o in rec.ops if o.op == "replace"]
    assert len(renames) >= 5  # 4 shards + manifest
    _check_invariant(rec, d, (1, _tree(1)), (2, _tree(2)),
                     dict(keep=4, shards=4, delta=False))


def test_powercut_replay_sharded_parity(tmp_path):
    """An erasure-coded set commits atomically: shards AND parity rename
    before the manifest, so every crash prefix restores prev-or-new and
    a completed save is durable with its parity rows intact."""
    d = str(tmp_path / "ckpts")
    kw = dict(keep=4, shards=2, parity=1, delta=False)
    mgr = CheckpointManager(d, **kw)
    mgr.save(1, _tree(1), blocking=True)
    rec = crashsim.record_commit(
        d, lambda: mgr.save(2, _tree(2), blocking=True))
    renames = [o for o in rec.ops if o.op == "replace"]
    assert len(renames) >= 4  # 2 shards + 1 parity + manifest
    assert any("-p00of01" in (o.dst or "") for o in renames)
    _check_invariant(rec, d, (1, _tree(1)), (2, _tree(2)), kw)


def test_powercut_replay_delta_depth2(tmp_path):
    d = str(tmp_path / "ckpts")
    kw = dict(keep=6, shards=0, delta=True, delta_chain=4)
    mgr = CheckpointManager(d, **kw)
    mgr.save(1, _tree(1), blocking=True)            # full base
    mgr.save(2, _tree(2), blocking=True)            # delta depth 1
    rec = crashsim.record_commit(
        d, lambda: mgr.save(3, _tree(3), blocking=True))  # delta depth 2
    _check_invariant(rec, d, (2, _tree(2)), (3, _tree(3)), kw)


def test_powercut_replay_journal_append(tmp_path):
    """Journal flush-on-commit appends (sync=False) AFTER the commit
    point: a torn/dropped journal tail must never demote the committed
    checkpoint (tolerant prefix indexing + sidecar staleness)."""
    d = str(tmp_path / "ckpts")
    kw = dict(keep=4, shards=0, delta=False)
    mgr = CheckpointManager(d, **kw)
    mgr.save(1, _tree(1), blocking=True)
    j = mgr.journal()
    for s in range(5):
        j.log(s, {"loss": 1.0 / (s + 1)})

    def commit():
        mgr.save(2, _tree(2), blocking=True)

    rec = crashsim.record_commit(d, commit)
    # the journal append targets the committed file, after its rename
    names = [o.op for o in rec.ops]
    assert "replace" in names
    _check_invariant(rec, d, (1, _tree(1)), (2, _tree(2)), kw)


def test_stale_sidecar_never_trusted(tmp_path):
    """A crash can durably commit a sidecar describing bytes that were
    rolled back; every such stale index must be detected and ignored."""
    d = str(tmp_path / "ckpts")
    mgr = CheckpointManager(d, keep=4, shards=0, delta=False)
    mgr.save(1, _tree(1), blocking=True)
    rec = crashsim.record_commit(
        d, lambda: mgr.save(2, _tree(2), blocking=True))
    try:
        # Worst case for the sidecar: keep every sidecar byte, drop the
        # volatile remainder at each commit-critical boundary.
        for k in crashsim.sampled_prefixes(rec, 6, seed=3):
            files = crashsim.crash_state(rec, k, drop_all_volatile=True)
            full = crashsim.crash_state(rec, len(rec.ops))
            for p, data in full.items():
                if p.endswith(".scdax"):
                    files[p] = data  # sidecar "survived" regardless
            crashsim.materialize(d, files)
            out, step = CheckpointManager(d, keep=4, shards=0,
                                          delta=False).restore_latest()
            assert step in (1, 2)
            _assert_tree_equal(out, _tree(step), f"prefix {k}")
    finally:
        crashsim.materialize(d, rec.final)
