"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode,
plus hypothesis property tests and agreement with the model's XLA path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.ssm_scan import ssm_scan_kernel


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,H,Hkv,Sq,Skv,D", [
        (1, 1, 1, 8, 8, 4),
        (2, 4, 2, 16, 16, 8),       # GQA
        (1, 4, 4, 24, 16, 8),       # Sq != Skv (unaligned to blocks)
        (2, 8, 2, 8, 32, 16),       # long kv, group 4
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep(self, B, H, Hkv, Sq, Skv, D, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (B, H, Sq, D), dtype)
        k = rand(ks[1], (B, Hkv, Skv, D), dtype)
        v = rand(ks[2], (B, Hkv, Skv, D), dtype)
        out = flash_attention_kernel(q, k, v, causal=True, block_q=8,
                                     block_k=8, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_non_causal(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = rand(ks[0], (1, 2, 16, 8), jnp.float32)
        k = rand(ks[1], (1, 2, 16, 8), jnp.float32)
        v = rand(ks[2], (1, 2, 16, 8), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=False, block_q=8,
                                     block_k=8, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_sliding_window(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = rand(ks[0], (1, 2, 32, 8), jnp.float32)
        k = rand(ks[1], (1, 2, 32, 8), jnp.float32)
        v = rand(ks[2], (1, 2, 32, 8), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=True, window=4,
                                     block_q=8, block_k=8, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_block_shape_independence(self):
        """Different VMEM tilings must give identical results."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = rand(ks[0], (1, 2, 32, 8), jnp.float32)
        k = rand(ks[1], (1, 2, 32, 8), jnp.float32)
        v = rand(ks[2], (1, 2, 32, 8), jnp.float32)
        outs = [flash_attention_kernel(q, k, v, block_q=bq, block_k=bk,
                                       interpret=True)
                for bq, bk in ((8, 8), (16, 8), (8, 16), (32, 32))]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       rtol=1e-5, atol=1e-5)

    def test_matches_model_attention_path(self):
        """Kernel ≡ the model's XLA chunked-flash (layers.flash_attention)."""
        from repro.models.layers import flash_attention as xla_flash
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        B, H, Hkv, S, D = 2, 4, 2, 16, 8
        q = rand(ks[0], (B, H, S, D), jnp.float32)
        k = rand(ks[1], (B, Hkv, S, D), jnp.float32)
        v = rand(ks[2], (B, Hkv, S, D), jnp.float32)
        out_kernel = flash_attention_kernel(q, k, v, block_q=8, block_k=8,
                                            interpret=True)
        # model path uses (B, S, H, D) layout
        out_xla = xla_flash(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), kv_chunk=8)
        np.testing.assert_allclose(np.asarray(out_kernel),
                                   np.asarray(out_xla.transpose(0, 2, 1, 3)),
                                   rtol=2e-5, atol=2e-5)

    @given(st.integers(1, 3), st.integers(0, 2), st.integers(1, 4),
           st.booleans())
    @settings(max_examples=10, deadline=None)
    def test_property_random_geometry(self, b, hkv_pow, sq_blocks, causal):
        hkv = 2 ** hkv_pow
        h = hkv * 2
        sq = 8 * sq_blocks
        ks = jax.random.split(jax.random.PRNGKey(b * 7 + sq), 3)
        q = rand(ks[0], (b, h, sq, 8), jnp.float32)
        k = rand(ks[1], (b, hkv, 16, 8), jnp.float32)
        v = rand(ks[2], (b, hkv, 16, 8), jnp.float32)
        out = flash_attention_kernel(q, k, v, causal=causal, block_q=8,
                                     block_k=8, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)


class TestSsmScanKernel:
    @pytest.mark.parametrize("B,S,d,N,chunk,dblk", [
        (1, 8, 4, 2, 4, 4),
        (2, 16, 8, 4, 8, 4),
        (1, 32, 16, 8, 8, 8),
        (2, 24, 6, 3, 8, 6),
    ])
    def test_shape_sweep(self, B, S, d, N, chunk, dblk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        decay = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, d, N)))
        inc = jax.random.normal(ks[1], (B, S, d, N)) * 0.1
        C = jax.random.normal(ks[2], (B, S, N))
        out = ssm_scan_kernel(decay, inc, C, chunk=chunk, d_block=dblk,
                              interpret=True)
        want = ref.ssm_scan_ref(decay, inc, C)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_chunk_independence(self):
        """State carried across chunk boundaries must be exact."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        decay = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 32, 4, 4)))
        inc = jax.random.normal(ks[1], (1, 32, 4, 4)) * 0.1
        C = jax.random.normal(ks[2], (1, 32, 4))
        outs = [ssm_scan_kernel(decay, inc, C, chunk=c, d_block=4,
                                interpret=True) for c in (4, 8, 16, 32)]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                       rtol=1e-6, atol=1e-6)

    def test_bf16_inputs_f32_state(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        decay = jax.nn.sigmoid(jax.random.normal(ks[0], (1, 16, 4, 2))
                               ).astype(jnp.bfloat16)
        inc = (jax.random.normal(ks[1], (1, 16, 4, 2)) * 0.1
               ).astype(jnp.bfloat16)
        C = jax.random.normal(ks[2], (1, 16, 2)).astype(jnp.bfloat16)
        out = ssm_scan_kernel(decay, inc, C, chunk=8, d_block=4,
                              interpret=True)
        want = ref.ssm_scan_ref(decay, inc, C)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_matches_model_mamba_core(self):
        """Kernel recurrence ≡ the model's chunked associative scan."""
        from repro.models.ssm import _chunked_diag_scan
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        B, S, d, N = 2, 16, 4, 4
        decay = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, d, N)))
        inc = jax.random.normal(ks[1], (B, S, d, N)) * 0.1
        C = jax.random.normal(ks[2], (B, S, N))
        h0 = jnp.zeros((B, d, N))
        hs, _ = _chunked_diag_scan(decay, inc, h0, chunk=8)
        want = jnp.einsum("bsdn,bsn->bsd", hs, C)
        out = ssm_scan_kernel(decay, inc, C, chunk=8, d_block=4,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @given(st.integers(1, 2), st.integers(1, 3), st.integers(1, 2))
    @settings(max_examples=8, deadline=None)
    def test_property_random_geometry(self, b, s_chunks, n_pow):
        S, N = 8 * s_chunks, 2 ** n_pow
        ks = jax.random.split(jax.random.PRNGKey(b + S + N), 3)
        decay = jax.nn.sigmoid(jax.random.normal(ks[0], (b, S, 4, N)))
        inc = jax.random.normal(ks[1], (b, S, 4, N)) * 0.2
        C = jax.random.normal(ks[2], (b, S, N))
        out = ssm_scan_kernel(decay, inc, C, chunk=8, d_block=4,
                              interpret=True)
        want = ref.ssm_scan_ref(decay, inc, C)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
