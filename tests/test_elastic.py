"""Mesh-elastic checkpoint/restore across device topologies (subprocess:
needs its own XLA device count, which must not leak into other tests)."""
import os
import subprocess
import sys

HELPER = os.path.join(os.path.dirname(__file__), "helpers",
                      "elastic_roundtrip.py")
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_elastic_mesh_roundtrip(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    out = subprocess.run(
        [sys.executable, HELPER, str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "OK elastic" in out.stdout
