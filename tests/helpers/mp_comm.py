"""Real-process communicator for the multiproc CI lane.

:class:`ThreadComm` proves partition independence with P concurrent ranks
in one process; this helper removes the last simulation: P **spawned**
OS processes (no shared interpreter state, no inherited file descriptors
— ``spawn``, not ``fork``, so the children look like genuinely separate
MPI ranks and the suite behaves identically on platforms without fork)
coordinating only through the scda collective interface, each pwriting
its own windows of one shared file.

:class:`MPComm` implements :class:`repro.core.comm.Communicator` over a
``multiprocessing`` barrier plus one inbox queue per rank.  Collectives
are sequence-numbered: every message carries ``(seq, sender, value)`` and
receivers buffer out-of-order arrivals, so back-to-back collectives from
ranks running at different speeds can never cross-talk.

:func:`run_mp_ranks` is the driver: it spawns P workers, runs
``target(comm, *args)`` on each, and returns the per-rank results in rank
order — the process analogue of :func:`repro.core.comm.run_ranks`.  The
target must be a module-level function (spawn pickles it by reference)
and its result must be picklable; return digests or booleans, not arrays.
"""
from __future__ import annotations

import multiprocessing as mp
import queue as _queue
import time
import traceback
from typing import Any, Callable, Dict, List, Tuple

from repro.core.comm import Communicator

#: Per-collective timeout (seconds).  Generous: CI machines stall, but a
#: deadlocked collective must fail the test instead of hanging the job.
OP_TIMEOUT = 120.0


class MPComm(Communicator):
    """One rank of a P-process group (see module docstring)."""

    def __init__(self, rank: int, size: int, barrier, inboxes) -> None:
        self.rank, self.size = rank, size
        self._barrier = barrier
        self._inboxes = inboxes      # one mp.Queue per rank, inboxes[r]
        self._seq = 0                # collective counter (lock-step by
        self._buf: Dict[Tuple[int, int], Any] = {}  # construction)

    def barrier(self) -> None:
        self._barrier.wait(timeout=OP_TIMEOUT)

    def _recv(self, seq: int, src: int) -> Any:
        key = (seq, src)
        deadline = time.monotonic() + OP_TIMEOUT
        while key not in self._buf:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"rank {self.rank}: no message {key} within "
                    f"{OP_TIMEOUT}s")
            s, r, v = self._inboxes[self.rank].get(timeout=remaining)
            self._buf[(s, r)] = v
        return self._buf.pop(key)

    def bcast(self, value: Any, root: int = 0) -> Any:
        seq = self._seq
        self._seq += 1
        if self.rank == root:
            for dst in range(self.size):
                if dst != root:
                    self._inboxes[dst].put((seq, root, value))
            return value
        return self._recv(seq, root)

    def allgather(self, value: Any) -> List[Any]:
        seq = self._seq
        self._seq += 1
        for dst in range(self.size):
            if dst != self.rank:
                self._inboxes[dst].put((seq, self.rank, value))
        return [value if src == self.rank else self._recv(seq, src)
                for src in range(self.size)]


def _entry(target: Callable, rank: int, size: int, barrier, inboxes,
           result_q, args: tuple) -> None:
    comm = MPComm(rank, size, barrier, inboxes)
    try:
        result_q.put((rank, True, target(comm, *args)))
    except BaseException as e:  # noqa: BLE001 - reported to the parent
        try:
            barrier.abort()  # free siblings blocked on a collective
        except Exception:
            pass
        result_q.put((rank, False,
                      f"rank {rank}: {type(e).__name__}: {e}\n"
                      f"{traceback.format_exc()}"))


def run_mp_ranks(target: Callable, size: int, *, args: tuple = (),
                 timeout: float = 300.0) -> List[Any]:
    """Run ``target(comm, *args)`` on ``size`` spawned processes.

    Returns per-rank results in rank order; raises with the failing
    rank's traceback text if any rank errored, and terminates the group
    on timeout or a silently dead child (never leaves orphans behind).
    """
    ctx = mp.get_context("spawn")
    barrier = ctx.Barrier(size)
    inboxes = [ctx.Queue() for _ in range(size)]
    result_q = ctx.Queue()
    procs = [ctx.Process(target=_entry, daemon=True,
                         args=(target, r, size, barrier, inboxes,
                               result_q, args))
             for r in range(size)]
    for p in procs:
        p.start()
    results: Dict[int, Tuple[bool, Any]] = {}
    deadline = time.monotonic() + timeout
    try:
        while len(results) < size:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"{size - len(results)} of {size} ranks still "
                    f"running after {timeout}s")
            try:
                rank, ok, payload = result_q.get(
                    timeout=min(1.0, remaining))
            except _queue.Empty:
                dead = [p for r, p in enumerate(procs)
                        if r not in results and p.exitcode not in (None, 0)]
                if dead:
                    raise RuntimeError(
                        f"{len(dead)} rank(s) died without reporting "
                        f"(exit codes {[p.exitcode for p in dead]})")
                continue
            results[rank] = (ok, payload)
    finally:
        for p in procs:
            p.join(timeout=10.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=10.0)
    failures = [payload for ok, payload in results.values() if not ok]
    if failures:
        raise RuntimeError("multiproc rank failure:\n"
                           + "\n".join(failures))
    return [results[r][1] for r in range(size)]
