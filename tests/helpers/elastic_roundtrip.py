"""Subprocess helper: prove mesh-elastic checkpointing on 8 devices.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (set by the
calling test BEFORE jax import).  Exercises:
  1. save under mesh (4 data, 2 model)  → file bytes F1
  2. save the same logical state under mesh (2, 4) → F2; (8, 1) → F3
     — all three must be byte-identical (partition-independence for
     sharded jax.Arrays).
  3. restore F1 under (2, 4), (8, 1), (1, 1) and fully-replicated —
     values must match exactly (elastic restart), with the overlapped
     restore engine (prefetch on, the default) and the serial oracle
     (prefetch_bytes=0) agreeing on every re-partitioned restore.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.checkpoint import restore, save  # noqa: E402


def make_state(mesh):
    """A small train-state-like pytree, sharded over the mesh."""
    def put(value, spec):
        return jax.device_put(value, NamedSharding(mesh, spec))

    k = jax.random.PRNGKey(7)
    w = jax.random.normal(k, (16, 32), jnp.float32)
    e = jax.random.normal(jax.random.PRNGKey(8), (64, 8), jnp.bfloat16)
    mu = jnp.arange(16 * 32, dtype=jnp.float32).reshape(16, 32) / 512.0
    return {
        "params": {
            "w": put(w, P("data", "model")),       # 2-D sharded
            "embed": put(e, P("model", None)),     # 1-D sharded
        },
        "opt": {
            "mu": put(mu, P(None, "data")),        # trailing-axis sharded
            "count": put(jnp.array(3, jnp.int32), P()),  # replicated
        },
    }


def mesh_of(shape):
    devs = np.array(jax.devices()[: shape[0] * shape[1]]).reshape(shape)
    return Mesh(devs, ("data", "model"))


def abstract_like(state, mesh, specs):
    def _like(path_value, spec):
        arr = path_value
        return jax.ShapeDtypeStruct(arr.shape, arr.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return {
        "params": {
            "w": _like(state["params"]["w"], specs["w"]),
            "embed": _like(state["params"]["embed"], specs["embed"]),
        },
        "opt": {
            "mu": _like(state["opt"]["mu"], specs["mu"]),
            "count": _like(state["opt"]["count"], P()),
        },
    }


def tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    ok = True
    for x, y in zip(fa, fb):
        ok &= np.array_equal(np.asarray(x), np.asarray(y))
    return ok


def main(tmpdir: str) -> int:
    assert jax.device_count() == 8, jax.device_count()
    m42, m24, m81 = mesh_of((4, 2)), mesh_of((2, 4)), mesh_of((8, 1))

    s42 = make_state(m42)
    p1 = os.path.join(tmpdir, "m42.scda")
    save(p1, s42, step=11)

    # Same logical values re-sharded on other meshes → identical bytes.
    host = jax.tree_util.tree_map(lambda x: np.asarray(x), s42)
    for name, mesh in (("m24", m24), ("m81", m81)):
        st = jax.tree_util.tree_map(
            lambda h, x: jax.device_put(h, x.sharding), host, make_state(mesh))
        p = os.path.join(tmpdir, f"{name}.scda")
        save(p, st, step=11)
        if open(p, "rb").read() != open(p1, "rb").read():
            print(f"FAIL: bytes differ for mesh {name}")
            return 1

    # Elastic restores under different meshes and shardings.
    cases = [
        (m24, {"w": P("data", "model"), "embed": P("model", None),
               "mu": P(None, "data")}),
        (m81, {"w": P("data", None), "embed": P(None, "model"),
               "mu": P(None, None)}),
        (m42, {"w": P(("data", "model"), None), "embed": P(),
               "mu": P("model", None)}),
    ]
    for mesh, specs in cases:
        like = abstract_like(s42, mesh, specs)
        # pipelined (default prefetch) AND serial oracle: both must
        # reproduce the logical state exactly under every re-partition.
        for pf in (None, 0):
            out, step = restore(p1, like, prefetch_bytes=pf)
            if step != 11 or not tree_equal(out, s42):
                print(f"FAIL: restore mismatch on mesh {mesh.shape} "
                      f"(prefetch_bytes={pf})")
                return 1
            # restored arrays must carry the requested sharding
            if out["params"]["w"].sharding.spec != specs["w"]:
                print("FAIL: sharding not honored")
                return 1

    print("OK elastic")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1]))
