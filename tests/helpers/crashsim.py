"""Power-cut replay: re-materialize every crash prefix of a commit.

The model is the standard crash-consistency simulation:

1. **Record.**  Snapshot the checkpoint directory, run one full
   ``manager.save()`` under ``faults.record()``, and keep the op log —
   every write, fsync, rename, truncate, and directory fsync the commit
   performed, in completion order (background writeback jobs append at
   completion time, so happens-before edges are preserved).

2. **Replay.**  For a crash after the first ``k`` ops, the disk holds the
   baseline plus some subset of those ``k`` ops' effects:

   * *durable* ops must be present: a data write/truncate is durable once
     a later ``fsync`` of the same path lands **within the prefix**; a
     rename (and a file creation) once a later ``fsync_dir`` of its
     parent directory does;
   * *volatile* ops (not yet covered by any fsync at crash time) may
     each independently be present, absent, or — for writes — torn to an
     arbitrary byte prefix.  The choices come from a seeded RNG, so every
     run is reproducible from ``(seed, prefix, variant)``.

   This is deliberately adversarial-but-legal: no file system reorders a
   write *past* the fsync that covered it, but everything un-fsynced is
   fair game (ext2-style reordering).

3. **Assert.**  The caller materializes each crash state into the real
   directory and checks the paper-level invariant: ``restore_latest()``
   yields either the previous checkpoint or a fully valid new one —
   never garbage, never an error.  For the *complete* op log the new
   checkpoint must be the result under **every** volatile choice: that is
   precisely the assertion that catches a missing directory fsync (the
   rename would be droppable, demoting a "committed" save).
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import Callable, Dict, Iterator, List, Optional, Tuple

from repro.core import faults
from repro.core.faults import Op

#: ops that mutate the file map when replayed
_MUTATORS = ("open", "pwrite", "pwritev", "truncate", "replace")


def _ap(path: str) -> str:
    return os.path.abspath(path)


def snapshot_dir(directory: str) -> Dict[str, bytes]:
    """Byte-for-byte snapshot of every regular file under ``directory``."""
    files: Dict[str, bytes] = {}
    for root, _dirs, names in os.walk(directory):
        for n in names:
            p = os.path.join(root, n)
            with open(p, "rb") as f:
                files[_ap(p)] = f.read()
    return files


def materialize(directory: str, files: Dict[str, bytes]) -> None:
    """Make ``directory`` hold exactly ``files`` (a crash state)."""
    want = set(files)
    for root, _dirs, names in os.walk(directory):
        for n in names:
            p = _ap(os.path.join(root, n))
            if p not in want:
                os.remove(p)
    for p, data in files.items():
        os.makedirs(os.path.dirname(p), exist_ok=True)
        with open(p, "wb") as f:
            f.write(data)


@dataclasses.dataclass
class CommitRecording:
    """One recorded commit: the states around it and the ops between."""
    directory: str
    baseline: Dict[str, bytes]      # disk before save()
    final: Dict[str, bytes]         # disk after save() returned
    ops: List[Op]

    def __len__(self) -> int:
        return len(self.ops)


def record_commit(directory: str, commit: Callable[[], None]) \
        -> CommitRecording:
    """Run ``commit()`` under the op recorder; returns the recording."""
    baseline = snapshot_dir(directory)
    with faults.record() as log:
        commit()
    return CommitRecording(directory, baseline, snapshot_dir(directory),
                           list(log))


# -- durability classification ------------------------------------------------

def _next_cover(rec: CommitRecording) -> List[Optional[int]]:
    """For each op index, the index of the fsync that makes it durable
    (None = never covered).  Data ops are covered by the next ``fsync``
    of their path; renames and creations by the next ``fsync_dir`` of
    their parent directory."""
    fsyncs: Dict[str, List[int]] = {}
    dirsyncs: Dict[str, List[int]] = {}
    for j, op in enumerate(rec.ops):
        if op.op == "fsync":
            fsyncs.setdefault(_ap(op.path), []).append(j)
        elif op.op == "fsync_dir":
            dirsyncs.setdefault(_ap(op.path), []).append(j)

    def nxt(table: Dict[str, List[int]], path: str, i: int) -> Optional[int]:
        return next((j for j in table.get(path, ()) if j > i), None)

    cover: List[Optional[int]] = []
    for i, op in enumerate(rec.ops):
        if op.op in ("pwrite", "pwritev", "truncate"):
            cover.append(nxt(fsyncs, _ap(op.path), i))
        elif op.op == "replace":
            cover.append(nxt(dirsyncs, os.path.dirname(_ap(op.dst)), i))
        elif op.op == "open":
            # Creation/truncation-at-open: durable once the file's data is
            # fsynced or its dirent is (whichever the protocol does first).
            d = nxt(dirsyncs, os.path.dirname(_ap(op.path)), i)
            f = nxt(fsyncs, _ap(op.path), i)
            cands = [x for x in (d, f) if x is not None]
            cover.append(min(cands) if cands else None)
        else:
            cover.append(None)  # fsync/fsync_dir mutate nothing
    return cover


def _apply(files: Dict[str, bytes], op: Op, data: bytes) -> None:
    """Apply one op's effect to the in-memory file map."""
    p = _ap(op.path)
    if op.op == "open":
        if op.n & getattr(os, "O_TRUNC", 0):
            files[p] = b""
        elif p not in files:
            files[p] = b""
    elif op.op in ("pwrite", "pwritev"):
        cur = bytearray(files.get(p, b""))
        end = op.offset + len(data)
        if len(cur) < end:
            cur.extend(b"\x00" * (end - len(cur)))
        cur[op.offset:end] = data
        files[p] = bytes(cur)
    elif op.op == "truncate":
        cur = bytearray(files.get(p, b""))
        if len(cur) < op.n:
            cur.extend(b"\x00" * (op.n - len(cur)))
        files[p] = bytes(cur[:op.n])
    elif op.op == "replace":
        files[_ap(op.dst)] = files.pop(p, b"")


def crash_state(rec: CommitRecording, prefix: int,
                rng: Optional[random.Random] = None,
                drop_all_volatile: bool = False) -> Dict[str, bytes]:
    """The disk after a power cut following ``rec.ops[:prefix]``.

    ``rng`` drives the volatile choices (None = keep everything, the
    no-reordering best case); ``drop_all_volatile`` is the worst case —
    nothing un-fsynced survives.
    """
    cover = _next_cover(rec)
    files = dict(rec.baseline)
    for i in range(prefix):
        op = rec.ops[i]
        if op.op not in _MUTATORS:
            continue
        durable = cover[i] is not None and cover[i] < prefix
        data = op.data
        if not durable:
            if drop_all_volatile:
                continue
            if rng is not None:
                roll = rng.random()
                if roll < 1 / 3:
                    continue                       # dropped entirely
                if roll < 2 / 3 and data:          # torn mid-write
                    data = data[:rng.randint(0, len(data) - 1)]
        _apply(files, op, data)
    return files


def iter_crash_states(rec: CommitRecording, seed: int = 0,
                      prefixes: Optional[List[int]] = None,
                      variants: int = 2) \
        -> Iterator[Tuple[int, str, Dict[str, bytes]]]:
    """Yield ``(prefix, variant_name, files)`` crash states.

    Per prefix: the all-durable best case, the drop-everything-volatile
    worst case, and ``variants`` seeded random drop/tear mixes.  With
    ``prefixes=None`` every prefix of the op log is replayed (the
    exhaustive nightly matrix).
    """
    ks = prefixes if prefixes is not None else list(range(len(rec.ops) + 1))
    for k in ks:
        yield k, "keep-all", crash_state(rec, k)
        yield k, "drop-volatile", crash_state(rec, k, drop_all_volatile=True)
        for v in range(variants):
            rng = random.Random((seed << 20) ^ (k << 4) ^ v)
            yield k, f"mix-{v}", crash_state(rec, k, rng=rng)


def sampled_prefixes(rec: CommitRecording, n: int, seed: int = 0) \
        -> List[int]:
    """A bounded, deterministic prefix sample for the quick CI lane:
    always includes 0, the full log, and every op index adjacent to a
    commit-critical op (rename, fsync, fsync_dir) — the interesting
    boundaries — plus a seeded random fill up to ``n``."""
    total = len(rec.ops)
    must = {0, total}
    for i, op in enumerate(rec.ops):
        if op.op in ("replace", "fsync", "fsync_dir"):
            must.update((i, i + 1))
    must = {k for k in must if 0 <= k <= total}
    rest = sorted(set(range(total + 1)) - must)
    rng = random.Random(seed)
    fill = rng.sample(rest, min(max(0, n - len(must)), len(rest)))
    return sorted(must | set(fill))
