"""Deterministic fault injection across the I/O stack (repro.core.faults).

Covers the plan grammar, the backend's transient-retry and taxonomy
conversion, ENOSPC clean-abort semantics at the manager level, fault
delivery from the background writeback/prefetch executors, the
``SimulatedCrash`` power-cut semantics, and ``scdatool repair``.
"""
import errno
import os

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import faults, fopen_write
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.index import SIDECAR_SUFFIX, ScdaIndex
from repro.core.io_backend import FileBackend, replace_durable
from repro.tools import cli
from repro.tools.fsck import fsck_file, repair_file, repair_set


# -- plan grammar -------------------------------------------------------------

class TestFaultPlan:
    def test_parse_fields(self):
        plan = faults.FaultPlan.parse(
            "pwrite:errno=ENOSPC:nth=3:count=2:path=tmp;"
            "pwritev:torn=1;*:crash:nth=40;preadv:short=100")
        r = plan.rules[0]
        assert (r.op, r.kind, r.errno_, r.nth, r.count, r.path) == \
            ("pwrite", "errno", errno.ENOSPC, 3, 2, "tmp")
        assert plan.rules[1].kind == "torn" and plan.rules[1].n == 1
        assert plan.rules[2].op == "*" and plan.rules[2].kind == "crash"
        assert plan.rules[3].n == 100

    def test_parse_numeric_errno(self):
        plan = faults.FaultPlan.parse("fsync:errno=5")
        assert plan.rules[0].errno_ == 5

    def test_parse_whole_file_loss_actions(self):
        plan = faults.FaultPlan.parse(
            "open:missing:path=-s00of04;open:unlink:count=-1")
        assert plan.rules[0].kind == "missing"
        assert plan.rules[0].path == "-s00of04"
        assert plan.rules[1].kind == "unlink"

    def test_missing_raises_enoent(self, tmp_path):
        p = str(tmp_path / "f.bin")
        with open(p, "wb") as f:
            f.write(b"x")
        with faults.inject("open:missing"):
            with pytest.raises(OSError) as ei:
                faults.os_open(p, os.O_RDONLY)
        assert ei.value.errno == errno.ENOENT
        assert os.path.exists(p)  # the file itself is untouched

    def test_unlink_removes_file_for_real(self, tmp_path):
        p = str(tmp_path / "f.bin")
        with open(p, "wb") as f:
            f.write(b"x")
        with faults.inject("open:unlink"):
            with pytest.raises(ScdaError) as ei:
                FileBackend(p, "r", create=False)
        assert ei.value.code == ScdaErrorCode.FS_OPEN
        assert not os.path.exists(p)

    @pytest.mark.parametrize("bad", [
        "frobnicate:crash",            # unknown op
        "pwrite:nth=2",                # no action
        "pwrite:errno=ENOTANERRNO",    # unknown errno name
        "pwrite:crash:wat=1",          # unknown field
    ])
    def test_parse_rejects(self, bad):
        with pytest.raises(ValueError):
            faults.FaultPlan.parse(bad)

    def test_nth_count_scheduling(self):
        inj = faults.FaultInjector("fsync:errno=EIO:nth=2:count=2")
        fired = [inj.decide("fsync", "f") is not None for _ in range(5)]
        assert fired == [False, True, True, False, False]

    def test_path_filter(self):
        inj = faults.FaultInjector("pwrite:errno=EIO:path=.tmp:count=-1")
        assert inj.decide("pwrite", "a.scda") is None
        assert inj.decide("pwrite", "a.scda.tmp") is not None

    def test_bernoulli_deterministic(self):
        spec = "pwrite:errno=EIO:p=0.5:seed=9"
        i1, i2 = faults.FaultInjector(spec), faults.FaultInjector(spec)
        s1 = [i1.decide("pwrite", "f") is not None for _ in range(32)]
        s2 = [i2.decide("pwrite", "f") is not None for _ in range(32)]
        assert s1 == s2          # same seed, same schedule
        assert any(s1) and not all(s1)


# -- backend hardening --------------------------------------------------------

class TestBackendFaults:
    def test_transient_retried(self, tmp_path, fault_injection):
        inj = fault_injection("pwrite:errno=EINTR:nth=1:count=3;"
                              "pwrite:errno=EAGAIN:nth=4:count=2")
        p = str(tmp_path / "x.scda")
        b = FileBackend(p, "w", create=True)
        b.pwrite(0, b"payload")  # survives 5 injected transient errors
        b.close(sync=True)
        assert len(inj.injected) == 5
        with open(p, "rb") as f:
            assert f.read() == b"payload"

    def test_hard_errno_is_taxonomy_error(self, tmp_path, fault_injection):
        fault_injection("pwrite:errno=EIO:count=-1")
        b = FileBackend(str(tmp_path / "x.scda"), "w", create=True)
        with pytest.raises(ScdaError) as ei:
            b.pwrite(128, b"data")
        b.close()
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        assert ei.value.offset == 128
        assert "x.scda@128" in ei.value.detail

    def test_retries_bounded(self, tmp_path, fault_injection, monkeypatch):
        monkeypatch.setenv("REPRO_SCDA_RETRIES", "2")
        fault_injection("pwrite:errno=EAGAIN:count=-1")
        b = FileBackend(str(tmp_path / "x.scda"), "w", create=True)
        with pytest.raises(ScdaError) as ei:
            b.pwrite(0, b"data")
        b.close()
        assert "gave up after 2 transient retries" in ei.value.detail

    def test_read_paths_retry_and_convert(self, tmp_path, fault_injection):
        p = str(tmp_path / "x.scda")
        with open(p, "wb") as f:
            f.write(b"A" * 64)
        fault_injection("pread:errno=EINTR:nth=1;"
                        "pread:errno=EIO:nth=3")
        b = FileBackend(p, "r", create=False, readahead=0)
        assert b.pread(0, 8) == b"A" * 8    # EINTR retried
        with pytest.raises(ScdaError) as ei:
            b.pread(16, 8)                   # EIO converts
        b.close()
        assert ei.value.code == ScdaErrorCode.FS_READ
        assert ei.value.offset == 16

    def test_torn_pwritev_lands_prefix_then_crashes(self, tmp_path):
        p = str(tmp_path / "x.scda")
        b = faults.FaultBackend(p, "w", True, "pwritev:torn=1")
        # fragments above the coalescing threshold stay distinct iovecs
        frags = [b"A" * 16384, b"B" * 16384, b"C" * 16384]
        with pytest.raises(faults.SimulatedCrash):
            b.pwritev(0, frags)
        os.close(b.fd)
        b.fd = -1
        with open(p, "rb") as f:
            assert f.read() == frags[0]  # fragment 0 landed, cut at 1

    def test_crash_is_not_caught_by_taxonomy(self, tmp_path,
                                             fault_injection):
        fault_injection("fsync:crash")
        b = FileBackend(str(tmp_path / "x.scda"), "w", create=True)
        b.pwrite(0, b"d")
        with pytest.raises(faults.SimulatedCrash):
            b.fsync()
        os.close(b.fd)
        b.fd = -1

    def test_env_activation(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_SCDA_FAULTS", "pwrite:errno=EIO:count=-1")
        b = FileBackend(str(tmp_path / "x.scda"), "w", create=True)
        with pytest.raises(ScdaError):
            b.pwrite(0, b"d")
        b.close()
        monkeypatch.setenv("REPRO_SCDA_FAULTS", "")
        b = FileBackend(str(tmp_path / "y.scda"), "w", create=True)
        b.pwrite(0, b"d")  # plan cleared with the variable
        b.close()

    def test_malformed_env_spec_is_inert(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_SCDA_FAULTS", "no-such-op:crash")
        b = FileBackend(str(tmp_path / "x.scda"), "w", create=True)
        b.pwrite(0, b"d")
        b.close(sync=True)

    def test_scoped_backend_does_not_leak(self, tmp_path):
        plan = "pwrite:errno=EIO:count=-1"
        bad = faults.FaultBackend(str(tmp_path / "bad.scda"), "w", True,
                                  plan)
        ok = FileBackend(str(tmp_path / "ok.scda"), "w", create=True)
        with pytest.raises(ScdaError):
            bad.pwrite(0, b"d")
        ok.pwrite(0, b"d")  # unaffected: the plan is per-backend
        bad.close()
        ok.close()


class TestExecutorFaults:
    def test_writeback_fault_surfaces_with_offset(self, tmp_path):
        b = faults.FaultBackend(str(tmp_path / "x.scda"), "w", True,
                                "pwrite:errno=EIO:count=-1;"
                                "pwritev:errno=EIO:count=-1")
        b.submit_write_gather([(4096, b"Z" * 64)], window=1 << 20)
        with pytest.raises(ScdaError) as ei:
            b.drain_writes()
        b.close()
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        assert ei.value.offset is not None

    def test_writeback_crash_stays_crash(self, tmp_path):
        b = faults.FaultBackend(str(tmp_path / "x.scda"), "w", True,
                                "pwrite:crash;pwritev:crash")
        b.submit_write_gather([(0, b"Z" * 64)], window=1 << 20)
        with pytest.raises(faults.SimulatedCrash):
            b.drain_writes()
        os.close(b.fd)
        b.fd = -1

    def test_prefetch_fault_surfaces_on_foreground_read(self, tmp_path):
        p = str(tmp_path / "x.scda")
        with open(p, "wb") as f:
            f.write(b"A" * 8192)
        b = faults.FaultBackend(p, "r", False, "pread:errno=EIO:count=-1")
        b.prefetch([(0, 4096)], window=1 << 20)
        with pytest.raises(ScdaError) as ei:
            b.pread(0, 4096)  # the advisory prefetch failed; this raises
        b.close()
        assert ei.value.code == ScdaErrorCode.FS_READ


# -- manager-level clean aborts ----------------------------------------------

def _tree(seed: int):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((33, 7)).astype(np.float32),
            "s": np.array(seed, dtype=np.int64)}


def _assert_no_tmp(directory: str) -> None:
    leftovers = [n for n in os.listdir(directory) if ".tmp" in n]
    assert leftovers == [], f"orphaned tmp files: {leftovers}"


class TestManagerCleanAbort:
    @pytest.mark.parametrize("shards", [0, 2])
    def test_enospc_mid_save_aborts_clean(self, tmp_path, fault_injection,
                                          shards):
        d = str(tmp_path / "ckpts")
        mgr = CheckpointManager(d, keep=3, shards=shards, delta=False)
        mgr.save(1, _tree(1), blocking=True)
        fault_injection("pwrite:errno=ENOSPC:path=.tmp:count=-1;"
                        "pwritev:errno=ENOSPC:path=.tmp:count=-1")
        with pytest.raises(ScdaError) as ei:
            mgr.save(2, _tree(2), blocking=True)
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        assert "NO SPACE LEFT ON DEVICE" in str(ei.value)
        # clean abort: no partial checkpoint visible, no tmp orphans
        _assert_no_tmp(d)
        out, step = mgr.restore_latest()
        assert step == 1
        faults.uninstall()  # "space freed up"
        mgr.save(2, _tree(2), blocking=True)  # the manager is reusable
        out, step = mgr.restore_latest()
        assert step == 2
        assert np.array_equal(out["w"], _tree(2)["w"])
        _assert_no_tmp(d)

    def test_fault_during_refresh_sidecar_direct(self, tmp_path,
                                                 fault_injection):
        p = str(tmp_path / "a.scda")
        with fopen_write(None, p, user_string=b"t") as f:
            f.write_block(b"b", b"payload")
        ScdaIndex.build(p).write_sidecar()
        from repro.core import fopen_append
        with fopen_append(None, p) as f:
            f.write_block(b"b2", b"more")
        fault_injection("replace:errno=EIO:path=" + SIDECAR_SUFFIX
                        + ":count=-1")
        with pytest.raises(ScdaError) as ei:
            ScdaIndex.refresh_sidecar(p)
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        faults.uninstall()
        idx = ScdaIndex.refresh_sidecar(p)  # recovers once the fault clears
        assert idx is not None and len(idx.entries) == 2
        ScdaIndex.load_sidecar(p).verify(deep=True)

    def test_sidecar_fault_never_blocks_commit(self, tmp_path,
                                               fault_injection):
        d = str(tmp_path / "ckpts")
        mgr = CheckpointManager(d, keep=3, shards=0, delta=False)
        fault_injection("replace:errno=EIO:path=" + SIDECAR_SUFFIX
                        + ":count=-1")
        mgr.save(1, _tree(1), blocking=True)  # sidecars are best-effort
        out, step = mgr.restore_latest()
        assert step == 1
        _assert_no_tmp(d)


# -- scdatool repair ----------------------------------------------------------

def _torn_archive(tmp_path, name="a.scda", garbage=b"\x13" * 37,
                  sidecar=True):
    p = str(tmp_path / name)
    with fopen_write(None, p, user_string=b"t") as f:
        f.write_inline(b"i", b"x" * 32)
        f.write_block(b"b", b"hello world payload")
    if sidecar:
        idx = ScdaIndex.build(p)
        from repro.core.reader import fopen_read
        with fopen_read(None, p) as r:
            idx = idx.with_checksums(r)
        idx.write_sidecar()
    clean = os.path.getsize(p)
    with open(p, "ab") as f:
        f.write(garbage)
    return p, clean


class TestRepair:
    def test_repair_salvages_valid_prefix(self, tmp_path):
        p, clean = _torn_archive(tmp_path)
        assert any(f.severity == "error" for f in fsck_file(p))
        res = repair_file(p)
        assert res.action == "repaired"
        assert res.valid_bytes == clean and res.sections == 2
        assert os.path.getsize(p) == clean
        assert fsck_file(p) == []  # fsck-clean after the repair
        # quarantined bytes are the exact damaged tail, by offset
        assert res.quarantine == f"{p}.quarantine-{clean}"
        with open(res.quarantine, "rb") as f:
            assert f.read() == b"\x13" * 37
        # sidecar rebuilt, checksums preserved
        idx = ScdaIndex.load_sidecar(p)
        idx.verify(deep=True)
        assert idx.has_checksums()

    def test_repair_clean_and_dry_run(self, tmp_path):
        p, clean = _torn_archive(tmp_path)
        dry = repair_file(p, dry_run=True)
        assert dry.action == "would-repair"
        assert os.path.getsize(p) == clean + 37  # untouched
        repair_file(p)
        again = repair_file(p)
        assert again.action == "clean" and again.sections == 2

    def test_repair_unrecoverable_header(self, tmp_path):
        p = str(tmp_path / "junk.scda")
        with open(p, "wb") as f:
            f.write(b"not an scda file at all")
        res = repair_file(p)
        assert res.action == "unrecoverable"

    def test_repair_set_reports_per_shard(self, tmp_path):
        d = str(tmp_path / "ckpts")
        mgr = CheckpointManager(d, keep=2, shards=3, delta=False)
        mgr.save(1, _tree(1), blocking=True)
        manifest = mgr.path_for(1)
        from repro.checkpoint.sharding import shard_file
        victim = shard_file(manifest, 1, 3)
        assert os.path.exists(victim)
        with open(victim, "ab") as f:
            f.write(b"\xde\xad\xbe\xef" * 9)
        results = repair_set(manifest)
        by_path = {r.path: r for r in results}
        assert by_path[manifest].action == "clean"
        assert by_path[victim].action == "repaired"
        others = [r for r in results
                  if r.path not in (manifest, victim)]
        assert others and all(r.action == "clean" for r in others)
        # the set restores after repair
        out, step = CheckpointManager(d, keep=2, shards=3,
                                      delta=False).restore_latest()
        assert step == 1 and np.array_equal(out["w"], _tree(1)["w"])

    def test_cli_repair(self, tmp_path, capsys):
        p, clean = _torn_archive(tmp_path)
        assert cli.main(["repair", "--dry-run", p]) == 1
        assert os.path.getsize(p) == clean + 37
        assert cli.main(["repair", p]) == 0
        out = capsys.readouterr().out
        assert "repaired" in out and f"quarantine-{clean}" in out
        assert cli.main(["fsck", p]) == 0
        assert cli.main(["verify", p]) == 0
        assert cli.main(["repair", p]) == 0  # idempotent: now clean
