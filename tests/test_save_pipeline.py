"""The overlapped save engine (PR 4): write-side backpressure, the
snapshot → deflate → pwritev pipeline, and the pipelined checkpoint save
scheduler.

Core invariant — the write mirror of the PR-3 restore contract: the
pipeline changes WHEN payloads deflate and WHERE the pwritev happens,
never WHAT lands in the file.  Every pipelined save must be
byte-identical to the serial write oracle (``write_window=0`` /
``REPRO_SCDA_WRITE_PIPELINE=0``), at every writing partition, and every
failure must raise the same ScdaError the serial path raises — with the
temp file cleaned up and no leaked futures (no hangs).
"""
import os
import threading

import numpy as np
import pytest

from repro.checkpoint import pytree_io
from repro.checkpoint.manager import CheckpointManager
from repro.core import ScdaError, ThreadComm, codec, run_ranks
from repro.core.errors import ScdaErrorCode
from repro.core.io_backend import (MAX_ZERO_PROGRESS, FileBackend,
                                   write_pipeline_window)
from repro.core.pipeline import WriteItem, run_write_pipeline

WW = 1 << 20  # pipelined write window used throughout


def _read(path):
    with open(path, "rb") as fh:
        return fh.read()


# --------------------------------------------------------------------------
# FileBackend: background writeback (submit_write_gather / drain_writes)
# --------------------------------------------------------------------------

class TestWriteback:
    def test_background_equals_foreground(self, tmp_path):
        rng = np.random.default_rng(0)
        frags, pos = [], 0
        for _ in range(50):
            n = int(rng.integers(1, 5000))
            frags.append((pos, bytes(rng.integers(0, 256, n,
                                                  dtype=np.uint8))))
            pos += n + int(rng.integers(0, 3)) * 64  # some gaps
        a, b = str(tmp_path / "fg.bin"), str(tmp_path / "bg.bin")
        fg = FileBackend(a, "w", create=True)
        fg.write_gather(frags)
        fg.close()
        bg = FileBackend(b, "w", create=True)
        for frag in frags:  # one job per fragment: maximal reordering
            bg.submit_write_gather([frag], window=WW)
        bg.drain_writes()
        assert bg.pending_write_bytes() == 0
        bg.close()
        assert _read(a) == _read(b)

    def test_tiny_window_backpressure_still_completes(self, tmp_path):
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        payload = b"x" * 4096
        for i in range(32):  # window smaller than one fragment is legal
            b.submit_write_gather([(i * 4096, payload)], window=100)
        b.drain_writes()
        b.close()
        assert _read(str(tmp_path / "w.bin")) == payload * 32

    def test_window_zero_is_synchronous(self, tmp_path):
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        b.submit_write_gather([(0, b"hello")], window=0)
        assert b._wb_pool is None  # never spun up a thread
        assert b.pending_write_bytes() == 0
        b.close()
        assert _read(str(tmp_path / "w.bin")) == b"hello"

    def test_write_error_surfaces_on_drain_and_submit(self, tmp_path,
                                                      monkeypatch):
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)

        def boom(fd, bufs, off):
            raise OSError(28, "No space left on device")

        monkeypatch.setattr(os, "pwritev", boom)
        b.submit_write_gather([(0, b"z" * 100)], window=WW)
        with pytest.raises(ScdaError) as ei:
            b.drain_writes()
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        monkeypatch.undo()
        b.close()

    def test_close_surfaces_pending_write_error(self, tmp_path,
                                                monkeypatch):
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)

        def boom(fd, bufs, off):
            raise OSError(5, "Input/output error")

        monkeypatch.setattr(os, "pwritev", boom)
        b.submit_write_gather([(0, b"z" * 100)], window=WW)
        monkeypatch.undo()
        with pytest.raises(ScdaError) as ei:
            b.close()
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        assert b.fd == -1  # descriptor never leaks on the error path

    def test_poison_survives_drain(self, tmp_path, monkeypatch):
        # drain_writes delivers the error ONCE (close after a handled
        # failure must not re-raise and mask it), but the file stays
        # poisoned: later submissions fail fast on every path, or the
        # caller could "successfully" close a file missing fragments.
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)

        def boom(fd, bufs, off):
            raise OSError(28, "No space left on device")

        monkeypatch.setattr(os, "pwritev", boom)
        b.submit_write_gather([(0, b"z" * 100)], window=WW)
        with pytest.raises(ScdaError):
            b.drain_writes()
        monkeypatch.undo()
        with pytest.raises(ScdaError) as ei:  # background path
            b.submit_write_gather([(100, b"y" * 100)], window=WW)
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        with pytest.raises(ScdaError):  # serial path poisons too
            b.submit_write_gather([(100, b"y" * 100)], window=0)
        b.close()  # error already delivered: close stays clean

    def test_submit_delivery_consumes_error_close_stays_clean(
            self, tmp_path, monkeypatch):
        # The once-only delivery contract holds on the SUBMIT path too:
        # once a submission has raised the failure, close() must not
        # re-raise it (it would mask whatever the caller is unwinding).
        import time
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)

        def boom(fd, bufs, off):
            raise OSError(28, "No space left on device")

        monkeypatch.setattr(os, "pwritev", boom)
        b.submit_write_gather([(0, b"z" * 100)], window=WW)
        monkeypatch.undo()
        for _ in range(500):  # job fails promptly; reap sets the poison
            if b.pending_write_bytes() == 0:
                break
            time.sleep(0.01)
        with pytest.raises(ScdaError) as ei:
            b.submit_write_gather([(100, b"y" * 100)], window=WW)
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        b.close()  # delivered above: close must stay clean
        assert b.fd == -1

    def test_non_scda_write_error_converts_and_closes_fd(self, tmp_path,
                                                         monkeypatch):
        # A writeback job dying with a NON-ScdaError (bad buffer, memory
        # pressure) must still surface as the foreground FS_WRITE error —
        # never escape raw past close()'s handler and leak the fd.
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)

        def boom(fd, bufs, off):
            raise TypeError("synthetic non-ScdaError failure")

        monkeypatch.setattr(os, "pwritev", boom)
        b.submit_write_gather([(0, b"z" * 100)], window=WW)
        with pytest.raises(ScdaError) as ei:
            b.drain_writes()
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        monkeypatch.undo()
        b.close()
        assert b.fd == -1


# --------------------------------------------------------------------------
# write_gather zero-progress accounting — incl. the small-fragment
# pre-join path (fully-joined runs must NOT bypass the vectored path)
# --------------------------------------------------------------------------

class TestZeroProgress:
    def test_zero_progress_large_fragments(self, tmp_path, monkeypatch):
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        monkeypatch.setattr(os, "pwritev", lambda fd, bufs, off: 0)
        with pytest.raises(ScdaError) as ei:
            b.write_gather([(0, b"x" * 20000), (20000, b"y" * 20000)])
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        monkeypatch.undo()
        b.close()

    def test_zero_progress_prejoined_small_run(self, tmp_path,
                                               monkeypatch):
        """A run whose fragments all pre-join used to collapse to one
        buffer and silently take the os.pwrite path — injection (and
        stall accounting) at the pwritev layer never saw it.  It must
        now raise FS_WRITE through the same vectored-path guard."""
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        calls = []

        def zero(fd, bufs, off):
            calls.append(len(bufs))
            return 0

        monkeypatch.setattr(os, "pwritev", zero)
        small = [(i * 100, b"a" * 100) for i in range(10)]  # joins to one
        with pytest.raises(ScdaError) as ei:
            b.write_gather(small)
        assert ei.value.code == ScdaErrorCode.FS_WRITE
        assert len(calls) == MAX_ZERO_PROGRESS  # the injection DID bite
        assert all(c == 1 for c in calls)       # ... on the joined view
        monkeypatch.undo()
        b.close()

    def test_short_writes_resume_byte_identical(self, tmp_path,
                                                monkeypatch):
        real = os.pwritev

        def tiny(fd, bufs, off):  # ≤3 bytes per call, resumes mid-buffer
            return real(fd, [memoryview(bufs[0])[:3]], off)

        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        monkeypatch.setattr(os, "pwritev", tiny)
        frags = [(0, b"a" * 100), (100, b"b" * 100), (200, b"c" * 56),
                 (256, b"X" * 20000), (20256, b"d" * 10), (20266, b"e" * 10)]
        b.write_gather(frags)
        monkeypatch.undo()
        b.close()
        assert _read(str(tmp_path / "w.bin")) == \
            b"a" * 100 + b"b" * 100 + b"c" * 56 + b"X" * 20000 \
            + b"d" * 10 + b"e" * 10

    def test_intermittent_stalls_complete(self, tmp_path, monkeypatch):
        real = os.pwritev
        count = [0]

        def flaky(fd, bufs, off):  # a few zeros between every grain
            count[0] += 1
            if count[0] % 4 != 0:
                return 0
            return real(fd, [memoryview(bufs[0])[:512]], off)

        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        monkeypatch.setattr(os, "pwritev", flaky)
        b.write_gather([(0, b"q" * 4096), (4096, b"r" * 4096)])
        monkeypatch.undo()
        b.close()
        assert _read(str(tmp_path / "w.bin")) == b"q" * 4096 + b"r" * 4096


# --------------------------------------------------------------------------
# run_write_pipeline: serial mode is the oracle for the pipelined mode
# --------------------------------------------------------------------------

def _raw_items(payloads):
    cursor = [0]
    items = []
    for i, p in enumerate(payloads):
        def plan(payload, n=len(p)):
            frags = [(cursor[0], payload)]
            cursor[0] += n
            return frags
        items.append(WriteItem(key=i, snapshot=lambda p=p: p, plan=plan))
    return items


class TestRunWritePipeline:
    def test_serial_equals_pipelined_raw(self, tmp_path):
        rng = np.random.default_rng(1)
        payloads = [bytes(rng.integers(0, 256, int(rng.integers(1, 40000)),
                                       dtype=np.uint8)) for _ in range(20)]
        out = {}
        for window in (0, WW):
            path = str(tmp_path / f"w{window}.bin")
            b = FileBackend(path, "w", create=True)
            run_write_pipeline(b, _raw_items(payloads), window)
            b.close()
            out[window] = _read(path)
        assert out[0] == out[WW] == b"".join(payloads)

    @pytest.mark.parametrize("nchunks", [1, 3, 17])
    def test_serial_equals_pipelined_deflate(self, tmp_path, nchunks):
        rng = np.random.default_rng(2)
        chunks = [rng.standard_normal(3000).astype(np.float32).tobytes()
                  for _ in range(nchunks)]

        def make_items():
            cursor = [0]

            def plan(streams):
                frags = []
                for s in streams:
                    frags.append((cursor[0], s))
                    cursor[0] += len(s)
                return frags
            return [WriteItem(key=0, snapshot=lambda: chunks, plan=plan,
                              deflate=True)]

        out = {}
        for window in (0, WW):
            path = str(tmp_path / f"w{window}.bin")
            b = FileBackend(path, "w", create=True)
            run_write_pipeline(b, make_items(), window)
            b.close()
            out[window] = _read(path)
        oracle = b"".join(codec.compress(c) for c in chunks)
        assert out[0] == out[WW] == oracle

    def test_plans_run_in_item_order(self, tmp_path):
        order = []
        cursor = [0]
        items = []
        for i in range(12):
            def plan(payload, i=i):
                order.append(i)
                frags = [(cursor[0], payload)]
                cursor[0] += len(payload)
                return frags
            items.append(WriteItem(key=i, snapshot=lambda i=i: b"%03d" % i,
                                   plan=plan, deflate=False))
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        run_write_pipeline(b, items, WW)
        b.close()
        assert order == list(range(12))

    def test_raw_payloads_count_toward_byte_cap(self):
        # Non-deflate snapshots must register their real size with the
        # in-flight accounting — est 0 would let the engine pin
        # depth+1 whole-leaf host copies regardless of the byte cap.
        from repro.core.pipeline import _est_bytes
        assert _est_bytes(b"abc") == 3
        assert _est_bytes(memoryview(b"abcd")) == 4
        assert _est_bytes([b"ab", memoryview(b"cde")]) == 5
        assert _est_bytes([(0, b"ab"), (7, b"cdef")]) == 6  # window lists
        assert _est_bytes(object()) == 0  # unsizable: depth cap only
        gen = (b for b in [b"ab"])  # one-shot payloads must NOT be
        assert _est_bytes(gen) == 0  # consumed before plan() sees them
        assert list(gen) == [b"ab"]

    def test_generator_payload_reaches_plan_unconsumed(self, tmp_path):
        got = []

        def plan(payload):
            got.append(b"".join(payload))
            return [(0, got[-1])]

        items = [WriteItem(key=0, snapshot=lambda: (c for c in
                                                    [b"he", b"llo"]),
                           plan=plan)]
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        run_write_pipeline(b, items, WW)
        b.close()
        assert got == [b"hello"]
        assert _read(str(tmp_path / "w.bin")) == b"hello"

    def test_error_in_plan_drains_cleanly(self, tmp_path):
        items = _raw_items([b"x" * 100] * 8)

        def bad_plan(payload):
            raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE, "injected")

        items[3] = WriteItem(key=3, snapshot=lambda: b"y",
                             plan=bad_plan)
        b = FileBackend(str(tmp_path / "w.bin"), "w", create=True)
        with pytest.raises(ScdaError) as ei:
            run_write_pipeline(b, items, WW)
        assert ei.value.code == ScdaErrorCode.ARG_DATA_SIZE
        assert b.pending_write_bytes() == 0  # quiesced before raising
        b.close()


# --------------------------------------------------------------------------
# Checkpoint save: pipelined file bytes == serial write oracle (fuzzed)
# --------------------------------------------------------------------------

def _fuzz_tree(rng, max_leaves=6):
    dtypes = [np.float32, np.float64, np.int32, np.uint8, np.int16]
    tree = {}
    n = int(rng.integers(1, max_leaves + 1))
    for i in range(n):
        kind = int(rng.integers(0, 4))
        dt = dtypes[int(rng.integers(0, len(dtypes)))]
        if kind == 0:  # empty
            shape = (0, int(rng.integers(1, 5)))
        elif kind == 1:  # scalar-ish
            shape = ()
        elif kind == 2:  # 1-D, deliberately odd length
            shape = (int(rng.integers(1, 50000)),)
        else:  # small N-D
            shape = tuple(int(rng.integers(1, 40))
                          for _ in range(int(rng.integers(2, 4))))
        if np.issubdtype(dt, np.floating):
            val = rng.standard_normal(shape).astype(dt)
        else:
            val = rng.integers(0, 100, shape).astype(dt)
        tree[f"leaf{i}"] = val
    tree["aux_lr"] = 0.5
    return tree


@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_save_byte_identity_raw_fuzzed(tmp_path, P):
    rng = np.random.default_rng(100 + P)
    for trial in range(3):
        tree = _fuzz_tree(rng)
        oracle = str(tmp_path / f"oracle{trial}.scda")
        pytree_io.save(oracle, tree, step=trial, write_window=0)
        piped = str(tmp_path / f"piped{trial}.scda")

        def workload(comm):
            pytree_io.save(piped, tree, step=trial, comm=comm,
                           write_window=WW)
        run_ranks(ThreadComm.group(P), workload)
        assert _read(piped) == _read(oracle), \
            f"trial {trial}: pipelined save differs from oracle at P={P}"


def test_save_byte_identity_compressed_fuzzed(tmp_path):
    rng = np.random.default_rng(7)
    for trial in range(4):
        tree = _fuzz_tree(rng)
        chunk = int(rng.integers(1, 3)) << int(rng.integers(10, 14))
        a = str(tmp_path / f"o{trial}.scda")
        b = str(tmp_path / f"p{trial}.scda")
        pytree_io.save(a, tree, compressed=True, chunk_bytes=chunk,
                       write_window=0)
        pytree_io.save(b, tree, compressed=True, chunk_bytes=chunk,
                       write_window=WW)
        assert _read(a) == _read(b), f"trial {trial} chunk={chunk}"
        out, _ = pytree_io.restore(b)
        for k, v in tree.items():
            if isinstance(v, np.ndarray):
                np.testing.assert_array_equal(out[k], v)


def test_write_pipeline_env_knob(tmp_path, monkeypatch):
    tree = {"w": np.arange(10000, dtype=np.float32)}
    monkeypatch.setenv("REPRO_SCDA_WRITE_PIPELINE", "0")
    assert write_pipeline_window() == 0
    a = str(tmp_path / "a.scda")
    pytree_io.save(a, tree)
    monkeypatch.setenv("REPRO_SCDA_WRITE_PIPELINE", str(WW))
    assert write_pipeline_window() == WW
    b = str(tmp_path / "b.scda")
    pytree_io.save(b, tree)
    assert _read(a) == _read(b)


def test_short_write_parity_checkpoint(tmp_path, monkeypatch):
    """Partial pwritev returns mid-save: both modes must still produce
    the identical (correct) file — the resume path is byte-transparent
    under the pipeline too."""
    real = os.pwritev
    tree = {"w": np.arange(30000, dtype=np.float32),
            "b": np.ones((100,), np.float64)}

    def clipped(fd, bufs, off):
        return real(fd, [memoryview(bufs[0])[:1024]], off)

    files = {}
    for ww in (0, WW):
        path = str(tmp_path / f"ck{ww}.scda")
        monkeypatch.setattr(os, "pwritev", clipped)
        pytree_io.save(path, tree, write_window=ww)
        monkeypatch.undo()
        files[ww] = _read(path)
    assert files[0] == files[WW]
    out, _ = pytree_io.restore(str(tmp_path / f"ck{WW}.scda"))
    np.testing.assert_array_equal(out["w"], tree["w"])


@pytest.mark.parametrize("compressed", [False, True])
@pytest.mark.parametrize("ww", [0, WW])
def test_write_error_parity_and_tmp_cleanup(tmp_path, monkeypatch,
                                            compressed, ww):
    """An injected device failure mid-save must (a) raise the same
    FS_WRITE ScdaError in serial and pipelined modes, (b) leave no
    visible checkpoint and no .tmp file behind, (c) leak no futures."""
    real = os.pwritev
    calls = [0]

    def failing(fd, bufs, off):
        calls[0] += 1
        if calls[0] > 2:  # let the status/manifest through, then die
            raise OSError(28, "No space left on device")
        return real(fd, bufs, off)

    monkeypatch.setenv("REPRO_SCDA_WRITE_PIPELINE", str(ww))
    mgr = CheckpointManager(str(tmp_path / "ckpts"), compressed=compressed)
    tree = {"w": np.arange(200000, dtype=np.float32)}
    monkeypatch.setattr(os, "pwritev", failing)
    with pytest.raises(ScdaError) as ei:
        mgr.save(5, tree, blocking=True)
    monkeypatch.undo()
    assert ei.value.code == ScdaErrorCode.FS_WRITE
    assert mgr.all_steps() == []  # atomic-rename invariant held
    leftovers = [n for n in os.listdir(str(tmp_path / "ckpts"))
                 if n.endswith(".tmp")]
    assert leftovers == []  # failed save cleans its temp file


def test_interrupted_save_leaves_no_visible_checkpoint(tmp_path):
    """A save that dies mid-pipeline (after the data is written, before
    the commit) must not surface a visible checkpoint."""
    mgr = CheckpointManager(str(tmp_path / "ckpts"))
    mgr._crash_before_commit = True
    with pytest.raises(RuntimeError):
        mgr.save(3, {"w": np.arange(1000, dtype=np.float32)},
                 blocking=True)
    assert mgr.all_steps() == []
    mgr._crash_before_commit = False
    mgr.save(4, {"w": np.arange(1000, dtype=np.float32)}, blocking=True)
    assert mgr.all_steps() == [4]


# --------------------------------------------------------------------------
# Save under pressure
# --------------------------------------------------------------------------

def test_concurrent_save_and_restore_same_manager(tmp_path):
    """An async (pipelined) save of step N+1 racing restores of step N on
    the same manager: the restore must see only complete checkpoints and
    every byte must verify after the dust settles."""
    mgr = CheckpointManager(str(tmp_path / "ckpts"), keep=5)
    trees = {s: {"w": np.full((50000,), s, np.float32),
                 "m": np.arange(s * 1000 + 1, dtype=np.float64)}
             for s in (1, 2, 3)}
    mgr.save(1, trees[1], blocking=True)

    stop = threading.Event()
    failures = []

    def reader():
        while not stop.is_set():
            try:
                out, step = mgr.restore_latest()
            except ScdaError as e:  # never acceptable: files are atomic
                failures.append(repr(e))
                return
            w = out["w"]
            if not (w == w[0]).all() or int(w[0]) != step:
                failures.append(f"torn read at step {step}")
                return

    t = threading.Thread(target=reader)
    t.start()
    try:
        for s in (2, 3):
            mgr.save(s, trees[s])  # async, overlapped engine
            mgr.wait()
    finally:
        stop.set()
        t.join()
    assert failures == []
    for s in (1, 2, 3):
        out, step = mgr.restore(s)
        assert step == s
        np.testing.assert_array_equal(out["w"], trees[s]["w"])
        np.testing.assert_array_equal(out["m"], trees[s]["m"])


def test_save_while_restoring_same_file_contents(tmp_path):
    """Pipelined save and pipelined restore share the codec pool; a save
    running while a restore streams the previous checkpoint must corrupt
    neither."""
    a = str(tmp_path / "a.scda")
    b = str(tmp_path / "b.scda")
    tree_a = {"w": np.arange(100000, dtype=np.float32)}
    tree_b = {"w": np.arange(100000, dtype=np.float32) * 2.0}
    pytree_io.save(a, tree_a, compressed=True, chunk_bytes=1 << 14)

    out = {}

    def saver():
        pytree_io.save(b, tree_b, compressed=True, chunk_bytes=1 << 14,
                       write_window=WW)

    def restorer():
        out["a"], _ = pytree_io.restore(a)

    ts = [threading.Thread(target=saver), threading.Thread(target=restorer)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    np.testing.assert_array_equal(out["a"]["w"], tree_a["w"])
    got, _ = pytree_io.restore(b)
    np.testing.assert_array_equal(got["w"], tree_b["w"])
    # and the racing save still produced oracle bytes
    oracle = str(tmp_path / "oracle.scda")
    pytree_io.save(oracle, tree_b, compressed=True, chunk_bytes=1 << 14,
                   write_window=0)
    assert _read(b) == _read(oracle)
