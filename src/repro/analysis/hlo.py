"""Roofline-term extraction from compiled (post-SPMD) HLO text.

``jax.stages.Compiled.cost_analysis()`` counts every while-loop body ONCE —
for scan-over-layers models that understates flops/bytes/collectives by the
layer count (verified experimentally; see EXPERIMENTS.md §Dry-run).  This
module re-derives the three roofline inputs directly from
``compiled.as_text()``:

  * flops             — dot/convolution ops (plus matmul custom-calls),
                        2·M·N·K from the printed shapes & contracting dims,
  * traffic bytes     — Σ (operand + result bytes) over compute
                        instructions, a fusion-granularity memory model,
  * collective bytes  — Σ operand bytes over all-reduce / all-gather /
                        reduce-scatter / all-to-all / collective-permute,
                        with a per-type breakdown,

each multiplied through while-loop bodies by the trip count (taken from the
scheduler's ``backend_config known_trip_count``, falling back to the
condition's comparison constant).  The HLO is the per-device SPMD program,
so every figure is *per chip*.

Caveats (documented in EXPERIMENTS.md §Dry-run): CPU-backend fusion is
finer than TPU's, so the traffic term is an upper bound; flops of
non-matmul elementwise work are excluded (VPU, not MXU, work).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]            [a-z0-9]*)\[([0-9,]*)\]".replace(" ", ""))
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
)
_SKIP_OPCODES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "rng-get-and-update-state", "opt-barrier", "domain",
    "get-dimension-size", "add-dependency", "token",
}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0  # token/opaque
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _shapes_in(text: str) -> List[Tuple[str, str]]:
    return _SHAPE_RE.findall(text)


def _sum_bytes(text: str) -> int:
    return sum(_shape_bytes(dt, dims) for dt, dims in _shapes_in(text))


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result: str      # result type text
    operands: str    # operand list text (names, no types)
    attrs: str       # everything after the closing paren


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]                  # param name → type text
    instructions: List[Instruction]
    types: Dict[str, str]                   # any symbol → result type text


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\(")
_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*([^,]+(?:\[[0-9,]*\][^,]*)?)")


def _parse_instruction(line: str) -> Optional[Instruction]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, result, opcode = m.groups()
    open_idx = line.index(opcode + "(", m.end(2)) + len(opcode)
    depth = 0
    i = open_idx
    for i in range(open_idx, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operands = line[open_idx + 1:i]
    attrs = line[i + 1:]
    return Instruction(name, opcode, result, operands, attrs)


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if current is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _HEADER_RE.match(stripped)
                if m:
                    name, params_text = m.groups()
                    params = {p: t.strip() for p, t
                              in _PARAM_RE.findall(params_text)}
                    current = Computation(name, params, [], dict(params))
                    comps[name] = current
            continue
        if stripped == "}":
            current = None
            continue
        instr = _parse_instruction(line)
        if instr is not None:
            current.instructions.append(instr)
            current.types[instr.name] = instr.result
    return comps


def _entry_name(hlo: str, comps: Dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    if m and m.group(1) in comps:
        return m.group(1)
    return max(comps, key=lambda c: len(comps[c].instructions))


_ATTR_NAME_RE = re.compile(r"(condition|body|to_apply|calls)=\s*%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"[^0-9]*(\d+)')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _called(instr: Instruction) -> Dict[str, str]:
    return dict(_ATTR_NAME_RE.findall(instr.attrs))


def _operand_bytes(ins: Instruction, comp: Computation) -> int:
    total = 0
    for name in _OPERAND_RE.findall(ins.operands):
        t = comp.types.get(name)
        if t:
            total += _sum_bytes(t)
    return total


def _operand_shapes(ins: Instruction, comp: Computation) \
        -> List[List[int]]:
    out = []
    for name in _OPERAND_RE.findall(ins.operands):
        t = comp.types.get(name)
        if t:
            shapes = _shapes_in(t)
            if shapes:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                out.append(dims)
    return out


def _trip_count(ins: Instruction,
                comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(ins.attrs)
    if m:
        return max(1, int(m.group(1)))
    cond = _called(ins).get("condition")
    best = 1
    if cond and cond in comps:
        for ci in comps[cond].instructions:
            if ci.opcode == "constant":
                cm = re.match(r"^(\d+)$", ci.operands.strip())
                if cm:
                    best = max(best, int(cm.group(1)))
    return best


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    res_shapes = _shapes_in(ins.result)
    if not res_shapes:
        return 0.0
    out_elems = 1
    for d in res_shapes[0][1].split(","):
        if d:
            out_elems *= int(d)
    operand_shapes = _operand_shapes(ins, comp)
    if not operand_shapes:
        return 0.0
    lhs = operand_shapes[0] or [1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if m and m.group(1):
        k = 1
        for idx in m.group(1).split(","):
            k *= lhs[int(idx)]
    else:
        k = lhs[-1]
    return 2.0 * out_elems * k


def _custom_call_flops(ins: Instruction, comp: Computation) -> float:
    if not re.search(r"(matmul|dot|gemm)", ins.attrs, re.I):
        return 0.0
    ops = _operand_shapes(ins, comp)
    res = _shapes_in(ins.result)
    if len(ops) < 2 or not res:
        return 0.0
    out = [int(d) for d in res[0][1].split(",") if d]
    lhs, rhs = ops[0], ops[1]
    k = next((d for d in lhs if d in rhs and d not in out),
             lhs[-1] if lhs else 1)
    return 2.0 * math.prod(out) * k


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.traffic_bytes += mult * other.traffic_bytes
        self.collective_bytes += mult * other.collective_bytes
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) + mult * v


def analyze(hlo: str) -> Costs:
    comps = parse_computations(hlo)
    memo: Dict[str, Costs] = {}

    def comp_cost(name: str) -> Costs:
        if name in memo:
            return memo[name]
        memo[name] = Costs()  # break cycles defensively
        comp = comps.get(name)
        total = Costs()
        if comp is None:
            return total
        for ins in comp.instructions:
            called = _called(ins)
            if ins.opcode == "while":
                body = called.get("body")
                trips = _trip_count(ins, comps)
                if body:
                    total.add(comp_cost(body), mult=trips)
                continue
            if ins.opcode == "fusion":
                # memory model: the fusion's operand/result traffic;
                # flops & collectives: whatever got fused inside
                total.traffic_bytes += _operand_bytes(ins, comp) \
                    + _sum_bytes(ins.result)
                sub = called.get("calls")
                if sub:
                    inner = comp_cost(sub)
                    total.flops += inner.flops
                    total.collective_bytes += inner.collective_bytes
                continue
            if ins.opcode in ("call", "conditional"):
                for sub in called.values():
                    total.add(comp_cost(sub))
                continue
            if ins.opcode in _SKIP_OPCODES:
                continue
            base = next((c for c in _COLLECTIVES
                         if ins.opcode.startswith(c)), None)
            if base is not None:
                if ins.opcode.endswith("-done"):
                    continue  # counted at -start
                nbytes = _operand_bytes(ins, comp)
                total.collective_bytes += nbytes
                total.by_collective[base] = \
                    total.by_collective.get(base, 0.0) + nbytes
                total.traffic_bytes += nbytes + _sum_bytes(ins.result)
                continue
            if ins.opcode == "dot":
                total.flops += _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                total.flops += _dot_flops(ins, comp)
            elif ins.opcode == "custom-call":
                total.flops += _custom_call_flops(ins, comp)
            total.traffic_bytes += _operand_bytes(ins, comp) \
                + _sum_bytes(ins.result)
        memo[name] = total
        return total

    return comp_cost(_entry_name(hlo, comps))


# -- hardware model (TPU v5e-class, constants per the project brief) -------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def roofline_terms(costs: Costs) -> Dict[str, float]:
    compute_s = costs.flops / PEAK_FLOPS
    memory_s = costs.traffic_bytes / HBM_BW
    collective_s = costs.collective_bytes / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "step_s_lower_bound": max(compute_s, memory_s, collective_s),
    }
