"""Divisibility-aware sharding rules: FSDP + TP (+ EP/SP) over the mesh.

Rather than hand-writing PartitionSpecs per architecture, parameters carry
*logical* roles inferred from their tree path and shape; ``spec_for``
assigns mesh axes with divisibility checks and graceful fallback (e.g.
granite's 49155-row vocab cannot take the 16-way model axis → the embedding
shards on d_model instead; its 40 experts likewise fall back to
expert-internal TP).  This is what makes every (arch × mesh) cell lower
without per-arch special cases — and why the same rules hold on 256 or 512
chips.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# Ambient mesh policy — lets model code place activation constraints without
# threading mesh objects through every function.  No mesh set → no-ops, so
# tests and single-device runs are unaffected.
# --------------------------------------------------------------------------

import dataclasses
import threading


@dataclasses.dataclass
class Policy:
    mesh: Optional[Mesh] = None
    #: decode attention merges partial softmax over this axis via shard_map
    #: when the KV cache is sequence-sharded (long-context SP decode).
    sp_decode_axis: Optional[str] = None


_POLICY = threading.local()


def set_mesh(mesh: Optional[Mesh], sp_decode_axis: Optional[str] = None):
    _POLICY.value = Policy(mesh=mesh, sp_decode_axis=sp_decode_axis)


def get_policy() -> Policy:
    return getattr(_POLICY, "value", None) or Policy()


def model_axis_size() -> int:
    mesh = get_policy().mesh
    return int(mesh.shape[MODEL_AXIS]) if mesh is not None else 1


def constrain(x, *logical):
    """with_sharding_constraint by logical dim roles.

    Roles per dim: None (unsharded), "batch" (data axes), "model", or
    "seq_model"/"seq_data" for sequence-parallel layouts.  Roles whose mesh
    axes do not divide the dim are dropped (correctness first).
    """
    policy = get_policy()
    mesh = policy.mesh
    if mesh is None:
        return x
    spec = []
    for dim, role in zip(x.shape, logical):
        if role is None:
            spec.append(None)
            continue
        if role == "batch":
            axes = data_axes(mesh)
            ax = axes if len(axes) > 1 else axes[0]
        elif role == "model" or role == "seq_model":
            ax = MODEL_AXIS
        elif role == "seq_data":
            axes = data_axes(mesh)
            ax = axes if len(axes) > 1 else axes[0]
        else:
            raise ValueError(role)
        size = axis_size(mesh, ax)
        spec.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def padded_heads(n_heads: int) -> int:
    """Round the head count up to a model-axis multiple (forward-time pad).

    Archs whose head count does not divide the 16-way model axis (gemma3's
    8, llama4's 40, granite's 24) get zero-weight phantom heads so the
    uniform head-parallel attention layout applies everywhere; the phantom
    heads' wo rows are zero, so outputs are exact.  The flop overhead is
    visible in the roofline's useful-flop ratio.
    """
    m = model_axis_size()
    if m <= 1 or n_heads % m == 0:
        return n_heads
    return ((n_heads + m - 1) // m) * m


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch/FSDP axes: ('pod', 'data') when multi-pod, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


MODEL_AXIS = "model"


def _divisible(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def best_spec(mesh: Mesh, shape: Sequence[int],
              prefer_model: Sequence[int],
              prefer_data: Sequence[int] = (),
              skip: Sequence[int] = ()) -> P:
    """Assign mesh axes to tensor dims.

    ``prefer_model``: dim indices to try for the model (TP) axis, in order.
    ``prefer_data``: dim indices to try for the FSDP axes (defaults to all
    dims, largest first, excluding the model dim).
    Dims that do not divide are skipped — correctness first.
    """
    ndim = len(shape)
    assign: Dict[int, Any] = {}
    msize = axis_size(mesh, MODEL_AXIS)
    model_dim = None
    for d in prefer_model:
        if d < ndim and d not in skip and _divisible(shape[d], msize):
            assign[d] = MODEL_AXIS
            model_dim = d
            break
    daxes = data_axes(mesh)
    dsize = axis_size(mesh, daxes)
    cand = list(prefer_data) or sorted(
        range(ndim), key=lambda i: -shape[i])
    for d in cand:
        if d < ndim and d != model_dim and d not in skip \
                and _divisible(shape[d], dsize):
            assign[d] = daxes if len(daxes) > 1 else daxes[0]
            break
    return P(*[assign.get(i) for i in range(ndim)])


# --------------------------------------------------------------------------
# Parameter rules by tree-path pattern (order matters: first match wins)
# --------------------------------------------------------------------------
# Stacked layer params carry a leading n_layers dim (never sharded); the
# rule's dim indices are *relative to the unstacked tensor*.

_RULES = [
    # attention projections (d_model, H, hd) — TP on heads, hd fallback
    (re.compile(r"(attn|cross)/w[qkv]$"), dict(model=[1, 2], data=[0])),
    (re.compile(r"(attn|cross)/wo$"), dict(model=[0, 1], data=[2])),
    # MoE: experts first (EP), else expert-internal d_ff TP
    (re.compile(r"moe/router$"), dict(model=[1], data=[0])),
    (re.compile(r"moe/w_(gate|up)$"), dict(model=[0, 2], data=[1])),
    (re.compile(r"moe/w_down$"), dict(model=[0, 1], data=[2])),
    (re.compile(r"shared/w_(gate|up)$"), dict(model=[1], data=[0])),
    (re.compile(r"shared/w_down$"), dict(model=[0], data=[1])),
    # dense MLPs — TP on d_ff
    (re.compile(r"mlp/w_(gate|up)$"), dict(model=[1], data=[0])),
    (re.compile(r"mlp/w_down$"), dict(model=[0], data=[1])),
    # SSM: TP on d_inner (projections) / heads
    (re.compile(r"ssm/in_[xz]$"), dict(model=[1], data=[0])),
    (re.compile(r"ssm/in_(B|C|dt)$"), dict(model=[], data=[0])),
    (re.compile(r"ssm/out_proj$"), dict(model=[0], data=[1])),
    (re.compile(r"ssm/x_proj$"), dict(model=[0], data=[1])),
    (re.compile(r"ssm/dt_proj$"), dict(model=[1], data=[0])),
    (re.compile(r"ssm/(conv_w|conv_b|A_log|D|dt_bias|norm)$"),
     dict(model=[0], data=[])),
    # embeddings / unembeddings — vocab first, d_model fallback
    (re.compile(r"^embed$"), dict(model=[0, 1], data=[1, 0])),
    (re.compile(r"^lm_head$"), dict(model=[1, 0], data=[0, 1])),
    (re.compile(r"^mm_proj$"), dict(model=[1], data=[0])),
    # norms and 1-D params: replicated
    (re.compile(r"(ln\w*|norm|final_norm|enc_norm)$"), dict(model=[], data=[])),
]


def param_spec(mesh: Mesh, name: str, shape: Sequence[int],
               stacked: bool) -> P:
    """PartitionSpec for a (possibly layer-stacked) parameter."""
    off = 1 if stacked else 0
    inner = shape[off:]
    for pat, rule in _RULES:
        if pat.search(name):
            spec = best_spec(mesh, inner, rule["model"], rule["data"])
            return P(*([None] * off), *spec)
    # default: FSDP on the largest divisible dim
    spec = best_spec(mesh, inner, prefer_model=[])
    return P(*([None] * off), *spec)


def params_shardings(mesh: Mesh, abstract_params) -> Any:
    """NamedShardings for a whole (possibly stacked) param tree."""
    from repro.checkpoint.pytree_io import flatten_named
    named, treedef = flatten_named(abstract_params)
    out = []
    for name, leaf in named:
        stacked = name.startswith(("layers/", "enc_layers/"))
        short = name.split("/", 1)[1] if stacked else name
        spec = param_spec(mesh, short, leaf.shape, stacked)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------------
# Activation / input / cache shardings
# --------------------------------------------------------------------------

def batch_spec(mesh: Mesh, ndim: int, batch_divisible: bool = True) -> P:
    """Shard dim 0 on the data axes (the DP rule for tokens/labels)."""
    daxes = data_axes(mesh)
    ax = daxes if len(daxes) > 1 else daxes[0]
    return P(*((ax,) + (None,) * (ndim - 1)))


def input_shardings(mesh: Mesh, kind: str, cfg, shape_cfg) -> Dict[str, Any]:
    """NamedShardings for the step inputs of a given cell kind."""
    daxes = data_axes(mesh)
    dsize = axis_size(mesh, daxes)
    dax = daxes if len(daxes) > 1 else daxes[0]
    msize = axis_size(mesh, MODEL_AXIS)
    out: Dict[str, P] = {}
    B = shape_cfg.global_batch
    batch = dax if B % dsize == 0 else None

    if kind == "train":
        out["tokens"] = P(batch, None)
        out["labels"] = P(batch, None)
        if cfg.family == "vlm":
            out["patch_embeds"] = P(batch, None, MODEL_AXIS
                                    if cfg.d_model % msize == 0 else None)
        if cfg.family == "encdec":
            out["enc_embeds"] = P(batch, None, None)
        return {k: NamedSharding(mesh, v) for k, v in out.items()}

    # decode: cache shardings
    out["tokens"] = P(batch, None)
    hd, Hkv = cfg.head_dim_, cfg.n_kv_heads
    # KV cache (L, B, S, Hkv, hd): batch on data when divisible, else
    # sequence-parallel (SP) cache sharding on data; heads/head_dim on model
    if Hkv and Hkv % msize == 0:
        kv_model_dim = 3
    elif hd % msize == 0:
        kv_model_dim = 4
    else:
        kv_model_dim = None
    kv = [None] * 5
    if batch is not None:
        kv[1] = dax
    else:
        kv[2] = dax          # SP: shard cache sequence dim (long_500k)
    if kv_model_dim is not None:
        kv[kv_model_dim] = MODEL_AXIS
    out["cache_k"] = P(*kv)
    out["cache_v"] = P(*kv)
    # SSM state (L, B, ...): batch on data; d_inner/heads dim on model
    if cfg.ssm_type == "mamba1":
        # h: (L,B,di,N), conv: (L,B,K-1,di)
        out["ssm_h"] = P(None, batch,
                         MODEL_AXIS if cfg.d_inner % msize == 0 else None,
                         None)
        out["ssm_conv"] = P(None, batch, None,
                            MODEL_AXIS if cfg.d_inner % msize == 0 else None)
    elif cfg.ssm_type == "mamba2":
        # h: (L,B,H,N,P), conv: (L,B,K-1,di)
        out["ssm_h"] = P(None, batch,
                         MODEL_AXIS if cfg.ssm_heads % msize == 0 else None,
                         None, None)
        out["ssm_conv"] = P(None, batch, None,
                            MODEL_AXIS if cfg.d_inner % msize == 0 else None)
    if cfg.family == "encdec":
        out["enc_out"] = P(batch, None, None)
    return {k: NamedSharding(mesh, v) for k, v in out.items()}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
