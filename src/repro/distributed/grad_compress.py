"""Gradient compression with error feedback for cross-pod reductions.

At 1000+ nodes the pod axis rides DCN, an order of magnitude slower than
ICI — halving reduction bytes there is a direct step-time win.  We cast
gradients to bf16 *before* the (XLA-inserted) all-reduce and keep the
quantization residual in an f32 error-feedback accumulator, folding it into
the next step — the standard trick that keeps convergence intact (1-bit
Adam / EF-SGD lineage).

Usage:
    ef = init_error_feedback(params)
    grads, ef = compress_with_feedback(grads, ef)
    # hand `grads` to the optimizer as usual

``compress_grads`` (stateless bf16 round-trip) is the cheap default wired
into ``make_train_step(grad_transform=...)``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_grads(grads):
    """Stateless bf16 round-trip: halves reduction bytes for f32 grads."""
    return jax.tree_util.tree_map(
        lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, residual) -> Tuple[Any, Any]:
    """bf16-compress (g + residual); carry the quantization error forward."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        sent = target.astype(jnp.bfloat16).astype(jnp.float32)
        return sent.astype(g.dtype), target - sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_r = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, new_r
