"""Model configuration schema covering all assigned architecture families.

One frozen dataclass describes dense GQA transformers, MoE, pure SSM
(Mamba1/2), hybrid SSM+attention, encoder-decoder (audio), and VLM-stub
variants.  Every assigned arch is a concrete instance in a sibling module;
``smoke(cfg)`` derives the reduced CPU-testable variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int

    # -- attention ----------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0            # 0 → d_model // n_heads
    qk_norm: bool = False
    attn_window: int = 0         # sliding-window width for local layers
    local_global_pattern: int = 0  # k → k local layers then 1 global; 0 = all global
    rope_base: float = 10_000.0

    # -- mlp ------------------------------------------------------------------
    d_ff: int = 0
    mlp_type: str = "swiglu"     # swiglu | geglu | relu2 | gelu

    # -- moe ------------------------------------------------------------------
    n_experts: int = 0
    experts_top_k: int = 0
    moe_every: int = 1           # MoE block every k-th layer (1 = every layer)
    shared_expert: bool = False  # llama4-style always-on shared FFN
    capacity_factor: float = 1.25

    # -- ssm ------------------------------------------------------------------
    ssm_type: str = "none"       # none | mamba1 | mamba2
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64       # mamba2 head width
    shared_attn_every: int = 0   # hybrid: shared attn block cadence (zamba2)

    # -- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    max_source_len: int = 1500   # audio frames after the (stubbed) conv frontend

    # -- modality frontend stubs -------------------------------------------------
    frontend: str = "none"       # none | audio | vision
    num_patches: int = 0         # vision prefix length (anyres tiles)

    # -- embeddings / numerics -----------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # -- derived ----------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_is_global(self, i: int) -> bool:
        """Local:global attention pattern (gemma3: 5 local then 1 global)."""
        if self.local_global_pattern == 0 or self.attn_window == 0:
            return True
        return (i + 1) % (self.local_global_pattern + 1) == 0

    def layer_is_moe(self, i: int) -> bool:
        return self.is_moe and (i % self.moe_every == 0)

    def param_count(self) -> int:
        """Approximate parameter count N for MODEL_FLOPS = 6·N·D."""
        d, v = self.d_model, self.vocab
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim_
        attn = 0
        if self.has_attention:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
        ff_mult = {"swiglu": 3, "geglu": 3, "relu2": 2, "gelu": 2}[self.mlp_type]
        dense_ff = ff_mult * d * self.d_ff
        ssm = 0
        if self.ssm_type != "none":
            di, n = self.d_inner, self.ssm_state
            ssm = 2 * d * di + di * d          # in_proj(x,z) + out_proj
            ssm += di * self.ssm_conv
            if self.ssm_type == "mamba1":
                dt_rank = max(1, d // 16)
                ssm += di * n + di * 2         # A, D + dt bias-ish
                ssm += di * (dt_rank + 2 * n) + dt_rank * di
            else:
                ssm += d * (2 * n + 2 * self.ssm_heads) + self.ssm_heads * 2
        per_layer = 0
        moe_layers = sum(1 for i in range(self.n_layers)
                         if self.layer_is_moe(i))
        dense_layers = self.n_layers - moe_layers
        if self.family in ("dense", "encdec", "vlm"):
            per = attn + dense_ff
            total += self.n_layers * per
            if self.family == "encdec":
                total += self.encoder_layers * (attn + dense_ff)
                total += self.n_layers * attn  # cross attention
        elif self.family == "moe":
            moe_ff = ff_mult * d * self.d_ff * self.n_experts + d * self.n_experts
            if self.shared_expert:
                moe_ff += dense_ff
            total += moe_layers * (attn + moe_ff) + dense_layers * (attn + dense_ff)
        elif self.family == "ssm":
            total += self.n_layers * ssm
        elif self.family == "hybrid":
            total += self.n_layers * ssm
            if self.shared_attn_every:
                total += attn  # one shared block
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        ff_mult = {"swiglu": 3, "geglu": 3, "relu2": 2, "gelu": 2}[self.mlp_type]
        inactive = (self.n_experts - self.experts_top_k) * ff_mult * d * self.d_ff
        moe_layers = sum(1 for i in range(self.n_layers)
                         if self.layer_is_moe(i))
        return self.param_count() - moe_layers * inactive


def smoke(cfg: ModelConfig) -> ModelConfig:
    """The reduced same-family variant used by CPU smoke tests."""
    n_layers = min(cfg.n_layers, 4 if cfg.shared_attn_every else 2)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        vocab=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=2 if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        n_experts=min(cfg.n_experts, 4),
        experts_top_k=min(cfg.experts_top_k, 2),
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_type == "mamba2" else cfg.ssm_head_dim,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        max_source_len=16 if cfg.encoder_layers else cfg.max_source_len,
        num_patches=8 if cfg.num_patches else 0,
        attn_window=min(cfg.attn_window, 8) if cfg.attn_window else 0,
        local_global_pattern=min(cfg.local_global_pattern, 1),
        dtype="float32",
    )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
