"""llava-next-mistral-7b — VLM: mistral-7B backbone + anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

32L, d_model 4096, 32H GQA kv=8 (head_dim 128), swiglu d_ff 14336,
vocab 32000.  Vision frontend is a STUB: input_specs() provides
precomputed patch embeddings (B, 2880, 4096) = 5 anyres tiles x 576.
long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    vocab=32_000,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    rope_base=1_000_000.0,
    d_ff=14_336,
    mlp_type="swiglu",
    frontend="vision",
    num_patches=2880,
    tie_embeddings=False,
)
