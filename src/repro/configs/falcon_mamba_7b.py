"""falcon-mamba-7b — pure Mamba1 SSM, attention-free [arXiv:2410.05355;
unverified].

64L, d_model 4096 (d_inner 8192), state 16, conv 4, vocab 65024.
Runs long_500k: decode state is O(1) in sequence length.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    vocab=65_024,
    d_ff=0,
    ssm_type="mamba1",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
)
