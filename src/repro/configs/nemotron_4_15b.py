"""nemotron-4-15b — dense GQA with squared-ReLU MLP [arXiv:2402.16819;
unverified].

32L, d_model 6144, 48H GQA kv=8 (head_dim 128), squared-ReLU d_ff 24576,
vocab 256000, untied embeddings.  long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    vocab=256_000,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    mlp_type="relu2",
    tie_embeddings=False,
)
