"""whisper-medium — encoder-decoder audio transformer [arXiv:2212.04356;
unverified].

24 encoder + 24 decoder layers, d_model 1024, 16H MHA (kv=16, head_dim 64),
gelu d_ff 4096, vocab 51865.  The conv frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, 1500, 1024).  Decode shapes
exercise the decoder with self-attn KV cache + cross-attention.
long_500k skipped (full attention, 448-token decoder by design).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    encoder_layers=24,
    d_model=1024,
    vocab=51_865,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    mlp_type="gelu",
    max_source_len=1500,
    frontend="audio",
    tie_embeddings=True,
)
