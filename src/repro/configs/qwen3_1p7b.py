"""qwen3-1.7b — dense GQA with qk-norm [hf:Qwen/Qwen3-8B; hf].

28L, d_model 2048, 16H GQA kv=8 (head_dim 128), swiglu d_ff 6144,
vocab 151936.  long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    vocab=151_936,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    rope_base=1_000_000.0,
    d_ff=6144,
    mlp_type="swiglu",
    tie_embeddings=True,
)
