"""granite-moe-3b-a800m — MoE 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

32L, d_model 1536, 24H GQA kv=8 (head_dim 64), expert d_ff 512,
40 experts top-8, vocab 49155.  40 experts do not divide the 16-way model
axis -> expert-internal TP on d_ff instead of EP (DESIGN.md §6); vocab
49155 is odd -> embedding sharded on d_model.
long_500k skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    vocab=49_155,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    mlp_type="swiglu",
    n_experts=40,
    experts_top_k=8,
    tie_embeddings=True,
)
