"""Assigned architecture registry: ``get_config("--arch id")`` per cell.

Each assigned architecture lives in its own module with the exact published
configuration; ``REGISTRY`` maps the public ``--arch`` ids to them.
"""
from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                ModelConfig, PREFILL_32K, ShapeConfig,
                                TRAIN_4K, smoke)

from repro.configs.zamba2_2p7b import CONFIG as zamba2_2p7b
from repro.configs.gemma3_4b import CONFIG as gemma3_4b
from repro.configs.yi_6b import CONFIG as yi_6b
from repro.configs.nemotron_4_15b import CONFIG as nemotron_4_15b
from repro.configs.qwen3_1p7b import CONFIG as qwen3_1p7b
from repro.configs.falcon_mamba_7b import CONFIG as falcon_mamba_7b
from repro.configs.whisper_medium import CONFIG as whisper_medium
from repro.configs.llava_next_mistral_7b import CONFIG as llava_next_mistral_7b
from repro.configs.llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from repro.configs.granite_moe_3b_a800m import CONFIG as granite_moe_3b_a800m

REGISTRY = {
    "zamba2-2.7b": zamba2_2p7b,
    "gemma3-4b": gemma3_4b,
    "yi-6b": yi_6b,
    "nemotron-4-15b": nemotron_4_15b,
    "qwen3-1.7b": qwen3_1p7b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "whisper-medium": whisper_medium,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
}

SHAPES = {s.name: s for s in ALL_SHAPES}

#: archs with sub-quadratic sequence handling run the long_500k cell;
#: pure full-attention archs skip it (documented in DESIGN.md §6).
SUBQUADRATIC = {"zamba2-2.7b", "falcon-mamba-7b"}


def get_config(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; choose from "
                       f"{sorted(REGISTRY)}") from None


def cells():
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for arch in REGISTRY:
        for shape in ALL_SHAPES:
            if shape.name == "long_500k" and arch not in SUBQUADRATIC:
                continue
            out.append((arch, shape.name))
    return out


__all__ = ["REGISTRY", "SHAPES", "SUBQUADRATIC", "ModelConfig",
           "ShapeConfig", "get_config", "cells", "smoke",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "ALL_SHAPES"]
