"""zamba2-2.7b — hybrid Mamba2 + shared attention [arXiv:2411.15242; hf].

54 Mamba2 blocks (d_model 2560, state 64) with one *shared* GQA attention
block (32H, MHA kv=32, head_dim 80) applied every 6 blocks (9 applications;
params shared, KV caches per application).  Runs the long_500k cell: the
SSM state is O(1) in sequence length and the shared-attention KV cache is
sequence-sharded (SP).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    vocab=32_000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=0,
    ssm_type="mamba2",
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
    tie_embeddings=True,
)
