"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L, d_model 5120, 40H GQA kv=8 (head_dim 128), expert d_ff 8192,
16 routed experts top-1 + always-on shared expert, vocab 202048.
True expert parallelism: 16 experts = 16-way model axis.
long_500k skipped (chunked-attention variant not modeled).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    vocab=202_048,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    rope_base=500_000.0,
    d_ff=8192,
    mlp_type="swiglu",
    n_experts=16,
    experts_top_k=1,
    shared_expert=True,
    tie_embeddings=False,
)
