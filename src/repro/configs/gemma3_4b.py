"""gemma3-4b — dense, 5:1 local:global attention, 128k ctx
[hf:google/gemma-3-1b-pt; unverified].

34 layers, d_model 2560, 8 query heads (GQA kv=4) with head_dim 256
(attention width 2048 != d_model, as in gemma), geglu d_ff 10240,
262144-entry vocabulary, qk-norm, sliding window 1024 on local layers.
long_500k skipped: the global layers are full attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    vocab=262_144,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    qk_norm=True,
    attn_window=1024,
    local_global_pattern=5,
    rope_base=1_000_000.0,
    d_ff=10_240,
    mlp_type="geglu",
    tie_embeddings=True,
)
