from repro.train import step, loop
