"""Train / serve step builders — the jitted units the launcher lowers.

``make_train_step(cfg, opt)`` returns a pure function
    (params, opt_state, batch) → (params, opt_state, metrics)
with the sequence-chunked loss head, and ``make_serve_step(cfg)`` the
decode step (cache-functional).  Gradient compression for the cross-pod
reduction is a wrapper from ``repro.distributed.grad_compress``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, opt: adamw.AdamWConfig,
                    loss_chunk: int = 256,
                    grad_transform: Optional[Callable] = None):
    """Build the fused loss+grad+update step."""

    def loss_fn(params, batch):
        kw = {}
        if "patch_embeds" in batch:
            kw["patch_embeds"] = batch["patch_embeds"]
        if "enc_embeds" in batch:
            kw["enc_embeds"] = batch["enc_embeds"]
        return lm.lm_loss(cfg, params, batch["tokens"], batch["labels"],
                          loss_chunk=loss_chunk, **kw)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, stats = adamw.update(opt, grads, opt_state,
                                                params)
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return step


def make_eval_step(cfg: ModelConfig, loss_chunk: int = 256):
    def step(params, batch):
        kw = {k: batch[k] for k in ("patch_embeds", "enc_embeds")
              if k in batch}
        return lm.lm_loss(cfg, params, batch["tokens"], batch["labels"],
                          loss_chunk=loss_chunk, **kw)
    return step


def make_prefill_step(cfg: ModelConfig):
    """Full-sequence forward (prompt ingestion): tokens → last-token logits.

    This is the compute shape of serving prefill; see EXPERIMENTS.md
    §Dry-run for the cache-write accounting.
    """
    def step(params, batch):
        kw = {k: batch[k] for k in ("patch_embeds", "enc_embeds")
              if k in batch}
        hidden, _ = lm.forward_hidden(cfg, params, batch["tokens"], **kw)
        return lm.unembed(cfg, params, hidden[:, -1:, :])[:, 0, :]
    return step


def make_serve_step(cfg: ModelConfig):
    def step(params, cache, tokens):
        return lm.serve_step(cfg, params, cache, tokens)
    return step
