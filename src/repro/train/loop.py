"""The fault-tolerant training loop: restore-or-init, step, async checkpoint.

Every run is a restart: boot always goes through
``CheckpointManager.restore_or_init`` so a fresh start, a crash recovery,
and an elastic resize are the same code path (the scda serial-equivalence
guarantee is what makes the third case trivial).  Checkpoint failures are
caught and logged — the paper's §A.6 "file errors should never crash the
simulation" — while training continues.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import init_lm
from repro.optim import adamw
from repro.train.step import make_train_step

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro-ckpts"
    ckpt_keep: int = 3
    ckpt_compressed: bool = False
    log_every: int = 10
    seed: int = 0
    grad_compress: bool = False


def train(cfg: ModelConfig, loop: TrainLoopConfig,
          opt_cfg: Optional[adamw.AdamWConfig] = None,
          data: Optional[SyntheticTokens] = None,
          mesh=None,
          seq_len: int = 128, global_batch: int = 8,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict[str, Any]:
    """Run (or resume) a training job; returns final metrics + state."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=loop.total_steps)
    data = data or SyntheticTokens(DataConfig(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=loop.seed))
    hooks = hooks or {}
    if mesh is not None:
        from repro.distributed import sharding as sh
        sh.set_mesh(mesh)

    grad_transform = None
    if loop.grad_compress:
        from repro.distributed.grad_compress import compress_grads
        grad_transform = compress_grads

    loss_chunk = min(256, data.cfg.seq_len)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, loss_chunk=loss_chunk,
                                      grad_transform=grad_transform),
                      donate_argnums=(0, 1))

    mgr = CheckpointManager(loop.ckpt_dir, keep=loop.ckpt_keep,
                            compressed=loop.ckpt_compressed)

    def init_state():
        params = init_lm(cfg, jax.random.PRNGKey(loop.seed))
        return {"params": params, "opt": adamw.init(params)}

    # like = the abstract state tree: restore rebuilds the exact structure
    # (incl. the optimizer NamedTuple) under any current topology.
    state, start_step = mgr.restore_or_init(
        init_state, like=jax.eval_shape(init_state))
    if start_step >= 0:
        log.info("resumed from checkpoint at step %d", start_step)
    metrics: Dict[str, Any] = {}
    losses = []
    t0 = time.time()
    for step in range(start_step + 1, loop.total_steps):
        batch = data.sharded_batch(step, mesh)
        params, opt, metrics = step_fn(state["params"], state["opt"], batch)
        state = {"params": params, "opt": opt}
        losses.append(float(metrics["loss"]))
        if step % loop.log_every == 0 or step == loop.total_steps - 1:
            log.info("step %d loss %.4f gnorm %.3f lr %.2e (%.2fs)",
                     step, float(metrics["loss"]),
                     float(metrics["grad_norm"]), float(metrics["lr"]),
                     time.time() - t0)
        if "on_step" in hooks:
            hooks["on_step"](step, state, metrics)
        if loop.ckpt_every and step % loop.ckpt_every == 0 and step > 0:
            try:
                mgr.save(step, state)
            except Exception as e:  # noqa: BLE001 — never crash the job
                log.error("checkpoint save failed (continuing): %s", e)
        if "should_die" in hooks and hooks["should_die"](step):
            # failure-injection hook used by tests/examples
            mgr.wait()
            raise SystemExit(f"injected failure at step {step}")
    try:
        mgr.save(loop.total_steps - 1, state, blocking=True)
    except Exception as e:  # noqa: BLE001
        log.error("final checkpoint failed: %s", e)
    return {"state": state, "metrics": metrics, "losses": losses,
            "start_step": start_step, "manager": mgr}
