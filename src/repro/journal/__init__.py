"""Streaming journal subsystem — telemetry appended into scda archives.

    from repro.journal import ScdaJournal, read_records

    j = ScdaJournal("run/step_0000000500.scda")
    j.log(step, {"loss": 1.25, "lr": 3e-4})
    ...
    j.flush()                       # one framed varray section per flush

    for rec in read_records("run/step_0000000500.scda"):
        print(rec["step"], rec["data"])

Built entirely on mode-'a' appends (:func:`repro.core.fopen_append`), so
a journaled archive remains byte-identical to one a single serial session
would have written, and every format tool (``scdatool ls/fsck/verify/
tail``) understands it.
"""
from repro.journal.journal import (JOURNAL_USER_STRING, RECORD_VERSION,
                                   DEFAULT_FLUSH_RECORDS, ScdaJournal,
                                   decode_record, encode_record,
                                   flatten_scalars, iter_records,
                                   journal_flush_records, read_records)

__all__ = [
    "JOURNAL_USER_STRING", "RECORD_VERSION", "DEFAULT_FLUSH_RECORDS",
    "ScdaJournal", "decode_record", "encode_record", "flatten_scalars",
    "iter_records", "journal_flush_records", "read_records",
]
