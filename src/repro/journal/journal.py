"""Streaming journal on appendable scda archives.

Long-running training jobs emit two data streams: big, periodic state
snapshots (checkpoints) and a small, continuous trickle of telemetry —
loss curves, learning rates, eval scalars, wall-clock marks.  Historically
the trickle lands in ad-hoc side files; this module streams it *into the
same scda archive the checkpoint lives in* (cf. Lemon's LIME records and
H5MD's in-place time-series groups), so one file carries the state AND the
story of how it got there, inspectable with the ordinary format tools.

Mechanics: :meth:`ScdaJournal.log` buffers records in memory;
:meth:`ScdaJournal.flush` opens the target archive in mode 'a'
(:func:`repro.core.writer.fopen_append` — tail-validated, byte-identical
to a longer serial session) and writes the buffered batch as ONE framed
varray section (user string ``"scda-journal 00"``, one JSON record per
element), then refreshes the ``.scdax`` sidecar incrementally and
atomically so ``seek_section``/lazy restores never see a torn index.
Auto-flush every ``REPRO_SCDA_JOURNAL_FLUSH`` records (default 64; 0 =
explicit flush only).  A previous flush torn by a crash is healed on the
next one (``recover=True`` truncates back to the last valid section
boundary — whole-section framing means a record is either fully on disk
or not at all).

Records are JSON objects ``{"v": 1, "step": <int|None>, "data": {name:
scalar}}``; pytrees of scalars flatten to '/'-joined names exactly like
checkpoint leaves.  ``scdatool tail`` prints them; ``iter_records`` /
``read_records`` are the library mirror.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.index import ScdaIndex
from repro.core.reader import fopen_read
from repro.core.writer import fopen_append

#: Section user string identifying journal sections inside any archive.
JOURNAL_USER_STRING = b"scda-journal 00"
#: Record schema version (the "v" key of every record).
RECORD_VERSION = 1
#: Default auto-flush threshold (records); env-overridable.
DEFAULT_FLUSH_RECORDS = 64


def journal_flush_records() -> int:
    """The effective auto-flush threshold, read from the environment per
    call (``REPRO_SCDA_JOURNAL_FLUSH``; 0 disables auto-flush)."""
    raw = os.environ.get("REPRO_SCDA_JOURNAL_FLUSH", "")
    try:
        return max(0, int(raw)) if raw else DEFAULT_FLUSH_RECORDS
    except ValueError:
        return DEFAULT_FLUSH_RECORDS


def _scalar(name: str, value: Any):
    """Coerce one leaf to a JSON scalar; reject anything with extent."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    try:
        arr = np.asarray(value)  # numpy/jax scalars and 0-d arrays
    except Exception:
        arr = None
    if arr is not None and arr.ndim == 0:
        return arr.item()
    raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                    f"journal record leaf {name!r} is not a scalar "
                    f"({type(value).__name__})")


def flatten_scalars(tree: Any) -> Dict[str, Any]:
    """Flatten a pytree of scalars to '/'-joined names (dicts and
    lists/tuples recurse; everything else must be a JSON-able scalar,
    numpy/jax 0-d arrays included).  No jax import — the journal stays
    usable from pure-numpy telemetry code."""
    out: Dict[str, Any] = {}

    def walk(prefix: str, obj: Any) -> None:
        if isinstance(obj, dict):
            for k in sorted(obj, key=str):
                walk(f"{prefix}/{k}" if prefix else str(k), obj[k])
        elif isinstance(obj, (list, tuple)):
            for i, v in enumerate(obj):
                walk(f"{prefix}/{i}" if prefix else str(i), v)
        else:
            out[prefix or "."] = _scalar(prefix or ".", obj)

    walk("", tree)
    return out


def encode_record(step: Optional[int], scalars: Any) -> bytes:
    """One journal record (a varray element) as canonical JSON bytes."""
    doc = {"v": RECORD_VERSION,
           "step": None if step is None else int(step),
           "data": flatten_scalars(scalars)}
    return json.dumps(doc, sort_keys=True,
                      separators=(",", ":")).encode("ascii")


def decode_record(raw: bytes) -> Dict[str, Any]:
    try:
        doc = json.loads(raw.decode("utf-8"))
    except (ValueError, UnicodeDecodeError) as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"journal record: {e}") from e
    if not isinstance(doc, dict) or "data" not in doc:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "journal record is not a {step, data} object")
    return doc


class ScdaJournal:
    """Buffered telemetry writer appending to one scda archive.

    ``path`` may be None at construction (a training run that has not
    committed its first checkpoint yet): records buffer until
    :meth:`retarget` points the journal at a file.  The journal is a
    rank-0 facility — metrics are replicated, so exactly one process
    should flush (the checkpoint manager wires this up).

    ``flush_records=None`` takes ``REPRO_SCDA_JOURNAL_FLUSH`` (default
    64; 0 = explicit :meth:`flush` only).  ``update_sidecar`` refreshes
    the ``.scdax`` atomically after each flush (suffix-only scan, CRCs
    preserved); ``sync`` makes each flush a durable collective close.
    ``enabled=False`` turns the journal into an inert sink (log and
    flush are no-ops) — what the manager hands every rank but 0, so
    replicated training code can log unconditionally without non-root
    ranks buffering unboundedly or double-appending.
    """

    def __init__(self, path: Optional[str] = None, *,
                 flush_records: Optional[int] = None,
                 sync: bool = False,
                 update_sidecar: bool = True,
                 enabled: bool = True) -> None:
        self.path = path
        self.flush_records = journal_flush_records() \
            if flush_records is None else max(0, int(flush_records))
        self.sync = sync
        self.update_sidecar = update_sidecar
        self.enabled = enabled
        self._buf: List[bytes] = []
        # One lock serializes log/flush/retarget: the checkpoint manager
        # flushes from its ASYNC save thread (flush-on-commit) while the
        # training thread keeps logging — without it two flushes could
        # append at the same resume cursor (torn tail) and records logged
        # mid-flush could be dropped with the swapped-out buffer.
        self._lock = threading.RLock()

    # -- writing ---------------------------------------------------------------
    @property
    def pending(self) -> int:
        """Records buffered in memory, not yet on disk."""
        with self._lock:
            return len(self._buf)

    def log(self, step: Optional[int], scalars: Any) -> None:
        """Buffer one record; auto-flush at the configured threshold.

        Encoding happens NOW (cheap, and errors surface at the log site);
        the disk write is deferred to a flush, so the training loop never
        waits on an append unless it crosses the threshold.  Thread-safe
        against a concurrent :meth:`flush` (the manager's async commit).
        """
        if not self.enabled:
            return
        record = encode_record(step, scalars)
        with self._lock:
            self._buf.append(record)
            if (self.flush_records and self.path is not None
                    and len(self._buf) >= self.flush_records):
                try:
                    self.flush()
                except (ScdaError, OSError):
                    # Telemetry must never crash the training loop on a
                    # transient disk error: the records stay buffered
                    # (flush clears only on success) and the error
                    # resurfaces on an *explicit* flush()/close().
                    pass

    def retarget(self, path: str) -> None:
        """Point future flushes at ``path`` (buffered records carry over)
        — the checkpoint manager calls this at every commit so telemetry
        follows the newest checkpoint file."""
        with self._lock:
            self.path = path

    def flush(self) -> int:
        """Append all buffered records as one framed varray section.

        Returns the number of records written (0 when the buffer is
        empty or no target is set).  The buffer is cleared only on
        success — a failed flush keeps the records for the next attempt,
        and ``recover=True`` on the append heals a previously torn tail
        (whole-section framing: partially appended records never count).
        Serialized against concurrent log/flush callers.
        """
        with self._lock:
            if not self.enabled or not self._buf or self.path is None:
                return 0
            records = self._buf
            sizes = [len(b) for b in records]
            with fopen_append(None, self.path, sync=self.sync,
                              recover=True) as f:
                f.write_varray(JOURNAL_USER_STRING, records,
                               [len(records)], sizes)
            self._buf = []
            path = self.path
        if self.update_sidecar:
            try:
                ScdaIndex.refresh_sidecar(path)
            except (ScdaError, OSError):
                pass  # best-effort, like the manager's commit sidecars
        return len(records)

    def close(self) -> int:
        """Flush any buffered tail; the journal object stays reusable."""
        return self.flush()

    def __enter__(self) -> "ScdaJournal":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Don't mask an in-flight exception with a flush failure, and
        # don't flush mid-crash state either.
        if exc_type is None:
            self.close()


# -- reading (the scdatool-tail mirror) --------------------------------------

def iter_records(path: str, start_section: int = 0,
                 index: Optional[ScdaIndex] = None) \
        -> Iterator[Tuple[int, Dict[str, Any]]]:
    """Yield ``(section_index, record)`` for every journal record at or
    after ``start_section``, in file order.

    Non-journal sections are skipped, so journals interleave freely with
    checkpoint leaves.  §3-encoded journal sections (a ``scdatool copy
    --recompress`` output) decode transparently, exactly like raw ones.
    Pass a pre-built ``index`` to skip the header scan (``scdatool tail
    --follow`` extends one incrementally between polls and resumes from
    the previously seen section count).
    """
    with fopen_read(None, path) as r:
        if index is not None:
            r.set_index(index)
        try:
            idx = r.index()
        except ScdaError as e:
            if e.group != 1:
                raise
            # A power cut can tear the newest append; every record in
            # the valid prefix is still whole-section framed and
            # readable (the next flush truncates and heals the tail).
            idx = ScdaIndex.build_prefix(r)
            r.set_index(idx)
        for i in range(max(0, start_section), len(idx.entries)):
            e = idx.entries[i]
            if e.user_string != JOURNAL_USER_STRING or e.type != "V":
                continue
            hdr = r.seek_section(i)
            sizes = r.read_varray_sizes([hdr.N])
            for raw in r.read_varray_data([hdr.N], sizes):
                yield i, decode_record(raw)


def read_records(path: str) -> List[Dict[str, Any]]:
    """All journal records of ``path``, in append order."""
    return [rec for _, rec in iter_records(path)]
