"""Deterministic synthetic token pipeline with host-sharded batches.

Production shape without external data dependencies: every (step, position)
token is a pure function of the seed — so any host can materialize exactly
its own shard of the global batch (no data server), restarts are
bit-reproducible from the step counter alone (the checkpoint stores just
``step``), and elastic restarts re-partition cleanly.  The token stream is
Zipf-ish so losses move like real text rather than uniform noise.

Swap `SyntheticTokens` for a real tokenized corpus by implementing the same
``global_batch_shard`` contract.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class SyntheticTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf-ish stationary distribution over the vocabulary.
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._cdf = np.cumsum(probs / probs.sum())

    def _tokens(self, step: int, row_start: int, rows: int) -> np.ndarray:
        """Rows [row_start, row_start+rows) of the global batch at ``step``."""
        cfg = self.cfg
        ss = np.random.SeedSequence(
            entropy=cfg.seed, spawn_key=(step,))
        # one RNG per global row → row content independent of partition
        out = np.empty((rows, cfg.seq_len + 1), np.int32)
        for i in range(rows):
            rng = np.random.Generator(np.random.Philox(
                np.random.SeedSequence(entropy=cfg.seed,
                                       spawn_key=(step, row_start + i))))
            u = rng.random(cfg.seq_len + 1)
            out[i] = np.searchsorted(self._cdf, u).astype(np.int32)
        return out

    def global_batch_shard(self, step: int, row_start: int,
                           rows: int) -> Dict[str, np.ndarray]:
        """tokens/labels for rows of the global batch (host's shard)."""
        seq = self._tokens(step, row_start, rows)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def sharded_batch(self, step: int, mesh=None,
                      extra: Optional[Dict[str, jnp.ndarray]] = None):
        """The full global batch as jax arrays, batch-sharded if mesh given."""
        cfg = self.cfg
        host = self.global_batch_shard(step, 0, cfg.global_batch)
        batch = {k: jnp.asarray(v) for k, v in host.items()}
        if mesh is not None:
            from jax.sharding import NamedSharding
            from repro.distributed import sharding as sh
            spec = sh.batch_spec(mesh, 2)
            batch = {k: jax.device_put(v, NamedSharding(mesh, spec))
                     for k, v in batch.items()}
        if extra:
            batch.update(extra)
        return batch

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.sharded_batch(step)
            step += 1
