"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod:  2×16×16 = 512 chips, axes (pod, data, model) — the pod axis is
the DCN dimension; gradient reductions cross it once per step, everything
else stays on intra-pod ICI.

A function, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before the first jax use).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """A small mesh over whatever devices exist (tests/examples on CPU)."""
    return jax.make_mesh((data, model), ("data", "model"))
