import os
os.environ["XLA_FLAGS"] = (os.environ.get("SCDA_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above run before ANY other import — jax locks the device
count on first init.  Placeholder host devices let ``jax.make_mesh`` build
the production meshes:

    single-pod:  (16, 16)      axes (data, model)         = 256 chips
    multi-pod:   (2, 16, 16)   axes (pod, data, model)    = 512 chips

For each cell we AOT-compile the real train/serve step against
ShapeDtypeStruct inputs (no allocation), print ``memory_analysis()`` (fits?)
and ``cost_analysis()`` (flops/bytes), and extract the roofline terms from
the post-SPMD HLO (collective bytes, while-trip-corrected; see
``repro.analysis.hlo``).  Results append to a JSON file consumed by
``benchmarks/`` and EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    python -m repro.launch.dryrun --all            # full sweep, both meshes
    python -m repro.launch.dryrun --arch ... --multi-pod only
"""
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import hlo as hlo_analysis          # noqa: E402
from repro.configs import SHAPES, cells, get_config     # noqa: E402
from repro.launch import specs as sp                    # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.optim.adamw import AdamWConfig               # noqa: E402
from repro.train.step import (make_prefill_step, make_serve_step,  # noqa: E402
                              make_train_step)

RESULTS_DEFAULT = "benchmarks/results/dryrun.json"


def compile_cell(arch: str, shape_name: str, multi_pod: bool,
                 kv_chunk: int = 512, loss_chunk: int = 256,
                 save_hlo: str = ""):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    from repro.distributed import sharding as sh
    # long-context decode with batch < data axis: sequence-parallel cache +
    # shard_map partial-softmax merge over the data axis
    sp_axis = None
    if shape.kind == "decode" and cfg.has_attention:
        daxes = sh.data_axes(mesh)
        if shape.global_batch % sh.axis_size(mesh, daxes) != 0:
            sp_axis = "data"
    sh.set_mesh(mesh, sp_decode_axis=sp_axis)
    t0 = time.time()

    with mesh:
        params_abs = sp.abstract_params(cfg, mesh)
        if shape.kind == "train":
            opt_abs = sp.abstract_opt_state(cfg, mesh, params_abs)
            batch_abs = sp.train_inputs(cfg, shape, mesh)
            step = make_train_step(cfg, AdamWConfig(),
                                   loss_chunk=loss_chunk)
            jitted = jax.jit(step, donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            batch_abs = sp.train_inputs(cfg, shape, mesh)
            batch_abs.pop("labels")
            step = make_prefill_step(cfg)
            jitted = jax.jit(step)
            lowered = jitted.lower(params_abs, batch_abs)
        else:
            cache_abs, tokens_abs = sp.decode_inputs(cfg, shape, mesh)
            step = make_serve_step(cfg)
            jitted = jax.jit(step, donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, tokens_abs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    costs = hlo_analysis.analyze(hlo_text)
    terms = hlo_analysis.roofline_terms(costs)
    if save_hlo:
        with open(save_hlo, "w") as fh:
            fh.write(hlo_text)

    D = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n_active = cfg.active_param_count()
    mult = {"train": 6, "prefill": 2, "decode": 2}[shape.kind]
    model_flops = mult * n_active * D
    model_flops_per_chip = model_flops / n_chips

    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "chips": int(n_chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": getattr(
                mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(
                mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(
                mem, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(
                mem, "alias_size_in_bytes", None),
        },
        "xla_cost_analysis": {   # while bodies counted once — see §Dry-run
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "hlo_per_chip": {
            "flops": costs.flops,
            "traffic_bytes": costs.traffic_bytes,
            "collective_bytes": costs.collective_bytes,
            "by_collective": costs.by_collective,
        },
        "roofline": terms,
        "model_flops_total": model_flops,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flop_ratio": (model_flops_per_chip / costs.flops
                              if costs.flops else None),
        "hbm_state_bytes_per_device": _state_bytes_per_device(
            params_abs, shape, locals()),
    }
    return record


def _state_bytes_per_device(params_abs, shape, env) -> int:
    """Persistent state (params [+opt] [+cache]) bytes per device."""
    def tree_bytes(tree):
        total = 0
        for leaf in jax.tree_util.tree_leaves(tree):
            n_shards = leaf.sharding.num_devices if leaf.sharding else 1
            total += leaf.size * leaf.dtype.itemsize // max(1, n_shards) \
                if hasattr(leaf, "size") else 0
        return total
    total = tree_bytes(params_abs)
    if shape.kind == "train" and "opt_abs" in env:
        total += tree_bytes(env["opt_abs"])
    if shape.kind != "train" and "cache_abs" in env:
        total += tree_bytes(env["cache_abs"])
    return int(total)


def run_cells(cell_list, out_path: str, kv_chunk: int, loss_chunk: int):
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    results = []
    if os.path.exists(out_path):
        with open(out_path) as fh:
            results = json.load(fh)
    done = {(r["arch"], r["shape"], tuple(r["mesh"])) for r in results}
    failures = []
    for arch, shape_name, multi_pod in cell_list:
        mesh_shape = (2, 16, 16) if multi_pod else (16, 16)
        if (arch, shape_name, mesh_shape) in done:
            print(f"skip {arch} × {shape_name} × {mesh_shape} (done)")
            continue
        label = f"{arch} × {shape_name} × {'2x16x16' if multi_pod else '16x16'}"
        print(f"=== {label}", flush=True)
        try:
            rec = compile_cell(arch, shape_name, multi_pod,
                               kv_chunk=kv_chunk, loss_chunk=loss_chunk)
            r = rec["roofline"]
            print(f"    ok  lower {rec['lower_s']}s compile "
                  f"{rec['compile_s']}s  dominant={r['dominant']} "
                  f"compute={r['compute_s']:.4f}s "
                  f"memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s", flush=True)
            results.append(rec)
            with open(out_path, "w") as fh:
                json.dump(results, fh, indent=1)
        except Exception as e:  # noqa: BLE001 — sweep must report, not die
            print(f"    FAIL {e}", flush=True)
            traceback.print_exc()
            failures.append((label, str(e)))
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", choices=["no", "only", "both"],
                    default="no")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DEFAULT)
    ap.add_argument("--kv-chunk", type=int, default=512)
    ap.add_argument("--loss-chunk", type=int, default=256)
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()

    if args.all:
        todo = []
        for arch, shape_name in cells():
            if args.multi_pod in ("no", "both"):
                todo.append((arch, shape_name, False))
            if args.multi_pod in ("only", "both"):
                todo.append((arch, shape_name, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        pods = {"no": [False], "only": [True], "both": [False, True]}
        todo = [(args.arch, args.shape, mp) for mp in pods[args.multi_pod]]

    if len(todo) == 1 and args.save_hlo:
        rec = compile_cell(*todo[0][:2], todo[0][2],
                           kv_chunk=args.kv_chunk,
                           loss_chunk=args.loss_chunk,
                           save_hlo=args.save_hlo)
        print(json.dumps(rec, indent=1))
        return 0

    failures = run_cells(todo, args.out, args.kv_chunk, args.loss_chunk)
    if failures:
        print(f"\n{len(failures)} cell(s) FAILED:")
        for label, err in failures:
            print(f"  {label}: {err}")
        return 1
    print("\nall cells compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
