"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Selects any assigned architecture (full or reduced), builds the host mesh,
and runs the fault-tolerant loop with scda checkpointing.  On a real
multi-host TPU fleet the same entry point runs per host after
``jax.distributed.initialize`` (the checkpoint layer keys windows off each
process's addressable shards automatically).
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import REGISTRY, get_config, smoke
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(REGISTRY))
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced same-family config (default on CPU)")
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-ckpts")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-compressed", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-par", type=int, default=0,
                    help="data axis size (0 = all local devices)")
    ap.add_argument("--model-par", type=int, default=1)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    dp = args.data_par or max(1, jax.device_count() // args.model_par)
    mesh = make_host_mesh(dp, args.model_par)
    loop = TrainLoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=f"{args.ckpt_dir}/{cfg.name}", ckpt_keep=3,
        ckpt_compressed=args.ckpt_compressed,
        grad_compress=args.grad_compress)
    out = train(cfg, loop,
                AdamWConfig(lr=args.lr, total_steps=args.steps),
                mesh=mesh, seq_len=args.seq_len,
                global_batch=args.global_batch)
    print(f"done: start_step={out['start_step']} "
          f"final_loss={out['losses'][-1]:.4f} "
          f"checkpoints={out['manager'].all_steps()}")


if __name__ == "__main__":
    main()
