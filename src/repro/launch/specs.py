"""Abstract input construction per (arch × shape × mesh) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
sharding-attached, zero allocation) for every input of the cell's step
function — the pattern that lets ``jit(...).lower(...).compile()`` validate
a 512-chip program on a laptop.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.models import lm
from repro.optim import adamw


def _with_shardings(abstract, shardings):
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract, shardings)


def abstract_params(cfg: ModelConfig, mesh: Mesh):
    a = jax.eval_shape(lambda: lm.init_lm(cfg, jax.random.PRNGKey(0)))
    return _with_shardings(a, sh.params_shardings(mesh, a))


def abstract_opt_state(cfg: ModelConfig, mesh: Mesh, params_abs):
    a = jax.eval_shape(lambda: adamw.init(
        jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params_abs)))
    mu = _with_shardings(a.mu, jax.tree_util.tree_map(
        lambda s: s.sharding, params_abs))
    nu = _with_shardings(a.nu, jax.tree_util.tree_map(
        lambda s: s.sharding, params_abs))
    count = jax.ShapeDtypeStruct((), jnp.int32,
                                 sharding=sh.replicated(mesh))
    return adamw.AdamWState(mu=mu, nu=nu, count=count)


def train_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) \
        -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    shd = sh.input_shardings(mesh, "train", cfg, shape)
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                       sharding=shd["tokens"]),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32,
                                       sharding=shd["labels"]),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), jnp.float32,
            sharding=shd["patch_embeds"])
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.max_source_len, cfg.d_model), jnp.float32,
            sharding=shd["enc_embeds"])
    return batch


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) \
        -> Tuple[Any, jax.ShapeDtypeStruct]:
    """(abstract cache, abstract tokens) for a serve_step cell."""
    B, S = shape.global_batch, shape.seq_len
    shd = sh.input_shardings(mesh, "decode", cfg, shape)
    cache_abs = jax.eval_shape(partial(lm.init_cache, cfg, B, S))
    rep = sh.replicated(mesh)

    def shard_of(path_name: str):
        return shd.get(path_name, rep)

    cache = {}
    for key, leaf in cache_abs.items():
        if key == "pos":
            cache[key] = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                              sharding=rep)
        elif key in ("k", "v"):
            cache[key] = jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype, sharding=shd[f"cache_{key}"])
        elif key == "ssm":
            cache[key] = {
                "h": jax.ShapeDtypeStruct(leaf["h"].shape, leaf["h"].dtype,
                                          sharding=shd["ssm_h"]),
                "conv": jax.ShapeDtypeStruct(leaf["conv"].shape,
                                             leaf["conv"].dtype,
                                             sharding=shd["ssm_conv"]),
            }
        elif key == "enc_out":
            cache[key] = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                              sharding=shd["enc_out"])
        else:
            cache[key] = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                              sharding=rep)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                  sharding=shd["tokens"])
    return cache, tokens


def hybrid_kv_shape_fix(cfg: ModelConfig, shd, cache_abs):
    """zamba2's shared-attn cache has G (not L) leading entries — the
    sharding specs are rank-aligned already (rank 5)."""
    return shd
