"""Model definitions: layers, SSM blocks, and the composable LM core."""
from repro.models.lm import (compute_dtype, forward, forward_hidden,
                             init_cache, init_lm, lm_loss, serve_step,
                             unembed)

__all__ = ["compute_dtype", "forward", "forward_hidden", "init_cache",
           "init_lm", "lm_loss", "serve_step", "unembed"]
