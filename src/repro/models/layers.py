"""Building-block layers: norms, RoPE, memory-efficient attention, MLPs, MoE.

Everything is a pure function over explicit param pytrees (no flax/haiku —
zero dependencies beyond jax), initialized by ``init_*`` helpers that return
plain dicts.  Attention uses an online-softmax kv-chunked scan so activation
memory stays O(S·chunk) rather than O(S²) — the same access pattern the
Pallas flash kernel implements on TPU, keeping dry-run rooflines honest.
"""
from __future__ import annotations

import math
import os
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------------- norms --
def rms_norm(x, weight, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((d,), dtype)   # stored as offset from 1 (gemma-style)


# -------------------------------------------------------------------- rope --
def rope(x, positions, base: float = 10_000.0):
    """Rotary embedding; x: (..., S, H, D), positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,half)
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention --
# Projections are stored 3-D — (d_model, heads, head_dim) — so tensor
# parallelism shards the *heads* dim directly (no flat-dim reshape for
# GSPMD to lose).  When the head count does not divide the model axis,
# phantom zero heads are padded in at forward time (sharding.padded_heads):
# their wo rows are zero, so the output is exact and the overhead is
# visible in the roofline, not hidden in a resharding.
def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d_model, n_heads, head_dim), dtype=dtype),
        "wk": _init(ks[1], (d_model, n_kv, head_dim), dtype=dtype),
        "wv": _init(ks[2], (d_model, n_kv, head_dim), dtype=dtype),
        "wo": _init(ks[3], (n_heads, head_dim, d_model),
                    scale=1.0 / math.sqrt(n_heads * head_dim), dtype=dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rms_norm(head_dim, dtype)
        p["k_norm"] = init_rms_norm(head_dim, dtype)
    return p


def _pad_heads(w, axis: int, h_pad: int):
    h = w.shape[axis]
    if h == h_pad:
        return w
    pads = [(0, 0)] * w.ndim
    pads[axis] = (0, h_pad - h)
    return jnp.pad(w, pads)


def _project_qkv(p, x, n_heads, n_kv, head_dim, positions, rope_base,
                 eps=1e-6):
    from repro.distributed import sharding as sh
    h_pad = sh.padded_heads(n_heads)
    kv_pad = n_kv if h_pad % n_kv == 0 else h_pad  # keep repeat integral
    wq = _pad_heads(p["wq"], 1, h_pad)
    wk = _pad_heads(p["wk"], 1, kv_pad)
    wv = _pad_heads(p["wv"], 1, kv_pad)
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", x, wk)
    v = jnp.einsum("bsd,dhk->bshk", x, wv)
    q = sh.constrain(q, "batch", None, "model", None)
    k = sh.constrain(k, "batch", None, None, None)
    v = sh.constrain(v, "batch", None, None, None)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    if positions is not None:
        q = rope(q, positions, rope_base)
        k = rope(k, positions, rope_base)
    return q, k, v


def _output_proj(p, out, n_heads, d_model):
    """out: (B, S, H_pad, hd) → (B, S, d_model); phantom heads die here."""
    from repro.distributed import sharding as sh
    h_pad = out.shape[2]
    wo = _pad_heads(p["wo"], 0, h_pad)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return sh.constrain(y, "batch", None, None)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    kv_chunk: int = 512, q_offset=0):
    """Online-softmax attention, scanned over kv chunks.

    q: (B, Sq, H, D) head-parallel; k, v: (B, Skv, Hkv, D) with
    H % Hkv == 0 (GQA) — kv heads are repeated to H chunk-by-chunk inside
    the scan, which is the TP-friendly "replicate KV across the head
    groups" layout.  ``window`` (static int or traced scalar) masks keys
    older than ``window`` positions; None disables windowing.  ``q_offset``
    is the absolute position of q[0] (decode).  Softmax statistics and
    accumulation in f32.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    rep = H // Hkv
    scale = 1.0 / math.sqrt(D)
    kv_chunk = min(kv_chunk, Skv)
    n_chunks = math.ceil(Skv / kv_chunk)
    pad = n_chunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, D)
    q_pos = q_offset + jnp.arange(Sq)

    from repro.distributed import sharding as sh

    def body(carry, inp):
        acc, m, l = carry
        kj, vj, j = inp
        kj = sh.constrain(kj, "batch", None, None, None)
        vj = sh.constrain(vj, "batch", None, None, None)
        if rep > 1:
            kj = jnp.repeat(kj, rep, axis=2)
            vj = jnp.repeat(vj, rep, axis=2)
        s = jnp.einsum("bshd,bchd->bshc", q, kj,
                       preferred_element_type=jnp.float32) * scale
        s = sh.constrain(s, "batch", None, "model", None)
        kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else \
            jnp.ones((Sq, kv_chunk), bool)
        if window is not None:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        if pad:
            mask &= (kv_pos < Skv)[None, :]
        s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, :], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bshc,bchd->bshd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (acc, m_new, l), None

    acc0 = sh.constrain(jnp.zeros((B, Sq, H, D), jnp.float32),
                        "batch", None, "model", None)
    m0 = sh.constrain(jnp.full((B, Sq, H), -jnp.inf, jnp.float32),
                      "batch", None, "model")
    l0 = sh.constrain(jnp.zeros((B, Sq, H), jnp.float32),
                      "batch", None, "model")
    js = jnp.arange(n_chunks)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), js))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.astype(q.dtype)


def attention_block(p: Params, x, *, n_heads, n_kv, head_dim, rope_base,
                    causal=True, window=None, kv_chunk=512, positions=None,
                    eps=1e-6):
    """Full attention over a sequence (train / prefill)."""
    B, S, d_model = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, positions,
                           rope_base, eps)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          kv_chunk=kv_chunk)
    return _output_proj(p, out, n_heads, d_model)


def attention_decode(p: Params, x, cache_k, cache_v, pos, *, n_heads, n_kv,
                     head_dim, rope_base, window=None, eps=1e-6,
                     kv_chunk=512):
    """One-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_max, Hkv, D); pos: scalar int32 —
    number of tokens already in the cache.  Returns (out, new_k, new_v).
    Reuses the chunked flash path so the (1, S_max) score row never
    materializes at once; if the ambient policy declares a sequence-
    parallel decode axis, partial softmax states are merged across shards
    via shard_map + psum instead (long-context SP decode).
    """
    from repro.distributed import sharding as sh
    B = x.shape[0]
    d_model = x.shape[-1]
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k, v = _project_qkv(p, x, n_heads, n_kv, head_dim, positions,
                           rope_base, eps)
    # drop phantom kv heads before touching the (unpadded) cache
    k = k[:, :, :n_kv, :]
    v = v[:, :, :n_kv, :]
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), pos, axis=1)
    sp_axis = sh.get_policy().sp_decode_axis
    if sp_axis:
        out = _sp_decode_attention(q, cache_k, cache_v, pos, window, sp_axis)
    else:
        out = flash_attention(q, cache_k, cache_v, causal=True,
                              window=window, kv_chunk=kv_chunk,
                              q_offset=pos)
    return _output_proj(p, out, n_heads, d_model), cache_k, cache_v


def _sp_decode_attention(q, cache_k, cache_v, pos, window, axis: str):
    """Sequence-parallel decode attention (shard_map over the cache's
    sequence shards; partial softmax merged with pmax/psum).

    Per shard: local flash over its cache slice; merge:
        m* = pmax(m);  l* = Σ l·e^{m−m*};  acc* = Σ acc·e^{m−m*};
    out = acc*/l*.  Collective volume is O(B·H·D) — independent of S.
    """
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as sh
    mesh = sh.get_policy().mesh
    B, _, H, D = q.shape
    S = cache_k.shape[1]
    n_shards = sh.axis_size(mesh, axis)
    S_local = S // n_shards
    Hkv = cache_k.shape[2]
    kv_model = "model" if (axis != "model" and Hkv % sh.axis_size(
        mesh, "model") == 0) else None

    def local(qb, kb, vb, posb):
        idx = jax.lax.axis_index(axis)
        offset = idx * S_local
        rep = qb.shape[2] // kb.shape[2]  # both are per-shard head counts
        if rep > 1:
            kb = jnp.repeat(kb, rep, axis=2)
            vb = jnp.repeat(vb, rep, axis=2)
        s = jnp.einsum("bshd,bchd->bshc", qb, kb,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        kv_pos = offset + jnp.arange(S_local)
        mask = kv_pos <= posb
        if window is not None:
            mask &= (posb - kv_pos) < window
        s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
        m = s.max(axis=-1)
        m_star = jax.lax.pmax(m, axis)
        m_safe = jnp.where(jnp.isfinite(m_star), m_star, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
        l = jax.lax.psum(p.sum(axis=-1), axis)
        acc = jnp.einsum("bshc,bchd->bshd", p.astype(vb.dtype), vb,
                         preferred_element_type=jnp.float32)
        acc = jax.lax.psum(acc, axis)
        return (acc / jnp.maximum(l[..., None], 1e-37)).astype(qb.dtype)

    qspec = P(None, None, "model" if H % sh.axis_size(mesh, "model") == 0
              and axis != "model" else None, None)
    kvspec = P(None, axis, kv_model, None)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(qspec, kvspec, kvspec, P()),
        out_specs=qspec, check_vma=False)(q, cache_k, cache_v, pos)


# --------------------------------------------------------------------- mlp --
def init_mlp(key, d_model: int, d_ff: int, mlp_type: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_down": _init(ks[2], (d_ff, d_model), dtype=dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = _init(ks[0], (d_model, d_ff), dtype=dtype)
        p["w_up"] = _init(ks[1], (d_model, d_ff), dtype=dtype)
    else:
        p["w_up"] = _init(ks[1], (d_model, d_ff), dtype=dtype)
    return p


def mlp_block(p: Params, x, mlp_type: str):
    from repro.distributed import sharding as sh
    ff = ("batch", None, "model") if x.ndim == 3 else ("batch", "model")
    dm = ("batch", None, None) if x.ndim == 3 else ("batch", None)
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
        h = act(sh.constrain(x @ p["w_gate"], *ff)) \
            * sh.constrain(x @ p["w_up"], *ff)
    elif mlp_type == "relu2":  # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(sh.constrain(x @ p["w_up"], *ff)))
    elif mlp_type == "gelu":
        h = jax.nn.gelu(sh.constrain(x @ p["w_up"], *ff))
    else:
        raise ValueError(mlp_type)
    return sh.constrain(h @ p["w_down"], *dm)


# --------------------------------------------------------------------- moe --
def init_moe(key, d_model: int, d_ff: int, n_experts: int, mlp_type: str,
             shared_expert: bool, dtype) -> Params:
    ks = jax.random.split(key, 5)
    gated = mlp_type in ("swiglu", "geglu")
    p = {
        "router": _init(ks[0], (d_model, n_experts), scale=0.02, dtype=dtype),
        "w_up": _init(ks[2], (n_experts, d_model, d_ff), dtype=dtype),
        "w_down": _init(ks[3], (n_experts, d_ff, d_model),
                        scale=1.0 / math.sqrt(d_ff), dtype=dtype),
    }
    if gated:
        p["w_gate"] = _init(ks[1], (n_experts, d_model, d_ff), dtype=dtype)
    if shared_expert:
        p["shared"] = init_mlp(ks[4], d_model, d_ff, mlp_type, dtype)
    return p


def moe_block(p: Params, x, *, n_experts: int, top_k: int, mlp_type: str,
              capacity_factor: float = 1.25, shared_expert: bool = False):
    """Token-choice top-k MoE with capacity buckets (GShard-style).

    Sort-free dispatch: tokens are scattered into per-expert capacity
    buffers (E, C, D); overflow tokens are dropped (their residual path
    still flows).  Expert FFNs run as one batched einsum — MXU-shaped and
    EP-shardable on the expert axis.  Returns (y, aux_loss).
    """
    from repro.distributed import sharding as sh
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    # §Perf iteration H2: pad the expert count up to a model-axis multiple
    # (like head padding) so expert parallelism applies even when E ∤ 16
    # (granite's 40 experts).  Phantom experts are masked to -inf in the
    # router, so results are exact; their weights are zero blocks.
    msize = max(1, sh.model_axis_size())
    e_pad = ((n_experts + msize - 1) // msize) * msize \
        if n_experts % msize else n_experts
    logits = (xt @ p["router"]).astype(jnp.float32)       # (T, E)
    if e_pad != n_experts:
        logits = jnp.pad(logits, ((0, 0), (0, e_pad - n_experts)),
                         constant_values=-1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)              # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    C = max(1, int(capacity_factor * T * top_k / n_experts))
    flat_ids = ids.reshape(-1)                            # (T*k,)
    # position of each assignment within its expert, in token order
    onehot = jax.nn.one_hot(flat_ids, e_pad, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1)
    pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
    keep = pos < C
    safe_pos = jnp.where(keep, pos, 0)

    ep = "model" if e_pad % msize == 0 and msize > 1 else None
    # §Perf H3 (REFUTED in this form — see EXPERIMENTS.md): sharding the
    # capacity dim over data cuts expert flops by the data-axis size, but
    # scatter into a 2-D-sharded operand makes GSPMD emit all-gather
    # storms; a shard_map all-to-all dispatch is the proper fix (future
    # work).  Off by default.
    cap = "batch" if os.environ.get("REPRO_MOE_2D") == "1" else None
    buf = jnp.zeros((e_pad, C, D), x.dtype)
    src = jnp.repeat(xt, top_k, axis=0) * keep[:, None].astype(x.dtype)
    buf = buf.at[flat_ids, safe_pos].add(src)
    buf = sh.constrain(buf, ep, cap, None)

    w_up = _pad_heads(p["w_up"], 0, e_pad)
    h_up = jnp.einsum("ecd,edf->ecf", buf, w_up,
                      preferred_element_type=jnp.float32).astype(x.dtype)
    h_up = sh.constrain(h_up, ep, cap, None if ep else "model")
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else jax.nn.gelu
        w_gate = _pad_heads(p["w_gate"], 0, e_pad)
        h_gate = jnp.einsum("ecd,edf->ecf", buf, w_gate,
                            preferred_element_type=jnp.float32).astype(x.dtype)
        h = act(h_gate) * h_up
    elif mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h_up))
    else:
        h = jax.nn.gelu(h_up)
    w_down = _pad_heads(p["w_down"], 0, e_pad)
    out = jnp.einsum("ecf,efd->ecd", h, w_down,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = sh.constrain(out, ep, cap, None)

    gathered = out[flat_ids, safe_pos]                    # (T*k, D)
    gathered = gathered * (gates.reshape(-1)
                           * keep.astype(jnp.float32)).astype(x.dtype)[:, None]
    y = gathered.reshape(T, top_k, D).sum(axis=1)

    # load-balance aux loss (Switch/GShard) — over real experts only
    me = probs[:, :n_experts].mean(axis=0)
    ce = jnp.zeros((e_pad,), jnp.float32).at[flat_ids].add(1.0) / (T * top_k)
    aux = n_experts * jnp.sum(me * ce[:n_experts])

    if shared_expert:
        y = y + mlp_block(p["shared"], xt, mlp_type)
    return y.reshape(B, S, D), aux
