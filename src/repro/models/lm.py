"""Composable language-model core covering all assigned families.

Design points:
  * Pure functions over explicit param pytrees; layer params are *stacked*
    (leading dim = n_layers) and consumed by ``lax.scan`` — HLO size is
    depth-independent (compile-time matters on 1-core CPU and at 512-way
    SPMD) and XLA can overlap the per-layer collectives with compute.
  * Hybrid archs (zamba2) scan over *groups* of (E mamba blocks + 1 shared
    attention application) — no data-dependent control flow.
  * Local/global attention patterns (gemma3) ride the same scan via a
    per-layer traced window size.
  * The loss head is chunked over the sequence (remat'd) so the (S, vocab)
    logits tensor never materializes — decisive for 256k vocabularies.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S

Params = Dict[str, Any]


def compute_dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------

def _init_dense_layer(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim_,
                                 cfg.qk_norm, jnp.float32),
        "ln2": L.init_rms_norm(cfg.d_model),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type,
                          jnp.float32),
    }


def _init_moe_layer(cfg: ModelConfig, key) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim_,
                                 cfg.qk_norm, jnp.float32),
        "ln2": L.init_rms_norm(cfg.d_model),
        "moe": L.init_moe(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                          cfg.mlp_type, cfg.shared_expert, jnp.float32),
    }


def _init_ssm_layer(cfg: ModelConfig, key) -> Params:
    return {
        "ln1": L.init_rms_norm(cfg.d_model),
        "ssm": S.init_ssm(key, cfg, jnp.float32),
    }


def _init_encdec_dec_layer(cfg: ModelConfig, key) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim_,
                                 cfg.qk_norm, jnp.float32),
        "ln_x": L.init_rms_norm(cfg.d_model),
        "cross": L.init_attention(k2, cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.head_dim_,
                                  cfg.qk_norm, jnp.float32),
        "ln2": L.init_rms_norm(cfg.d_model),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.mlp_type,
                          jnp.float32),
    }


def _stack_init(layer_fn, n: int, key) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(layer_fn)(keys)


def init_lm(cfg: ModelConfig, key) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02),
        "final_norm": L.init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab),
                                          jnp.float32)
                        / math.sqrt(cfg.d_model))
    fam = cfg.family
    if fam in ("dense", "vlm"):
        p["layers"] = _stack_init(partial(_init_dense_layer, cfg),
                                  cfg.n_layers, ks[2])
    elif fam == "moe":
        p["layers"] = _stack_init(partial(_init_moe_layer, cfg),
                                  cfg.n_layers, ks[2])
    elif fam == "ssm":
        p["layers"] = _stack_init(partial(_init_ssm_layer, cfg),
                                  cfg.n_layers, ks[2])
    elif fam == "hybrid":
        assert cfg.n_layers % cfg.shared_attn_every == 0, \
            "hybrid needs n_layers divisible by shared_attn_every"
        p["layers"] = _stack_init(partial(_init_ssm_layer, cfg),
                                  cfg.n_layers, ks[2])
        p["shared_attn"] = {
            "ln": L.init_rms_norm(cfg.d_model),
            "attn": L.init_attention(ks[3], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim_,
                                     cfg.qk_norm, jnp.float32),
        }
    elif fam == "encdec":
        p["enc_layers"] = _stack_init(partial(_init_dense_layer, cfg),
                                      cfg.encoder_layers, ks[2])
        p["enc_norm"] = L.init_rms_norm(cfg.d_model)
        p["layers"] = _stack_init(partial(_init_encdec_dec_layer, cfg),
                                  cfg.n_layers, ks[3])
    else:
        raise ValueError(fam)
    if fam == "vlm":
        p["mm_proj"] = (jax.random.normal(ks[4], (cfg.d_model, cfg.d_model),
                                          jnp.float32)
                        / math.sqrt(cfg.d_model))
    return p


# --------------------------------------------------------------------------
# Forward (train / prefill): hidden states
# --------------------------------------------------------------------------

def _windows_per_layer(cfg: ModelConfig, S_kv: int) -> Optional[jnp.ndarray]:
    """Per-layer effective window (traced into the layer scan), or None."""
    if cfg.attn_window == 0:
        return None
    w = [S_kv if cfg.layer_is_global(i) else cfg.attn_window
         for i in range(cfg.n_layers)]
    return jnp.asarray(w, jnp.int32)


def _attn_kwargs(cfg: ModelConfig):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                head_dim=cfg.head_dim_, rope_base=cfg.rope_base,
                eps=cfg.norm_eps)


def forward_hidden(cfg: ModelConfig, params: Params, tokens,
                   patch_embeds=None, enc_embeds=None,
                   kv_chunk: int = 512, remat: bool = True) \
        -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token ids → final hidden states (B, S, d). Returns (hidden, moe_aux).

    ``remat=True`` checkpoints each layer-scan body: the backward pass
    recomputes layer internals instead of saving per-layer attention/MLP
    intermediates — the policy that makes 4k×256 batches fit HBM.
    """
    ckpt = jax.checkpoint if remat else (lambda f: f)
    from repro.distributed import sharding as sh
    dtype = compute_dtype(cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = sh.constrain(x, "batch", None, None)
    if cfg.family == "vlm":
        assert patch_embeds is not None, "vlm needs patch embeddings"
        prefix = (patch_embeds.astype(dtype) @
                  params["mm_proj"].astype(dtype))
        x = jnp.concatenate([prefix, x], axis=1)
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps

    if cfg.family in ("dense", "vlm", "moe"):
        windows = _windows_per_layer(cfg, x.shape[1])

        def body(carry, xs):
            x, aux = carry
            x = sh.constrain(x, "batch", None, None)
            lp = xs[0]
            window = xs[1] if windows is not None else None
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            h = L.rms_norm(x, lp["ln1"], eps)
            h = L.attention_block(lp["attn"], h, window=window,
                                  kv_chunk=kv_chunk, **_attn_kwargs(cfg))
            x = x + h
            h = L.rms_norm(x, lp["ln2"], eps)
            if cfg.family == "moe":
                h, a = L.moe_block(lp["moe"], h, n_experts=cfg.n_experts,
                                   top_k=cfg.experts_top_k,
                                   mlp_type=cfg.mlp_type,
                                   capacity_factor=cfg.capacity_factor,
                                   shared_expert=cfg.shared_expert)
                aux = aux + a
            else:
                h = L.mlp_block(lp["mlp"], h, cfg.mlp_type)
            return (x + h, aux), None

        xs = (params["layers"],) + ((windows,) if windows is not None else ())
        (x, aux), _ = jax.lax.scan(ckpt(body), (x, aux), xs)

    elif cfg.family == "ssm":
        def body(x, lp):
            x = sh.constrain(x, "batch", None, None)
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            return x + S.ssm_block(lp["ssm"],
                                   L.rms_norm(x, lp["ln1"], eps), cfg), None
        x, _ = jax.lax.scan(ckpt(body), x, params["layers"])

    elif cfg.family == "hybrid":
        E = cfg.shared_attn_every
        G = cfg.n_layers // E
        grouped = jax.tree_util.tree_map(
            lambda w: w.reshape((G, E) + w.shape[1:]), params["layers"])
        sa = jax.tree_util.tree_map(lambda w: w.astype(dtype),
                                    params["shared_attn"])

        def inner(x, lp):
            x = sh.constrain(x, "batch", None, None)
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            return x + S.ssm_block(lp["ssm"],
                                   L.rms_norm(x, lp["ln1"], eps), cfg), None

        def group(x, gp):
            x, _ = jax.lax.scan(inner, x, gp)
            h = L.rms_norm(x, sa["ln"], eps)
            h = L.attention_block(sa["attn"], h, kv_chunk=kv_chunk,
                                  **_attn_kwargs(cfg))
            return x + h, None

        x, _ = jax.lax.scan(ckpt(group), x, grouped)

    elif cfg.family == "encdec":
        assert enc_embeds is not None, "encdec needs encoder embeddings"
        e = enc_embeds.astype(dtype)

        def enc_body(e, lp):
            e = sh.constrain(e, "batch", None, None)
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            h = L.rms_norm(e, lp["ln1"], eps)
            e = e + L.attention_block(lp["attn"], h, causal=False,
                                      kv_chunk=kv_chunk, **_attn_kwargs(cfg))
            h = L.rms_norm(e, lp["ln2"], eps)
            return e + L.mlp_block(lp["mlp"], h, cfg.mlp_type), None

        e, _ = jax.lax.scan(ckpt(enc_body), e, params["enc_layers"])
        e = L.rms_norm(e, params["enc_norm"].astype(dtype), eps)

        def dec_body(x, lp):
            x = sh.constrain(x, "batch", None, None)
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            h = L.rms_norm(x, lp["ln1"], eps)
            x = x + L.attention_block(lp["attn"], h, kv_chunk=kv_chunk,
                                      **_attn_kwargs(cfg))
            h = L.rms_norm(x, lp["ln_x"], eps)
            x = x + _cross_attention(cfg, lp["cross"], h, e)
            h = L.rms_norm(x, lp["ln2"], eps)
            return x + L.mlp_block(lp["mlp"], h, cfg.mlp_type), None

        x, _ = jax.lax.scan(ckpt(dec_body), x, params["layers"])
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"].astype(dtype), eps)
    return x, aux


def _cross_attention(cfg: ModelConfig, p, x, enc_out):
    """Decoder→encoder attention (no causal mask, no rope on keys)."""
    from repro.distributed import sharding as sh
    B, S, d_model = x.shape
    h_pad = sh.padded_heads(cfg.n_heads)
    kv_pad = cfg.n_kv_heads if h_pad % cfg.n_kv_heads == 0 else h_pad
    wq = L._pad_heads(p["wq"], 1, h_pad)
    wk = L._pad_heads(p["wk"], 1, kv_pad)
    wv = L._pad_heads(p["wv"], 1, kv_pad)
    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, wk)
    v = jnp.einsum("bsd,dhk->bshk", enc_out, wv)
    q = sh.constrain(q, "batch", None, "model", None)
    out = L.flash_attention(q, k, v, causal=False)
    return L._output_proj(p, out, cfg.n_heads, d_model)


def unembed(cfg: ModelConfig, params: Params, hidden):
    w = (params["embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(hidden.dtype)
    return hidden @ w


def forward(cfg: ModelConfig, params: Params, tokens, **kw):
    """Full logits (small-model / test path; loss uses the chunked head)."""
    hidden, aux = forward_hidden(cfg, params, tokens, **kw)
    return unembed(cfg, params, hidden).astype(jnp.float32)


# --------------------------------------------------------------------------
# Loss with a sequence-chunked, remat'd softmax head
# --------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params: Params, tokens, labels,
            loss_chunk: int = 256, aux_weight: float = 0.01, **kw):
    hidden, aux = forward_hidden(cfg, params, tokens, **kw)
    if cfg.family == "vlm":   # image prefix carries no LM loss
        hidden = hidden[:, -tokens.shape[1]:, :]
    B, Stot, D = hidden.shape
    n = max(1, Stot // loss_chunk)
    chunk = Stot // n
    assert n * chunk == Stot, f"seq {Stot} not divisible into {n} loss chunks"
    hc = hidden.reshape(B, n, chunk, D)
    lc = labels.reshape(B, n, chunk)

    from repro.distributed import sharding as sh

    @jax.checkpoint
    def chunk_loss(h, y):
        h = sh.constrain(h, "batch", None, None)
        logits = unembed(cfg, params, h).astype(jnp.float32)
        logits = sh.constrain(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None],
                                   axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def body(tot, xs):
        h, y = xs
        return tot + chunk_loss(h, y), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    loss = total / (B * Stot)
    return loss + aux_weight * aux


# --------------------------------------------------------------------------
# Decode (serve) path with layer-stacked caches
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = compute_dtype(cfg)
    hd, Hkv = cfg.head_dim_, cfg.n_kv_heads
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe"):
        cache["k"] = jnp.zeros((cfg.n_layers, batch, max_len, Hkv, hd), dtype)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, max_len, Hkv, hd), dtype)
    elif cfg.family == "ssm":
        st = S.init_ssm_state(cfg, batch, dtype)
        cache["ssm"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), st)
    elif cfg.family == "hybrid":
        st = S.init_ssm_state(cfg, batch, dtype)
        cache["ssm"] = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), st)
        G = cfg.n_layers // cfg.shared_attn_every
        cache["k"] = jnp.zeros((G, batch, max_len, Hkv, hd), dtype)
        cache["v"] = jnp.zeros((G, batch, max_len, Hkv, hd), dtype)
    elif cfg.family == "encdec":
        cache["k"] = jnp.zeros((cfg.n_layers, batch, max_len, Hkv, hd), dtype)
        cache["v"] = jnp.zeros((cfg.n_layers, batch, max_len, Hkv, hd), dtype)
        cache["enc_out"] = jnp.zeros((batch, cfg.max_source_len, cfg.d_model),
                                     dtype)
    return cache


def serve_step(cfg: ModelConfig, params: Params, cache: Params, tokens):
    """One decode step: tokens (B, 1) → (logits (B, vocab), new cache)."""
    dtype = compute_dtype(cfg)
    eps = cfg.norm_eps
    pos = cache["pos"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        windows = _windows_per_layer(cfg, cache["k"].shape[2])

        def body(x, xs):
            lp, kc, vc = xs[0], xs[1], xs[2]
            window = xs[3] if windows is not None else None
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            h = L.rms_norm(x, lp["ln1"], eps)
            h, kc, vc = L.attention_decode(lp["attn"], h, kc, vc, pos,
                                           window=window,
                                           **_attn_kwargs(cfg))
            x = x + h
            h = L.rms_norm(x, lp["ln2"], eps)
            if cfg.family == "moe":
                h, _ = L.moe_block(lp["moe"], h, n_experts=cfg.n_experts,
                                   top_k=cfg.experts_top_k,
                                   mlp_type=cfg.mlp_type,
                                   capacity_factor=cfg.capacity_factor,
                                   shared_expert=cfg.shared_expert)
            else:
                h = L.mlp_block(lp["mlp"], h, cfg.mlp_type)
            return x + h, (kc, vc)

        xs = (params["layers"], cache["k"], cache["v"])
        xs += (windows,) if windows is not None else ()
        x, (k_new, v_new) = jax.lax.scan(body, x, xs)
        new_cache.update(k=k_new, v=v_new)

    elif cfg.family == "ssm":
        def body(x, xs):
            lp, st = xs
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            h, st = S.ssm_decode(lp["ssm"], L.rms_norm(x, lp["ln1"], eps),
                                 st, cfg)
            return x + h, st
        x, ssm_new = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache.update(ssm=ssm_new)

    elif cfg.family == "hybrid":
        E = cfg.shared_attn_every
        G = cfg.n_layers // E
        grouped = jax.tree_util.tree_map(
            lambda w: w.reshape((G, E) + w.shape[1:]), params["layers"])
        ssm_grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((G, E) + a.shape[1:]), cache["ssm"])
        sa = jax.tree_util.tree_map(lambda w: w.astype(dtype),
                                    params["shared_attn"])

        def inner(x, xs):
            lp, st = xs
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            h, st = S.ssm_decode(lp["ssm"], L.rms_norm(x, lp["ln1"], eps),
                                 st, cfg)
            return x + h, st

        def group(x, xs):
            gp, gst, kc, vc = xs
            x, gst = jax.lax.scan(inner, x, (gp, gst))
            h = L.rms_norm(x, sa["ln"], eps)
            h, kc, vc = L.attention_decode(sa["attn"], h, kc, vc, pos,
                                           **_attn_kwargs(cfg))
            return x + h, (gst, kc, vc)

        x, (ssm_new, k_new, v_new) = jax.lax.scan(
            group, x, (grouped, ssm_grouped, cache["k"], cache["v"]))
        new_cache.update(
            ssm=jax.tree_util.tree_map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), ssm_new),
            k=k_new, v=v_new)

    elif cfg.family == "encdec":
        e = cache["enc_out"]

        def body(x, xs):
            lp, kc, vc = xs
            lp = jax.tree_util.tree_map(lambda w: w.astype(dtype), lp)
            h = L.rms_norm(x, lp["ln1"], eps)
            h, kc, vc = L.attention_decode(lp["attn"], h, kc, vc, pos,
                                           **_attn_kwargs(cfg))
            x = x + h
            h = L.rms_norm(x, lp["ln_x"], eps)
            x = x + _cross_attention(cfg, lp["cross"], h, e)
            h = L.rms_norm(x, lp["ln2"], eps)
            return x + L.mlp_block(lp["mlp"], h, cfg.mlp_type), (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache.update(k=k_new, v=v_new)
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"].astype(dtype), eps)
    logits = unembed(cfg, params, x)[:, 0, :].astype(jnp.float32)
    new_cache["pos"] = pos + 1
    return logits, new_cache
