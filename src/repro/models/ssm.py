"""Mamba1 (selective scan) and Mamba2 (SSD) blocks, TPU-adapted.

Hardware adaptation (DESIGN.md §3): instead of the CUDA selective-scan
kernel's thread-parallel recurrence, we use chunked formulations that map
onto TPU strengths —

  * Mamba1: per-(channel, state) diagonal recurrence evaluated as a scan
    over sequence chunks with a log-depth ``associative_scan`` inside each
    chunk (VPU-friendly, O(chunk) live memory, numerically safe because all
    decay products are ≤ 1).
  * Mamba2: the SSD block decomposition — intra-chunk attention-like
    matmuls + inter-chunk state recurrence — which is MXU-shaped matmul
    work, exactly the insight that makes Mamba2 TPU-native.

Decode steps are closed-form single-token state updates (O(1) in sequence
length — why the ``long_500k`` cell is cheap for SSM archs).
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _init, init_rms_norm, rms_norm

Params = Dict[str, Any]


# ----------------------------------------------------------------- conv1d --
def causal_conv1d(x, w, b):
    """Depthwise causal conv; x: (B, S, C), w: (C, K), b: (C,)."""
    K = w.shape[1]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, j:j + x.shape[1], :] * w[None, None, :, K - 1 - j]
              for j in range(K))
    return out + b


def conv_decode(x, conv_state, w, b):
    """Single-token conv; x: (B, C); conv_state: (B, K-1, C)."""
    window = jnp.concatenate([conv_state, x[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,ck->bc", window, w[:, ::-1]) + b
    return out, window[:, 1:, :]


# ----------------------------------------------------------------- mamba 1 --
def init_mamba1(key, d_model: int, d_state: int, d_conv: int, expand: int,
                dtype) -> Params:
    di = expand * d_model
    dt_rank = max(1, d_model // 16)
    ks = jax.random.split(key, 7)
    return {
        "in_x": _init(ks[0], (d_model, di), dtype=dtype),
        "in_z": _init(ks[5], (d_model, di), dtype=dtype),
        "conv_w": _init(ks[1], (di, d_conv), scale=1.0 / math.sqrt(d_conv),
                        dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * d_state), dtype=dtype),
        "dt_proj": _init(ks[3], (dt_rank, di), scale=1.0, dtype=dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, d_state + 1, dtype=jnp.float32), (di, d_state))
        ).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": _init(ks[4], (di, d_model), dtype=dtype),
    }


def _m1_gates(p, u, dt_rank, d_state):
    """Shared projections: returns x(conv'd), z, dt, B, C."""
    from repro.distributed import sharding as sh
    x = sh.constrain(u @ p["in_x"], "batch", None, "model")
    z = sh.constrain(u @ p["in_z"], "batch", None, "model")
    x = causal_conv1d(x, p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    dbc = x @ p["x_proj"]
    dt = dbc[..., :dt_rank]
    Bs = dbc[..., dt_rank:dt_rank + d_state]
    Cs = dbc[..., dt_rank + d_state:]
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
    return x, z, dt, Bs, Cs


def _chunked_diag_scan(decay, inc, h0, chunk: int):
    """h_t = decay_t ⊙ h_{t-1} + inc_t over axis 1, O(chunk) live memory.

    decay/inc: (B, S, ...); h0: (B, ...).  Returns (all h_t, h_final).
    """
    B, S = decay.shape[:2]
    nc = S // chunk
    assert nc * chunk == S, f"S={S} not divisible by chunk={chunk}"
    d_c = decay.reshape((B, nc, chunk) + decay.shape[2:])
    i_c = inc.reshape((B, nc, chunk) + inc.shape[2:])

    def combine(a, b):
        (da, ia), (db, ib) = a, b
        return da * db, db * ia + ib

    def body(h, xs):
        d, i = xs  # (B, chunk, ...)
        D_cum, I_cum = jax.lax.associative_scan(combine, (d, i), axis=1)
        h_t = D_cum * h[:, None] + I_cum
        return h_t[:, -1], h_t

    h_end, hs = jax.lax.scan(body, h0, (jnp.moveaxis(d_c, 1, 0),
                                        jnp.moveaxis(i_c, 1, 0)))
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, S) + decay.shape[2:])
    return hs, h_end


#: §Perf iteration H1 (EXPERIMENTS.md): fuse gate→decay/inc construction and
#: the y-projection into the chunk scan so the (B,S,d_inner,N) state tensors
#: never round-trip HBM.  REPRO_MAMBA1_FUSED=0 restores the baseline.
FUSED_DEFAULT = os.environ.get("REPRO_MAMBA1_FUSED", "1") == "1"


def _mamba1_core_fused(x, dt, Bs, Cs, A, h0, chunk: int):
    """y_t = C_t·h_t with h materialized only chunk-locally (VMEM-sized)."""
    B, S, di = x.shape
    nc = S // chunk
    assert nc * chunk == S

    def to_chunks(a):
        return jnp.moveaxis(a.reshape((B, nc, chunk) + a.shape[2:]), 1, 0)

    def combine(a, b):
        (da, ia), (db, ib) = a, b
        return da * db, db * ia + ib

    def body(h, xs):
        xc, dtc, bc, cc = xs                          # (B,c,di) / (B,c,N)
        dtf = dtc.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * A)           # (B,c,di,N) temp
        inc = (dtf * xc.astype(jnp.float32))[..., None] \
            * bc.astype(jnp.float32)[:, :, None, :]
        D_cum, I_cum = jax.lax.associative_scan(combine, (decay, inc),
                                                axis=1)
        h_t = D_cum * h[:, None] + I_cum
        y = jnp.einsum("bcdn,bcn->bcd", h_t, cc.astype(jnp.float32))
        return h_t[:, -1], y

    _, ys = jax.lax.scan(body, h0,
                         (to_chunks(x), to_chunks(dt), to_chunks(Bs),
                          to_chunks(Cs)))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, di)


def mamba1_block(p: Params, u, *, d_state: int, chunk: int = 256,
                 fused: Optional[bool] = None):
    """Training/prefill forward; u: (B, S, d_model) → (B, S, d_model)."""
    fused = FUSED_DEFAULT if fused is None else fused
    dt_rank = p["dt_proj"].shape[0]
    x, z, dt, Bs, Cs = _m1_gates(p, u, dt_rank, d_state)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (di, N)
    h0 = jnp.zeros((u.shape[0], x.shape[-1], d_state), jnp.float32)
    if fused:
        y = _mamba1_core_fused(x, dt, Bs, Cs, A, h0,
                               min(chunk, u.shape[1]))
    else:
        dtf = dt.astype(jnp.float32)
        decay = jnp.exp(dtf[..., None] * A)                  # (B,S,di,N)
        inc = (dtf * x.astype(jnp.float32))[..., None] \
            * Bs.astype(jnp.float32)[..., None, :]           # (B,S,di,N)
        hs, _ = _chunked_diag_scan(decay, inc, h0,
                                   min(chunk, u.shape[1]))
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cs.astype(jnp.float32))
    y = y.astype(u.dtype) + p["D"] * x
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba1_decode(p: Params, u, state, *, d_state: int):
    """Single token; u: (B, 1, d); state = {"h": (B,di,N), "conv": (B,K-1,di)}."""
    dt_rank = p["dt_proj"].shape[0]
    x = u[:, 0] @ p["in_x"]
    z = u[:, 0] @ p["in_z"]
    x, conv = conv_decode(x, state["conv"].astype(x.dtype),
                          p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x).astype(u.dtype)
    dbc = x @ p["x_proj"]
    dt = jax.nn.softplus(dbc[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    Bs = dbc[..., dt_rank:dt_rank + d_state]
    Cs = dbc[..., dt_rank + d_state:]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * A)                      # (B,di,N)
    inc = (dtf * x.astype(jnp.float32))[..., None] \
        * Bs.astype(jnp.float32)[..., None, :]
    h = decay * state["h"] + inc
    y = jnp.einsum("bdn,bn->bd", h, Cs.astype(jnp.float32)).astype(u.dtype)
    y = y + p["D"] * x
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None, :].astype(u.dtype)
    return out, {"h": h, "conv": conv.astype(state["conv"].dtype)}


# ----------------------------------------------------------------- mamba 2 --
def init_mamba2(key, d_model: int, d_state: int, d_conv: int, expand: int,
                head_dim: int, dtype) -> Params:
    di = expand * d_model
    H = di // head_dim
    ks = jax.random.split(key, 5)
    return {
        "in_z": _init(ks[0], (d_model, di), dtype=dtype),
        "in_x": _init(ks[3], (d_model, di), dtype=dtype),
        "in_B": _init(ks[4], (d_model, d_state), dtype=dtype),
        "in_C": _init(jax.random.fold_in(ks[4], 1), (d_model, d_state),
                      dtype=dtype),
        "in_dt": _init(jax.random.fold_in(ks[4], 2), (d_model, H),
                       dtype=dtype),
        "conv_w": _init(ks[1], (di, d_conv), scale=1.0 / math.sqrt(d_conv),
                        dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "A_log": jnp.zeros((H,), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "norm": init_rms_norm(di, dtype),
        "out_proj": _init(ks[2], (di, d_model), dtype=dtype),
    }


def _m2_split(p, u, di, d_state, H):
    from repro.distributed import sharding as sh
    z = sh.constrain(u @ p["in_z"], *(("batch", None, "model")
                                      if u.ndim == 3 else ("batch", "model")))
    x = sh.constrain(u @ p["in_x"], *(("batch", None, "model")
                                      if u.ndim == 3 else ("batch", "model")))
    Bs = u @ p["in_B"]
    Cs = u @ p["in_C"]
    dt = jax.nn.softplus(u @ p["in_dt"] + p["dt_bias"])
    return z, x, Bs, Cs, dt


def mamba2_block(p: Params, u, *, d_state: int, head_dim: int,
                 chunk: int = 128, eps: float = 1e-6):
    """SSD chunked forward; u: (B, S, d) → (B, S, d).

    Y_t = C_t · (exp(ΣL) R_chunk + Σ_{j≤t} exp(L_t − L_j) B_j (dt_j x_j))
          + D ⊙ x_t — all chunk-local terms are plain matmuls (MXU).
    """
    B, S, _ = u.shape
    di = p["out_proj"].shape[0]
    H = di // head_dim
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S
    z, x, Bs, Cs, dt = _m2_split(p, u, di, d_state, H)
    x = jax.nn.silu(causal_conv1d(x, p["conv_w"], p["conv_b"]))
    from repro.distributed import sharding as sh
    xh = x.reshape(B, nc, chunk, H, head_dim).astype(jnp.float32)
    xh = sh.constrain(xh, "batch", None, None, "model", None)
    Bc = Bs.reshape(B, nc, chunk, d_state).astype(jnp.float32)
    Cc = Cs.reshape(B, nc, chunk, d_state).astype(jnp.float32)
    dtc = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,)
    logdec = dtc * A                                          # (B,nc,c,H) ≤ 0
    cumL = jnp.cumsum(logdec, axis=2)                         # inclusive
    xdt = xh * dtc[..., None]                                 # (B,nc,c,H,P)

    # intra-chunk: masked decay-weighted attention-like matmul
    scores = jnp.einsum("bnik,bnjk->bnij", Cc, Bc)            # (B,nc,c,c)
    gap = cumL[:, :, :, None, :] - cumL[:, :, None, :, :]     # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    M = jnp.where(tri[None, None, :, :, None], jnp.exp(gap), 0.0)
    y_intra = jnp.einsum("bnij,bnijh,bnjhp->bnihp", scores, M, xdt)

    # chunk summaries and inter-chunk recurrence
    decay_to_end = jnp.exp(cumL[:, :, -1:, :] - cumL)         # (B,nc,c,H)
    S_n = jnp.einsum("bnjh,bnjk,bnjhp->bnhkp", decay_to_end, Bc, xdt)
    a_tot = jnp.exp(cumL[:, :, -1, :])                        # (B,nc,H)

    def body(R, xs):
        s_n, a_n = xs
        R_next = a_n[..., None, None] * R + s_n
        return R_next, R                                      # emit pre-state

    R0 = jnp.zeros((B, H, d_state, head_dim), jnp.float32)
    _, R_stack = jax.lax.scan(body, R0, (jnp.moveaxis(S_n, 1, 0),
                                         jnp.moveaxis(a_tot, 1, 0)))
    R_stack = jnp.moveaxis(R_stack, 0, 1)                     # (B,nc,H,N,P)
    y_inter = jnp.einsum("bnik,bnih,bnhkp->bnihp",
                         Cc, jnp.exp(cumL), R_stack)

    y = (y_intra + y_inter).reshape(B, S, H, head_dim)
    y = y + xh.reshape(B, S, H, head_dim) * p["D"].astype(jnp.float32)[..., None]
    y = y.reshape(B, S, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], eps)
    return y @ p["out_proj"]


def mamba2_decode(p: Params, u, state, *, d_state: int, head_dim: int,
                  eps: float = 1e-6):
    """Single token; state = {"h": (B,H,N,P), "conv": (B,K-1,di)}."""
    B = u.shape[0]
    di = p["out_proj"].shape[0]
    H = di // head_dim
    z, x, Bs, Cs, dt = _m2_split(p, u[:, 0], di, d_state, H)
    x, conv = conv_decode(x, state["conv"].astype(x.dtype),
                          p["conv_w"], p["conv_b"])
    x = jax.nn.silu(x)
    xh = x.reshape(B, H, head_dim).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt.astype(jnp.float32) * A)                   # (B,H)
    inc = jnp.einsum("bk,bhp->bhkp", Bs.astype(jnp.float32),
                     xh * dt.astype(jnp.float32)[..., None])
    h = a[..., None, None] * state["h"] + inc
    y = jnp.einsum("bk,bhkp->bhp", Cs.astype(jnp.float32), h)
    y = y + xh * p["D"].astype(jnp.float32)[..., None]
    y = y.reshape(B, di).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], eps)
    return ((y @ p["out_proj"])[:, None, :].astype(u.dtype),
            {"h": h, "conv": conv.astype(state["conv"].dtype)})


def init_ssm(key, cfg, dtype) -> Params:
    if cfg.ssm_type == "mamba1":
        return init_mamba1(key, cfg.d_model, cfg.ssm_state, cfg.ssm_conv,
                           cfg.ssm_expand, dtype)
    return init_mamba2(key, cfg.d_model, cfg.ssm_state, cfg.ssm_conv,
                       cfg.ssm_expand, cfg.ssm_head_dim, dtype)


def ssm_block(p: Params, u, cfg, chunk: int = 0):
    # chunk=1024 from §Perf H1 iterations 3-4: larger chunks amortize the
    # per-iteration scan traffic (smaller chunks were measured WORSE).
    chunk = chunk or int(os.environ.get(
        "REPRO_SSM_CHUNK", 1024 if cfg.ssm_type == "mamba1" else 128))
    if cfg.ssm_type == "mamba1":
        return mamba1_block(p, u, d_state=cfg.ssm_state, chunk=chunk)
    return mamba2_block(p, u, d_state=cfg.ssm_state,
                        head_dim=cfg.ssm_head_dim, chunk=chunk,
                        eps=cfg.norm_eps)


def ssm_decode(p: Params, u, state, cfg):
    if cfg.ssm_type == "mamba1":
        return mamba1_decode(p, u, state, d_state=cfg.ssm_state)
    return mamba2_decode(p, u, state, d_state=cfg.ssm_state,
                         head_dim=cfg.ssm_head_dim, eps=cfg.norm_eps)


def init_ssm_state(cfg, batch: int, dtype=jnp.float32) -> Params:
    di = cfg.d_inner
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype)
    if cfg.ssm_type == "mamba1":
        h = jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)
    else:
        h = jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                       cfg.ssm_head_dim), jnp.float32)
    return {"h": h, "conv": conv}
