"""AdamW with global-norm clipping — no optax dependency, fully sharded.

Optimizer state mirrors parameter sharding (each moment tensor inherits its
parameter's PartitionSpec), so the update is purely local per device; the
only cross-device work in a train step is the gradient reduction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """One AdamW step; returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    count = state.count + 1
    lr = schedule(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / c1
        nhat = nu / c2
        step_ = mhat / (jnp.sqrt(nhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step_ + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(new_mu, new_nu, count), stats
