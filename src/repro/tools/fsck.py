"""Structural validation of scda files (``scdatool fsck``).

Walks the section stream front to back, re-deriving every offset the way a
reader must, and checks everything the format makes checkable:

* file header: magic bytes, version range, vendor/user padding;
* section headers and count entries, including the per-element entry
  tables of V sections with STRICT letter enforcement (the normal skip
  path is deliberately lenient there, §A.5.1);
* §3 compression framing: base64 line geometry, the 'z' marker, the
  deflate stream's adler32, and the redundant size checks — every
  compressed payload is actually inflated (unless ``deep=False``);
* truncation: no section may extend past end of file, and the final
  section's padding must land exactly ON end of file (trailing garbage
  fails the next header parse and is reported as corruption at the
  EXACT byte offset where validation failed — the reader attaches
  ``ScdaError.offset`` to parse failures, so a valid prefix followed by
  garbage points at the failing entry/byte, not just at the section
  boundary; mode-'a' appends rely on this to make tail-validation
  errors actionable);
* data padding: the length is normative and enforced by offset
  arithmetic; the pad *bytes* are only advisory per §2.1.2 ("may consist
  of p arbitrary bytes"), so a pad matching neither the Unix nor the
  MIME discipline is reported as a warning, not an error;
* an existing ``.scdax`` sidecar, when present, is deep-verified against
  the file (stale sidecars are findings too);
* delta checkpoints (manifest version 2): every referenced base archive
  must exist, parse, and still match the content id recorded when the
  delta was saved — a deleted or rewritten base makes the delta
  unrestorable and is an error; with ``deep=True`` every chunk across
  the chain is additionally digest-verified (CRC32 + SHA-256);
* sharded-set manifests: every shard the manifest names must exist,
  match its recorded byte size and pinned content id, and pass its own
  fsck (recursively, same depth) — one fsck of the manifest validates
  the whole multi-file checkpoint.

Corruption cannot be resynced in a stream format — the walk stops at the
first structural error; warnings accumulate.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

from repro.core import spec
from repro.core.errors import ScdaError
from repro.core.index import SIDECAR_SUFFIX, ScdaIndex
from repro.core.io_backend import FileBackend, fsync_dir
from repro.core.reader import fopen_read
from repro.core.writer import validate_tail


@dataclasses.dataclass
class Finding:
    severity: str            # "error" | "warning"
    offset: int              # byte offset the finding anchors to
    section: Optional[int]   # logical section number, None for file-level
    message: str

    def __str__(self) -> str:
        where = f"section {self.section}" if self.section is not None \
            else "file"
        return f"{self.severity}: @{self.offset} ({where}): {self.message}"


def _payload_bytes(r, p) -> int:
    """On-disk data bytes of the pending section (strict-parses V tables)."""
    if p.kind == "I":
        return spec.INLINE_DATA_BYTES
    if p.kind == "B":
        return p.header.E
    if p.kind == "zB":
        return p.raw_E
    if p.kind == "A":
        return p.header.N * p.header.E
    entries = p.entries_start if p.kind == "V" else p.v_entries_start
    return sum(r._parse_entries(entries, 0, p.header.N, b"E"))


def _check_section(r, deep: bool) -> None:
    """Consume the pending section, validating as much as ``deep`` asks."""
    p = r._pending
    kind = p.kind
    N = p.header.N
    if kind == "I":
        r.read_inline_data()
    elif kind in ("B", "zB"):
        if deep:
            r.read_block_data()       # zB: inflate + adler32 + size check
        else:
            r.skip_data()
    elif kind == "A":
        r.skip_data()                 # raw payload: bounds are the check
    elif kind == "zA":
        if deep:
            r.read_array_data([N])    # inflate every element, verify E
        else:
            r.skip_data()
    elif kind == "V":
        r.skip_data()
    else:  # zV
        sizes = r.read_varray_sizes([N])   # strict 'U' entry parse
        if deep:
            r.read_varray_data([N], sizes)  # inflate, verify per-element U
        else:
            r.skip_data()


def _expected_extent(p, payload: int) -> int:
    """The section's on-disk size from spec arithmetic alone.

    Cross-checks the reader's cursor bookkeeping against an independent
    derivation — the two agreeing is a structural invariant of the format.
    """
    kind, hdr = p.kind, p.header
    if kind == "I":
        return spec.inline_section_bytes()
    if kind == "B":
        return spec.block_section_bytes(hdr.E)
    if kind == "zB":
        return spec.encoded_block_section_bytes(p.raw_E)
    if kind == "A":
        return spec.array_section_bytes(hdr.N, hdr.E)
    if kind == "V":
        return spec.varray_section_bytes(hdr.N, payload)
    if kind == "zA":
        return spec.encoded_array_section_bytes(hdr.N, payload)
    return spec.encoded_varray_section_bytes(hdr.N, payload)


def _pad_warning(backend, kind: str, data_region: int, payload: int,
                 end: int) -> Optional[str]:
    """Check the pad bytes against both canonical styles (advisory)."""
    if kind == "I":
        return None  # inline sections carry exactly 32 bytes, no padding
    pad = backend.pread(data_region + payload, end - data_region - payload)
    last = backend.pread(data_region + payload - 1, 1)[0] if payload else None
    for style in (spec.UNIX, spec.MIME):
        if pad == spec.pad_data(payload, last, style):
            return None
    return (f"data padding matches neither Unix nor MIME style "
            f"(legal per §2.1.2, but unusual): {pad[:16]!r}")


def _read_checkpoint_doc(path: str):
    """The repro-checkpoint manifest of ``path`` (flat or sharded-set),
    or None if it has no manifest section.  Reads only the manifest
    block (no jax, no leaf payloads) — fsck stays cheap on
    non-checkpoint archives."""
    from repro.checkpoint import manifest as mf
    with fopen_read(None, path) as r:
        idx = r.index()
        sec = idx.find(mf.MANIFEST_USER_STRING)
        if sec >= 0:
            r.seek_section(sec)
            return mf.parse(r.read_block_data())
        sec = idx.find(mf.SHARDS_MANIFEST_USER_STRING)
        if sec >= 0:
            r.seek_section(sec)
            return mf.parse_sharded(r.read_block_data())
        return None


def _check_delta_chain(path: str, deep: bool,
                       findings: List[Finding]) -> None:
    """Chain-level findings for delta checkpoints.

    A structurally valid delta archive is still unrestorable if any base
    it references was deleted or rewritten in place — those are errors
    anchored at the manifest, not at a byte of this file.  ``deep``
    additionally digest-verifies every chunk across the chain.
    """
    from repro.checkpoint import manifest as mf
    try:
        doc = _read_checkpoint_doc(path)
    except (ScdaError, OSError, ValueError):
        return  # not a readable checkpoint: nothing chain-level to check
    if not doc or not doc.get("delta"):
        return
    base_dir = os.path.dirname(os.path.abspath(path))
    ok = True
    for k, b in enumerate(doc["delta"].get("bases", []), start=1):
        name = b.get("file", "")
        bpath = os.path.join(base_dir, name)
        try:
            bdoc = _read_checkpoint_doc(bpath)
        except (ScdaError, OSError, ValueError) as e:
            findings.append(Finding(
                "error", 0, None, f"delta base #{k} {name!r}: {e}"))
            ok = False
            continue
        if bdoc is None:
            findings.append(Finding(
                "error", 0, None,
                f"delta base #{k} {name!r}: not a checkpoint archive"))
            ok = False
            continue
        got = mf.content_id(bdoc)
        if got != b.get("id"):
            findings.append(Finding(
                "error", 0, None,
                f"delta base #{k} {name!r}: content id {got} != recorded "
                f"{b.get('id')} — base rewritten since this delta was "
                f"saved"))
            ok = False
    if deep and ok:
        from repro.checkpoint.delta import verify_chain
        try:
            for problem in verify_chain(path):
                findings.append(Finding("error", 0, None,
                                        f"chain: {problem}"))
        except (ScdaError, OSError, ValueError) as e:
            findings.append(Finding("error", 0, None, f"chain: {e}"))


def _check_sharded_set(path: str, deep: bool, check_sidecar: bool,
                       findings: List[Finding]) -> None:
    """Set-level findings for sharded checkpoint manifests.

    The manifest file itself is tiny and already walked; what can rot is
    the set it names — a shard deleted, truncated, or rewritten in place.
    ``verify_set`` reports those by shard name; every shard still on disk
    is then fsck'd recursively (same depth), so one ``scdatool fsck
    MANIFEST`` validates the whole checkpoint."""
    from repro.checkpoint import manifest as mf, sharding
    try:
        with fopen_read(None, path) as r:
            if r.index().find(mf.SHARDS_MANIFEST_USER_STRING) < 0:
                return
    except (ScdaError, OSError):
        return
    for p in sharding.verify_set(path):
        findings.append(Finding("error", 0, None, f"set: {p}"))
    try:
        doc = sharding.read_sharded_manifest(path)
    except (ScdaError, OSError, ValueError):
        return  # verify_set already reported the manifest unreadable
    base = os.path.dirname(os.path.abspath(path))
    for k, srec in enumerate(doc.get("shards", [])):
        name = srec.get("file", "")
        spath = os.path.join(base, name)
        if not os.path.exists(spath):
            continue  # missing: already an error, named by verify_set
        for f in fsck_file(spath, deep=deep, check_sidecar=check_sidecar):
            findings.append(Finding(f.severity, f.offset, f.section,
                                    f"shard #{k} {name!r}: {f.message}"))
    for j, rec in enumerate((doc.get("parity") or {}).get("files", [])):
        name = rec.get("file", "")
        ppath = os.path.join(base, name)
        if not os.path.exists(ppath):
            continue  # missing: reported below via set health
        for f in fsck_file(ppath, deep=deep, check_sidecar=check_sidecar):
            findings.append(Finding(f.severity, f.offset, f.section,
                                    f"parity #{j} {name!r}: {f.message}"))
    # Erasure-code health: a finding names the verdict and the exact
    # shard files it rests on, so "is this checkpoint still restorable"
    # never requires reading the errors above back together.
    from repro.checkpoint import redundancy as red
    health, lost_data, lost_parity = red.set_health(path, doc)
    lost = ", ".join(lost_data + lost_parity)
    if health == "degraded-recoverable":
        findings.append(Finding(
            "warning", 0, None,
            f"set health: degraded-recoverable — lost {lost}; every "
            f"leaf still restores through parity (rebuild with "
            f"`scdatool repair --rebuild`)"))
    elif health == "unrecoverable":
        findings.append(Finding(
            "error", 0, None,
            f"set health: unrecoverable — lost {lost} exceeds the "
            f"parity budget"))


def fsck_file(path: str, deep: bool = True,
              check_sidecar: bool = True) -> List[Finding]:
    """Validate ``path``; returns findings (empty = clean)."""
    findings: List[Finding] = []
    try:
        r = fopen_read(None, path)
    except ScdaError as e:
        findings.append(Finding("error", 0, None, str(e)))
        return findings
    with r:
        sec = 0
        while not r.at_eof:
            start = r.cursor
            try:
                r.read_section_header(decode=True)
                p = r._pending
                data_region = (p.v_data_start
                               if p.kind in ("zA", "zV") else p.data_start)
                payload = _payload_bytes(r, p)
                _check_section(r, deep)
                if r.cursor - start != _expected_extent(p, payload):
                    findings.append(Finding(
                        "error", start, sec,
                        f"section extent {r.cursor - start} != spec "
                        f"arithmetic {_expected_extent(p, payload)}"))
                    return findings
                warn = _pad_warning(r._backend, p.kind, data_region,
                                    payload, r.cursor)
                if warn:
                    findings.append(Finding("warning", data_region + payload,
                                            sec, warn))
            except ScdaError as e:
                # Anchor the finding at the exact failing byte when the
                # reader pinned one (malformed entry, EOF position, bad
                # header) — "trailing garbage exists" becomes "validation
                # failed at byte X, section started at Y".
                at = e.offset if e.offset is not None else start
                msg = str(e)
                if e.offset is not None and e.offset != start:
                    msg += (f" (validation failed at byte {e.offset}; "
                            f"section started at {start})")
                findings.append(Finding("error", at, sec, msg))
                return findings  # a stream format cannot resync
            sec += 1
    if check_sidecar and os.path.exists(path + SIDECAR_SUFFIX):
        try:
            ScdaIndex.load_sidecar(path).verify(deep=True)
        except ScdaError as e:
            findings.append(Finding("error", 0, None,
                                    f"sidecar {path + SIDECAR_SUFFIX}: {e}"))
    _check_delta_chain(path, deep, findings)
    _check_sharded_set(path, deep, check_sidecar, findings)
    return findings


# --------------------------------------------------------------------------
# Repair (``scdatool repair``)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RepairResult:
    """Outcome of :func:`repair_file` on one archive.

    ``action`` is one of ``"clean"`` (nothing to do), ``"repaired"``,
    ``"would-repair"`` (dry run found damage), or ``"unrecoverable"``
    (no valid prefix — e.g. a corrupt file header).
    """
    path: str
    action: str
    valid_bytes: int = 0         # prefix kept (the truncation point)
    sections: int = 0            # whole sections surviving the repair
    dropped_bytes: int = 0       # damaged tail removed (or would be)
    quarantine: Optional[str] = None  # where the damaged bytes went
    sidecar: Optional[str] = None     # rebuilt sidecar, if one existed
    detail: str = ""

    def __str__(self) -> str:
        s = f"{self.path}: {self.action}"
        if self.action == "clean":
            return s + f" ({self.sections} sections, {self.valid_bytes} bytes)"
        if self.action == "unrecoverable":
            return s + f": {self.detail}"
        if self.action in ("rebuilt", "would-rebuild"):
            return s + f": {self.detail} ({self.valid_bytes} bytes)"
        s += (f": kept {self.sections} sections / {self.valid_bytes} bytes, "
              f"dropped {self.dropped_bytes} damaged bytes at offset "
              f"{self.valid_bytes}")
        if self.quarantine:
            s += f" -> {self.quarantine}"
        if self.sidecar:
            s += f" (sidecar rebuilt: {self.sidecar})"
        return s


def repair_file(path: str, quarantine: bool = True, dry_run: bool = False,
                sidecar: bool = True) -> RepairResult:
    """Salvage the valid section prefix of a damaged archive.

    Reuses the mode-'a' tail validator with ``recover=True``: everything
    before the first structural failure is a complete, fsck-clean
    archive — the damaged tail is cut at that exact byte.  With
    ``quarantine`` the removed bytes are preserved verbatim in
    ``<path>.quarantine-<offset>`` (forensics, nothing is destroyed);
    with ``sidecar`` an existing ``.scdax`` is rebuilt to describe the
    repaired file (checksums preserved if the old one recorded them).
    ``dry_run`` reports what would happen without touching the file.
    """
    try:
        size = os.stat(path).st_size
    except OSError as e:
        return RepairResult(path, "unrecoverable", detail=str(e))
    try:
        tail = validate_tail(path, recover=True)
    except ScdaError as e:
        return RepairResult(path, "unrecoverable", detail=str(e),
                            dropped_bytes=size)
    if tail.truncate_to is None:
        return RepairResult(path, "clean", valid_bytes=tail.end,
                            sections=tail.sections)
    cut = tail.truncate_to
    res = RepairResult(path, "would-repair" if dry_run else "repaired",
                       valid_bytes=cut, sections=tail.sections,
                       dropped_bytes=size - cut)
    if dry_run:
        return res
    b = FileBackend(path, "a", create=False)
    try:
        if quarantine and size > cut:
            qpath = f"{path}.quarantine-{cut}"
            damaged = b.pread(cut, size - cut)
            with open(qpath, "wb") as qf:
                qf.write(damaged)
                qf.flush()
                os.fsync(qf.fileno())
            res.quarantine = qpath
        b.truncate(cut)
        b.fsync()
    finally:
        b.close()
    # The truncation (and the quarantine file) must survive a power cut
    # just like a commit would.
    fsync_dir(os.path.dirname(os.path.abspath(path)))
    if sidecar and os.path.exists(path + SIDECAR_SUFFIX):
        try:
            idx = ScdaIndex.refresh_sidecar(path)
            if idx is not None:
                res.sidecar = path + SIDECAR_SUFFIX
        except (ScdaError, OSError) as e:
            res.detail = f"sidecar rebuild failed: {e}"
    return res


def is_sharded_manifest(path: str) -> bool:
    """True when ``path``'s valid prefix contains a sharded-set manifest."""
    from repro.checkpoint import manifest as mf
    try:
        with fopen_read(None, path) as r:
            try:
                idx = r.index()
            except ScdaError as e:
                if e.group != 1:
                    raise
                idx = ScdaIndex.build_prefix(r)
            return idx.find(mf.SHARDS_MANIFEST_USER_STRING) >= 0
    except (ScdaError, OSError):
        return False


def sibling_shards_exist(path: str) -> bool:
    """True when files named like shards of a set at ``path`` exist —
    how ``scdatool repair`` recognizes a sharded set whose manifest is
    too damaged for :func:`is_sharded_manifest` to say so."""
    from repro.checkpoint import sharding
    d = os.path.dirname(os.path.abspath(path))
    mname = os.path.basename(path)
    stem = mname[:-len(".scda")] if mname.endswith(".scda") else mname
    try:
        siblings = os.listdir(d)
    except OSError:
        return False
    for f in siblings:
        m = sharding._SHARD_RE.match(f)
        if m and m.group("stem") == stem:
            return True
    return False


def repair_set(path: str, quarantine: bool = True, dry_run: bool = False,
               sidecar: bool = True,
               rebuild: bool = False) -> List[RepairResult]:
    """Repair a sharded checkpoint set, reporting per-shard damage.

    The manifest file is repaired first (its own tail can be torn), then
    every shard it names — a damaged shard is salvaged independently
    instead of the whole set being refused.  When the manifest itself is
    beyond tail-salvage, repair falls back to the surviving shard
    archives: each is repaired on its own and a fresh manifest is
    rebuilt from their headers (see :func:`_rebuild_set_manifest`).

    With ``rebuild`` (``scdatool repair --rebuild``) a missing or
    wrong-sized shard of a parity-carrying set is re-materialized in
    place from the survivors — byte-identical to the lost original,
    dir-fsynced, content-id-verified before the rename lands.  Without
    parity (or past the parity budget) those stay unrecoverable
    entries; the manifest is never rewritten to drop them (that would
    change what was committed).
    """
    from repro.checkpoint import redundancy as red, sharding
    results = [repair_file(path, quarantine=quarantine, dry_run=dry_run,
                           sidecar=sidecar)]
    doc = None
    if results[0].action != "unrecoverable":
        try:
            doc = sharding.read_sharded_manifest(path)
        except (ScdaError, OSError, ValueError) as e:
            results[0].detail = f"manifest unreadable after repair: {e}"
    if doc is None:
        return _rebuild_set_manifest(path, quarantine=quarantine,
                                     dry_run=dry_run, sidecar=sidecar,
                                     results=results)
    base = os.path.dirname(os.path.abspath(path))
    for k, srec in enumerate(doc.get("shards", [])):
        name = srec.get("file", "")
        spath = os.path.join(base, name)
        lost = not os.path.exists(spath) \
            or os.path.getsize(spath) != srec.get("bytes")
        if lost and rebuild:
            try:
                size = red.rebuild_shard(path, doc, name, dry_run=dry_run)
                results.append(RepairResult(
                    spath, "would-rebuild" if dry_run else "rebuilt",
                    valid_bytes=size,
                    detail=f"shard #{k} reconstructed from surviving "
                           f"shards + parity"))
            except (ScdaError, OSError) as e:
                results.append(RepairResult(
                    spath, "unrecoverable", detail=f"shard #{k}: {e}"))
            continue
        if not os.path.exists(spath):
            results.append(RepairResult(
                spath, "unrecoverable",
                detail=f"shard #{k} named by the manifest is missing"
                       + ("" if not (doc.get("parity") or {})
                          else " (recoverable: rerun with --rebuild)")))
            continue
        r = repair_file(spath, quarantine=quarantine, dry_run=dry_run,
                        sidecar=sidecar)
        r.detail = (f"shard #{k}" + (f": {r.detail}" if r.detail else ""))
        results.append(r)
    for j, rec in enumerate((doc.get("parity") or {}).get("files", [])):
        name = rec.get("file", "")
        ppath = os.path.join(base, name)
        problems = red.verify_parity_file(ppath, rec)
        if not problems:
            results.append(RepairResult(
                ppath, "clean", valid_bytes=int(rec.get("bytes", 0)),
                detail=f"parity #{j}"))
            continue
        if rebuild:
            try:
                size = red.rebuild_shard(path, doc, name, dry_run=dry_run)
                results.append(RepairResult(
                    ppath, "would-rebuild" if dry_run else "rebuilt",
                    valid_bytes=size,
                    detail=f"parity #{j} recomputed from the data "
                           f"shards"))
            except (ScdaError, OSError) as e:
                results.append(RepairResult(
                    ppath, "unrecoverable", detail=f"parity #{j}: {e}"))
        else:
            results.append(RepairResult(
                ppath, "unrecoverable",
                detail=f"parity #{j}: {problems[0]} (recoverable: rerun "
                       f"with --rebuild)"))
    return results


def _rebuild_set_manifest(path: str, *, quarantine: bool, dry_run: bool,
                          sidecar: bool,
                          results: List[RepairResult]) -> List[RepairResult]:
    """Fallback for a sharded set whose MANIFEST is damaged beyond tail
    salvage: repair every sibling shard independently, then rebuild the
    manifest from the surviving shard headers.

    Everything the manifest records is re-derivable from the shards
    themselves — content ids and byte sizes from the repaired files,
    leaf placement from each shard's own manifest (ordered by
    ``(shard, index)``; the original global manifest order is gone, which
    is harmless: restore resolves leaves by name), the step from the
    status inline, the parity record from surviving parity meta blocks.
    Only set-level ``aux`` values are truly unrecoverable — they lived
    nowhere but the manifest — and are reported loudly.  Data shards
    missing from disk are reconstructed from parity first when the
    surviving rows cover them.
    """
    from repro.checkpoint import manifest as mf, redundancy as red, sharding
    d = os.path.dirname(os.path.abspath(path))
    mname = os.path.basename(path)
    stem = mname[:-len(".scda")] if mname.endswith(".scda") else mname
    shard_names: dict = {}
    n = None
    for f in sorted(os.listdir(d)):
        m = sharding._SHARD_RE.match(f)
        if m and m.group("stem") == stem:
            shard_names[int(m.group("k"))] = f
            n = int(m.group("n"))
    if n is None:
        results[0].action = "unrecoverable"
        results[0].detail += ("; no sibling shard files found — the "
                              "manifest cannot be rebuilt")
        return results
    # Surviving parity rows, keyed by row index j (position == j in the
    # manifest record, which is what the reconstructor checks against).
    parity_meta: dict = {}
    m_rows = 0
    for f in sorted(os.listdir(d)):
        g = red._PARITY_RE.match(f)
        if not g or g.group("stem") != stem:
            continue
        m_rows = max(m_rows, int(g.group("m")))
        try:
            meta = red.read_parity_meta(os.path.join(d, f))
        except (ScdaError, OSError, ValueError):
            continue
        if meta.get("n") == n:
            parity_meta[int(meta["j"])] = (f, meta)
    for k in sorted(shard_names):
        r = repair_file(os.path.join(d, shard_names[k]),
                        quarantine=quarantine, dry_run=dry_run,
                        sidecar=sidecar)
        r.detail = (f"shard #{k}" + (f": {r.detail}" if r.detail else ""))
        results.append(r)
    missing = [k for k in range(n) if k not in shard_names]
    if missing and parity_meta:
        # Parity meta records every shard's name and size — enough to
        # reconstruct the lost byte streams before reading any headers.
        meta = parity_meta[sorted(parity_meta)[0]][1]
        sizes = meta.get("sizes", [])
        names = meta.get("shards", [])
        pseudo = {
            "shards": [{"file": nm, "bytes": sz}
                       for nm, sz in zip(names, sizes)],
            "parity": {"code": meta.get("code"), "m": meta.get("m"),
                       "length": meta.get("length"),
                       "files": [
                           {"file": parity_meta[j][0],
                            "id": red.parity_id(parity_meta[j][1])}
                           if j in parity_meta else
                           {"file": red.parity_file(path, j,
                                                    int(meta.get("m", 0))),
                            "id": ""}
                           for j in range(int(meta.get("m", 0)))]},
        }
        for k in missing:
            name = names[k] if k < len(names) else \
                os.path.basename(sharding.shard_file(path, k, n))
            spath = os.path.join(d, name)
            try:
                recon = red.SetReconstructor(path, pseudo, lost=(name,))
            except (ScdaError, OSError) as e:
                results.append(RepairResult(
                    spath, "unrecoverable", detail=f"shard #{k}: {e}"))
                continue
            try:
                size = recon.shard_size(name)
                if not dry_run:
                    tmp = spath + ".rebuild"
                    with open(tmp, "wb") as out:
                        step_bytes = 4 << 20
                        for off in range(0, size, step_bytes):
                            out.write(recon.read(
                                name, off, min(step_bytes, size - off)))
                        out.flush()
                        os.fsync(out.fileno())
                    os.replace(tmp, spath)
                    fsync_dir(d)
                    shard_names[k] = name
                results.append(RepairResult(
                    spath, "would-rebuild" if dry_run else "rebuilt",
                    valid_bytes=size,
                    detail=f"shard #{k} reconstructed from surviving "
                           f"shards + parity"))
            except (ScdaError, OSError) as e:
                results.append(RepairResult(
                    spath, "unrecoverable", detail=f"shard #{k}: {e}"))
            finally:
                recon.close()
        missing = [k for k in range(n) if k not in shard_names]
    if missing:
        results[0].action = "unrecoverable"
        results[0].detail += (
            f"; shard(s) {sorted(missing)} are gone and no parity row "
            f"covers them — the manifest cannot be rebuilt")
        return results
    shard_recs, placed, step = [], [], None
    for k in sorted(shard_names):
        spath = os.path.join(d, shard_names[k])
        try:
            sdoc = _read_checkpoint_doc(spath)
        except (ScdaError, OSError, ValueError) as e:
            results[0].action = "unrecoverable"
            results[0].detail += (f"; shard #{k} has no readable "
                                  f"checkpoint manifest ({e})")
            return results
        if sdoc is None:
            results[0].action = "unrecoverable"
            results[0].detail += (f"; shard #{k} is not a checkpoint "
                                  f"archive")
            return results
        if step is None:
            step = sdoc.get("step")
        shard_recs.append({"file": shard_names[k],
                           "id": mf.content_id(sdoc),
                           "bytes": int(os.path.getsize(spath)),
                           "leaves": len(sdoc.get("leaves", []))})
        for j, leaf in enumerate(sdoc.get("leaves", [])):
            placed.append({"name": leaf["name"], "shard": k, "index": j,
                           "nbytes": leaf["nbytes"]})
    doc = {"format": mf.SHARDED_FORMAT, "version": mf.SHARDED_VERSION,
           "step": step, "aux": {}, "shards": shard_recs,
           "leaves": placed}
    if parity_meta:
        j0 = sorted(parity_meta)[0]
        meta0 = parity_meta[j0][1]
        prec = {"code": meta0.get("code"), "m": int(meta0.get("m", 0)),
                "length": int(meta0.get("length", 0)), "files": []}
        for j in range(prec["m"]):
            if j in parity_meta:
                f, meta = parity_meta[j]
                prec["files"].append({
                    "file": f, "id": red.parity_id(meta),
                    "bytes": int(os.path.getsize(os.path.join(d, f)))})
            else:
                # The row is gone; record the expected name with its
                # real id unknown — repair --rebuild recomputes it.
                prec["files"].append({
                    "file": os.path.basename(
                        red.parity_file(path, j, prec["m"])),
                    "id": "", "bytes": 0})
        doc["parity"] = prec
    res = RepairResult(path, "would-rebuild" if dry_run else "rebuilt",
                       valid_bytes=0, sections=2,
                       detail="manifest rebuilt from shard headers; "
                              "set-level aux entries (if any) were only "
                              "recorded in the manifest and are LOST")
    if not dry_run:
        from repro.core.writer import fopen_write
        tmp = path + ".rebuild"
        with fopen_write(None, tmp,
                         user_string=mf.SHARDS_FILE_USER_STRING,
                         sync=True) as f:
            f.write_inline(mf.STATUS_USER_STRING, mf.status_inline(step))
            f.write_block(mf.SHARDS_MANIFEST_USER_STRING,
                          mf.build_sharded(doc), E=None)
        os.replace(tmp, path)
        fsync_dir(d)
        res.valid_bytes = os.path.getsize(path)
    results[0] = res
    return results
