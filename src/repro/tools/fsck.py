"""Structural validation of scda files (``scdatool fsck``).

Walks the section stream front to back, re-deriving every offset the way a
reader must, and checks everything the format makes checkable:

* file header: magic bytes, version range, vendor/user padding;
* section headers and count entries, including the per-element entry
  tables of V sections with STRICT letter enforcement (the normal skip
  path is deliberately lenient there, §A.5.1);
* §3 compression framing: base64 line geometry, the 'z' marker, the
  deflate stream's adler32, and the redundant size checks — every
  compressed payload is actually inflated (unless ``deep=False``);
* truncation: no section may extend past end of file, and the final
  section's padding must land exactly ON end of file (trailing garbage
  fails the next header parse and is reported as corruption at the
  EXACT byte offset where validation failed — the reader attaches
  ``ScdaError.offset`` to parse failures, so a valid prefix followed by
  garbage points at the failing entry/byte, not just at the section
  boundary; mode-'a' appends rely on this to make tail-validation
  errors actionable);
* data padding: the length is normative and enforced by offset
  arithmetic; the pad *bytes* are only advisory per §2.1.2 ("may consist
  of p arbitrary bytes"), so a pad matching neither the Unix nor the
  MIME discipline is reported as a warning, not an error;
* an existing ``.scdax`` sidecar, when present, is deep-verified against
  the file (stale sidecars are findings too);
* delta checkpoints (manifest version 2): every referenced base archive
  must exist, parse, and still match the content id recorded when the
  delta was saved — a deleted or rewritten base makes the delta
  unrestorable and is an error; with ``deep=True`` every chunk across
  the chain is additionally digest-verified (CRC32 + SHA-256);
* sharded-set manifests: every shard the manifest names must exist,
  match its recorded byte size and pinned content id, and pass its own
  fsck (recursively, same depth) — one fsck of the manifest validates
  the whole multi-file checkpoint.

Corruption cannot be resynced in a stream format — the walk stops at the
first structural error; warnings accumulate.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional

from repro.core import spec
from repro.core.errors import ScdaError
from repro.core.index import SIDECAR_SUFFIX, ScdaIndex
from repro.core.io_backend import FileBackend, fsync_dir
from repro.core.reader import fopen_read
from repro.core.writer import validate_tail


@dataclasses.dataclass
class Finding:
    severity: str            # "error" | "warning"
    offset: int              # byte offset the finding anchors to
    section: Optional[int]   # logical section number, None for file-level
    message: str

    def __str__(self) -> str:
        where = f"section {self.section}" if self.section is not None \
            else "file"
        return f"{self.severity}: @{self.offset} ({where}): {self.message}"


def _payload_bytes(r, p) -> int:
    """On-disk data bytes of the pending section (strict-parses V tables)."""
    if p.kind == "I":
        return spec.INLINE_DATA_BYTES
    if p.kind == "B":
        return p.header.E
    if p.kind == "zB":
        return p.raw_E
    if p.kind == "A":
        return p.header.N * p.header.E
    entries = p.entries_start if p.kind == "V" else p.v_entries_start
    return sum(r._parse_entries(entries, 0, p.header.N, b"E"))


def _check_section(r, deep: bool) -> None:
    """Consume the pending section, validating as much as ``deep`` asks."""
    p = r._pending
    kind = p.kind
    N = p.header.N
    if kind == "I":
        r.read_inline_data()
    elif kind in ("B", "zB"):
        if deep:
            r.read_block_data()       # zB: inflate + adler32 + size check
        else:
            r.skip_data()
    elif kind == "A":
        r.skip_data()                 # raw payload: bounds are the check
    elif kind == "zA":
        if deep:
            r.read_array_data([N])    # inflate every element, verify E
        else:
            r.skip_data()
    elif kind == "V":
        r.skip_data()
    else:  # zV
        sizes = r.read_varray_sizes([N])   # strict 'U' entry parse
        if deep:
            r.read_varray_data([N], sizes)  # inflate, verify per-element U
        else:
            r.skip_data()


def _expected_extent(p, payload: int) -> int:
    """The section's on-disk size from spec arithmetic alone.

    Cross-checks the reader's cursor bookkeeping against an independent
    derivation — the two agreeing is a structural invariant of the format.
    """
    kind, hdr = p.kind, p.header
    if kind == "I":
        return spec.inline_section_bytes()
    if kind == "B":
        return spec.block_section_bytes(hdr.E)
    if kind == "zB":
        return spec.encoded_block_section_bytes(p.raw_E)
    if kind == "A":
        return spec.array_section_bytes(hdr.N, hdr.E)
    if kind == "V":
        return spec.varray_section_bytes(hdr.N, payload)
    if kind == "zA":
        return spec.encoded_array_section_bytes(hdr.N, payload)
    return spec.encoded_varray_section_bytes(hdr.N, payload)


def _pad_warning(backend, kind: str, data_region: int, payload: int,
                 end: int) -> Optional[str]:
    """Check the pad bytes against both canonical styles (advisory)."""
    if kind == "I":
        return None  # inline sections carry exactly 32 bytes, no padding
    pad = backend.pread(data_region + payload, end - data_region - payload)
    last = backend.pread(data_region + payload - 1, 1)[0] if payload else None
    for style in (spec.UNIX, spec.MIME):
        if pad == spec.pad_data(payload, last, style):
            return None
    return (f"data padding matches neither Unix nor MIME style "
            f"(legal per §2.1.2, but unusual): {pad[:16]!r}")


def _read_checkpoint_doc(path: str):
    """The repro-checkpoint manifest of ``path`` (flat or sharded-set),
    or None if it has no manifest section.  Reads only the manifest
    block (no jax, no leaf payloads) — fsck stays cheap on
    non-checkpoint archives."""
    from repro.checkpoint import manifest as mf
    with fopen_read(None, path) as r:
        idx = r.index()
        sec = idx.find(mf.MANIFEST_USER_STRING)
        if sec >= 0:
            r.seek_section(sec)
            return mf.parse(r.read_block_data())
        sec = idx.find(mf.SHARDS_MANIFEST_USER_STRING)
        if sec >= 0:
            r.seek_section(sec)
            return mf.parse_sharded(r.read_block_data())
        return None


def _check_delta_chain(path: str, deep: bool,
                       findings: List[Finding]) -> None:
    """Chain-level findings for delta checkpoints.

    A structurally valid delta archive is still unrestorable if any base
    it references was deleted or rewritten in place — those are errors
    anchored at the manifest, not at a byte of this file.  ``deep``
    additionally digest-verifies every chunk across the chain.
    """
    from repro.checkpoint import manifest as mf
    try:
        doc = _read_checkpoint_doc(path)
    except (ScdaError, OSError, ValueError):
        return  # not a readable checkpoint: nothing chain-level to check
    if not doc or not doc.get("delta"):
        return
    base_dir = os.path.dirname(os.path.abspath(path))
    ok = True
    for k, b in enumerate(doc["delta"].get("bases", []), start=1):
        name = b.get("file", "")
        bpath = os.path.join(base_dir, name)
        try:
            bdoc = _read_checkpoint_doc(bpath)
        except (ScdaError, OSError, ValueError) as e:
            findings.append(Finding(
                "error", 0, None, f"delta base #{k} {name!r}: {e}"))
            ok = False
            continue
        if bdoc is None:
            findings.append(Finding(
                "error", 0, None,
                f"delta base #{k} {name!r}: not a checkpoint archive"))
            ok = False
            continue
        got = mf.content_id(bdoc)
        if got != b.get("id"):
            findings.append(Finding(
                "error", 0, None,
                f"delta base #{k} {name!r}: content id {got} != recorded "
                f"{b.get('id')} — base rewritten since this delta was "
                f"saved"))
            ok = False
    if deep and ok:
        from repro.checkpoint.delta import verify_chain
        try:
            for problem in verify_chain(path):
                findings.append(Finding("error", 0, None,
                                        f"chain: {problem}"))
        except (ScdaError, OSError, ValueError) as e:
            findings.append(Finding("error", 0, None, f"chain: {e}"))


def _check_sharded_set(path: str, deep: bool, check_sidecar: bool,
                       findings: List[Finding]) -> None:
    """Set-level findings for sharded checkpoint manifests.

    The manifest file itself is tiny and already walked; what can rot is
    the set it names — a shard deleted, truncated, or rewritten in place.
    ``verify_set`` reports those by shard name; every shard still on disk
    is then fsck'd recursively (same depth), so one ``scdatool fsck
    MANIFEST`` validates the whole checkpoint."""
    from repro.checkpoint import manifest as mf, sharding
    try:
        with fopen_read(None, path) as r:
            if r.index().find(mf.SHARDS_MANIFEST_USER_STRING) < 0:
                return
    except (ScdaError, OSError):
        return
    for p in sharding.verify_set(path):
        findings.append(Finding("error", 0, None, f"set: {p}"))
    try:
        doc = sharding.read_sharded_manifest(path)
    except (ScdaError, OSError, ValueError):
        return  # verify_set already reported the manifest unreadable
    base = os.path.dirname(os.path.abspath(path))
    for k, srec in enumerate(doc.get("shards", [])):
        name = srec.get("file", "")
        spath = os.path.join(base, name)
        if not os.path.exists(spath):
            continue  # missing: already an error, named by verify_set
        for f in fsck_file(spath, deep=deep, check_sidecar=check_sidecar):
            findings.append(Finding(f.severity, f.offset, f.section,
                                    f"shard #{k} {name!r}: {f.message}"))


def fsck_file(path: str, deep: bool = True,
              check_sidecar: bool = True) -> List[Finding]:
    """Validate ``path``; returns findings (empty = clean)."""
    findings: List[Finding] = []
    try:
        r = fopen_read(None, path)
    except ScdaError as e:
        findings.append(Finding("error", 0, None, str(e)))
        return findings
    with r:
        sec = 0
        while not r.at_eof:
            start = r.cursor
            try:
                r.read_section_header(decode=True)
                p = r._pending
                data_region = (p.v_data_start
                               if p.kind in ("zA", "zV") else p.data_start)
                payload = _payload_bytes(r, p)
                _check_section(r, deep)
                if r.cursor - start != _expected_extent(p, payload):
                    findings.append(Finding(
                        "error", start, sec,
                        f"section extent {r.cursor - start} != spec "
                        f"arithmetic {_expected_extent(p, payload)}"))
                    return findings
                warn = _pad_warning(r._backend, p.kind, data_region,
                                    payload, r.cursor)
                if warn:
                    findings.append(Finding("warning", data_region + payload,
                                            sec, warn))
            except ScdaError as e:
                # Anchor the finding at the exact failing byte when the
                # reader pinned one (malformed entry, EOF position, bad
                # header) — "trailing garbage exists" becomes "validation
                # failed at byte X, section started at Y".
                at = e.offset if e.offset is not None else start
                msg = str(e)
                if e.offset is not None and e.offset != start:
                    msg += (f" (validation failed at byte {e.offset}; "
                            f"section started at {start})")
                findings.append(Finding("error", at, sec, msg))
                return findings  # a stream format cannot resync
            sec += 1
    if check_sidecar and os.path.exists(path + SIDECAR_SUFFIX):
        try:
            ScdaIndex.load_sidecar(path).verify(deep=True)
        except ScdaError as e:
            findings.append(Finding("error", 0, None,
                                    f"sidecar {path + SIDECAR_SUFFIX}: {e}"))
    _check_delta_chain(path, deep, findings)
    _check_sharded_set(path, deep, check_sidecar, findings)
    return findings


# --------------------------------------------------------------------------
# Repair (``scdatool repair``)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RepairResult:
    """Outcome of :func:`repair_file` on one archive.

    ``action`` is one of ``"clean"`` (nothing to do), ``"repaired"``,
    ``"would-repair"`` (dry run found damage), or ``"unrecoverable"``
    (no valid prefix — e.g. a corrupt file header).
    """
    path: str
    action: str
    valid_bytes: int = 0         # prefix kept (the truncation point)
    sections: int = 0            # whole sections surviving the repair
    dropped_bytes: int = 0       # damaged tail removed (or would be)
    quarantine: Optional[str] = None  # where the damaged bytes went
    sidecar: Optional[str] = None     # rebuilt sidecar, if one existed
    detail: str = ""

    def __str__(self) -> str:
        s = f"{self.path}: {self.action}"
        if self.action == "clean":
            return s + f" ({self.sections} sections, {self.valid_bytes} bytes)"
        if self.action == "unrecoverable":
            return s + f": {self.detail}"
        s += (f": kept {self.sections} sections / {self.valid_bytes} bytes, "
              f"dropped {self.dropped_bytes} damaged bytes at offset "
              f"{self.valid_bytes}")
        if self.quarantine:
            s += f" -> {self.quarantine}"
        if self.sidecar:
            s += f" (sidecar rebuilt: {self.sidecar})"
        return s


def repair_file(path: str, quarantine: bool = True, dry_run: bool = False,
                sidecar: bool = True) -> RepairResult:
    """Salvage the valid section prefix of a damaged archive.

    Reuses the mode-'a' tail validator with ``recover=True``: everything
    before the first structural failure is a complete, fsck-clean
    archive — the damaged tail is cut at that exact byte.  With
    ``quarantine`` the removed bytes are preserved verbatim in
    ``<path>.quarantine-<offset>`` (forensics, nothing is destroyed);
    with ``sidecar`` an existing ``.scdax`` is rebuilt to describe the
    repaired file (checksums preserved if the old one recorded them).
    ``dry_run`` reports what would happen without touching the file.
    """
    try:
        size = os.stat(path).st_size
    except OSError as e:
        return RepairResult(path, "unrecoverable", detail=str(e))
    try:
        tail = validate_tail(path, recover=True)
    except ScdaError as e:
        return RepairResult(path, "unrecoverable", detail=str(e),
                            dropped_bytes=size)
    if tail.truncate_to is None:
        return RepairResult(path, "clean", valid_bytes=tail.end,
                            sections=tail.sections)
    cut = tail.truncate_to
    res = RepairResult(path, "would-repair" if dry_run else "repaired",
                       valid_bytes=cut, sections=tail.sections,
                       dropped_bytes=size - cut)
    if dry_run:
        return res
    b = FileBackend(path, "a", create=False)
    try:
        if quarantine and size > cut:
            qpath = f"{path}.quarantine-{cut}"
            damaged = b.pread(cut, size - cut)
            with open(qpath, "wb") as qf:
                qf.write(damaged)
                qf.flush()
                os.fsync(qf.fileno())
            res.quarantine = qpath
        b.truncate(cut)
        b.fsync()
    finally:
        b.close()
    # The truncation (and the quarantine file) must survive a power cut
    # just like a commit would.
    fsync_dir(os.path.dirname(os.path.abspath(path)))
    if sidecar and os.path.exists(path + SIDECAR_SUFFIX):
        try:
            idx = ScdaIndex.refresh_sidecar(path)
            if idx is not None:
                res.sidecar = path + SIDECAR_SUFFIX
        except (ScdaError, OSError) as e:
            res.detail = f"sidecar rebuild failed: {e}"
    return res


def is_sharded_manifest(path: str) -> bool:
    """True when ``path``'s valid prefix contains a sharded-set manifest."""
    from repro.checkpoint import manifest as mf
    try:
        with fopen_read(None, path) as r:
            try:
                idx = r.index()
            except ScdaError as e:
                if e.group != 1:
                    raise
                idx = ScdaIndex.build_prefix(r)
            return idx.find(mf.SHARDS_MANIFEST_USER_STRING) >= 0
    except (ScdaError, OSError):
        return False


def repair_set(path: str, quarantine: bool = True, dry_run: bool = False,
               sidecar: bool = True) -> List[RepairResult]:
    """Repair a sharded checkpoint set, reporting per-shard damage.

    The manifest file is repaired first (its own tail can be torn), then
    every shard it names — a damaged shard is salvaged independently
    instead of the whole set being refused.  Missing shards are reported
    as unrecoverable entries; the manifest itself is never rewritten to
    drop them (that would change what was committed).
    """
    from repro.checkpoint import sharding
    results = [repair_file(path, quarantine=quarantine, dry_run=dry_run,
                           sidecar=sidecar)]
    if results[0].action == "unrecoverable":
        return results
    try:
        doc = sharding.read_sharded_manifest(path)
    except (ScdaError, OSError, ValueError) as e:
        results[0].detail = f"manifest unreadable after repair: {e}"
        return results
    base = os.path.dirname(os.path.abspath(path))
    for k, srec in enumerate(doc.get("shards", [])):
        name = srec.get("file", "")
        spath = os.path.join(base, name)
        if not os.path.exists(spath):
            results.append(RepairResult(
                spath, "unrecoverable",
                detail=f"shard #{k} named by the manifest is missing"))
            continue
        r = repair_file(spath, quarantine=quarantine, dry_run=dry_run,
                        sidecar=sidecar)
        r.detail = (f"shard #{k}" + (f": {r.detail}" if r.detail else ""))
        results.append(r)
    return results
