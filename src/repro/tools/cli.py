"""``scdatool`` — archive CLI for scda files.

Subcommands::

    scdatool ls FILE                 # section table (via the seekable index)
    scdatool ls --json FILE          # same, machine-readable (checkpoint +
                                     # delta-chain metadata included)
    scdatool cat FILE SECTION        # decoded payload of one section
    scdatool fsck FILE...            # structural validation, non-zero on
                                     # corruption; delta checkpoints also get
                                     # their base links checked
    scdatool index FILE...           # build/refresh (or --check) .scdax sidecars
    scdatool index --checksums F...  # sidecar + per-section payload CRC32s
    scdatool verify FILE...          # re-check payloads against the checksums
    scdatool verify --chain FILE...  # digest-verify a delta checkpoint across
                                     # its whole base chain (CRC32 + SHA-256)
    scdatool copy SRC DST            # rewrite; --recompress / --decompress
    scdatool diff A B                # leaf-wise compare via the indexes
    scdatool diff --logical A B      # chain-aware checkpoint compare (a delta
                                     # chain equals the full state it encodes)
    scdatool squash SRC DST          # materialize a delta chain into one
                                     # self-contained archive
    scdatool append DST SRC...       # grow DST in place (mode 'a') with
                                     # SRC's sections; sidecar refreshed
    scdatool tail FILE               # print journal records; -f follows
                                     # new sections as they land
    scdatool stats FILE...           # per-section stored/logical bytes and
                                     # compression ratios (via the index)
    scdatool stats --trace T.json    # summarize a Chrome trace captured
                                     # with REPRO_SCDA_TRACE: per-stage
                                     # time, syscall counts, bytes, MB/s

``SECTION`` is a section number (as printed by ``ls``) or a user string.
Installed as a console script via ``pyproject.toml``; equivalently
``python -m repro.tools.cli``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional

from repro.core import (ScdaError, ScdaErrorCode, ScdaIndex, fopen_append,
                        fopen_read, fopen_write)
from repro.core import trace as _trace
from repro.core.index import SIDECAR_SUFFIX
from repro.tools.fsck import (fsck_file, is_sharded_manifest, repair_file,
                              repair_set, sibling_shards_exist)


def _err(msg: str) -> None:
    print(f"scdatool: {msg}", file=sys.stderr)


def _printable(user: bytes) -> str:
    text = user.decode("latin-1")
    return text if text.isprintable() else repr(user)


# -- ls ----------------------------------------------------------------------

def _checkpoint_summary(path: str) -> Optional[dict]:
    """Best-effort checkpoint + delta-chain metadata of a repro
    checkpoint archive; None when ``path`` is not one (or unreadable).
    Reads only the manifest block — never jax, never the leaf payloads.
    Sharded-set manifests summarize via their shard table (existence
    checks only, no shard opens beyond a stat).
    """
    from repro.checkpoint import manifest as mf
    try:
        with fopen_read(None, path) as r:
            idx = r.index()
            sec = idx.find(mf.MANIFEST_USER_STRING)
            if sec < 0:
                if idx.find(mf.SHARDS_MANIFEST_USER_STRING) >= 0:
                    from repro.checkpoint import sharding
                    return sharding.summarize(path)
                return None
            r.seek_section(sec)
            doc = mf.parse(r.read_block_data())
    except (ScdaError, OSError, ValueError):
        return None
    out = {"format": doc.get("format"), "version": doc.get("version"),
           "step": doc.get("step"), "leaves": len(doc.get("leaves", []))}
    delta = doc.get("delta")
    if delta:
        stored = sum(len(l.get("present", []))
                     for l in doc.get("leaves", []))
        total = sum(len((l.get("chunks") or {}).get("hash", ()))
                    for l in doc.get("leaves", []))
        out["delta"] = {"depth": delta.get("depth"),
                        "bases": [dict(b) for b in delta.get("bases", [])],
                        "chunks_stored": stored, "chunks_total": total}
    return out


def _expand_set(path: str) -> List[str]:
    """``[path]`` — or, when ``path`` is a sharded-set manifest, the
    manifest followed by its shard files, so per-file subcommands
    (``verify``, ``index``) accept a manifest path and cover the whole
    set.  Unreadable paths pass through unchanged; the subcommand's own
    error reporting names them."""
    from repro.checkpoint import manifest as mf, sharding
    try:
        with fopen_read(None, path) as r:
            if r.index().find(mf.SHARDS_MANIFEST_USER_STRING) < 0:
                return [path]
        doc = sharding.read_sharded_manifest(path)
    except (ScdaError, OSError, ValueError):
        return [path]
    base = os.path.dirname(path)
    return [path] + [os.path.join(base, s.get("file", ""))
                     for s in doc.get("shards", [])]


def cmd_ls(args) -> int:
    idx = ScdaIndex.build(args.file)
    ckpt = _checkpoint_summary(args.file)
    if args.json:
        doc = {
            "file": args.file,
            "bytes": idx.file_size,
            "scda_version": idx.scda_version,
            "vendor": _printable(idx.vendor),
            "user": _printable(idx.user_string),
            "sections": [
                {"sec": i, "kind": e.kind, "type": e.type, "N": e.N,
                 "E": e.E, "payload": e.payload_bytes, "offset": e.start,
                 "user": _printable(e.user_string)}
                for i, e in enumerate(idx)],
        }
        if ckpt is not None:
            doc["checkpoint"] = ckpt
        print(json.dumps(doc, indent=1, sort_keys=True))
        return 0
    print(f"# {args.file}: {len(idx)} sections, {idx.file_size} bytes, "
          f"scda version {idx.scda_version:#x}, "
          f"vendor {_printable(idx.vendor)!r}, "
          f"user {_printable(idx.user_string)!r}")
    if ckpt is not None and ckpt.get("delta"):
        d = ckpt["delta"]
        bases = ", ".join(b["file"] for b in d["bases"])
        print(f"# delta checkpoint: depth {d['depth']}, "
              f"{d['chunks_stored']}/{d['chunks_total']} chunks stored, "
              f"bases: {bases}")
    if ckpt is not None and ckpt.get("format") == "repro-scda-sharded":
        files = ", ".join(
            s["file"] + ("" if s.get("present") else " (MISSING)")
            for s in ckpt.get("shards", []))
        print(f"# sharded checkpoint: step {ckpt.get('step')}, "
              f"{ckpt.get('leaves')} leaves across "
              f"{len(ckpt.get('shards', []))} shards: {files}")
    print(f"{'sec':>4} {'kind':>4} {'N':>10} {'E':>10} {'payload':>12} "
          f"{'offset':>12}  user string")
    for i, e in enumerate(idx):
        print(f"{i:>4} {e.kind:>4} {e.N:>10} {e.E:>10} "
              f"{e.payload_bytes:>12} {e.start:>12}  "
              f"{_printable(e.user_string)}")
    return 0


# -- cat ---------------------------------------------------------------------

def _resolve_section(idx: ScdaIndex, token: str) -> int:
    if token.isdigit():
        i = int(token)
        if not 0 <= i < len(idx):
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"section {i} outside [0, {len(idx)})")
        return i
    i = idx.find(token.encode("latin-1"))
    if i < 0:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"no section with user string {token!r}")
    return i


def cmd_cat(args) -> int:
    out = sys.stdout.buffer
    with fopen_read(None, args.file) as r:
        idx = r.index()
        i = _resolve_section(idx, args.section)
        e = idx.entries[i]
        if args.element is not None and e.type != "V":
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"--element requires a varray section; "
                            f"section {i} has type {e.type}")
        if args.extent:
            out.write(r._backend.pread(e.start, e.end - e.start))
            return 0
        hdr = r.seek_section(i)
        if hdr.type == "I":
            out.write(r.read_inline_data())
        elif hdr.type == "B":
            out.write(r.read_block_data())
        elif hdr.type == "A":
            for chunk in r.read_array_data([hdr.N]):
                out.write(chunk)
        else:  # V
            if args.element is not None:
                out.write(r.read_varray_elements([args.element])[0])
            else:
                sizes = r.read_varray_sizes([hdr.N])
                for chunk in r.read_varray_data([hdr.N], sizes):
                    out.write(chunk)
    return 0


# -- fsck --------------------------------------------------------------------

def _timed(args, body, label: str) -> int:
    """``--timing``: run ``body`` under a private trace collector and
    print the per-phase wall-time / bytes-scanned breakdown (Metrics
    counters from the syscall choke point) after its normal output."""
    tc = _trace.TraceCollector()
    t0 = tc.now()
    with _trace.scoped(tc):
        status = body(args)
    wall_ms = (tc.now() - t0) / 1e6
    snap = tc.metrics.snapshot()
    ctr = snap["counters"]
    scanned = ctr.get("io.pread.bytes", 0) + ctr.get("io.preadv.bytes", 0)
    calls = sum(v for k, v in ctr.items()
                if k.startswith("io.") and k.endswith(".calls"))
    print(f"# {label} timing: {wall_ms:.1f} ms wall, {scanned} bytes "
          f"scanned, {calls} syscalls")
    phases = sorted(((h["total_us"], name[:-3], h["count"])
                     for name, h in snap["histograms"].items()
                     if name.endswith(".us")), reverse=True)
    for total_us, name, count in phases:
        print(f"#   {name:<24} {count:>7} calls {total_us / 1e3:>9.1f} ms")
    return status


def cmd_fsck(args) -> int:
    if args.timing:
        return _timed(args, _fsck_body, "fsck")
    return _fsck_body(args)


def _fsck_body(args) -> int:
    status = 0
    for path in args.files:
        findings = fsck_file(path, deep=not args.fast,
                             check_sidecar=not args.no_sidecar)
        errors = sum(f.severity == "error" for f in findings)
        warnings = len(findings) - errors
        for f in findings:
            if not args.quiet or f.severity == "error":
                print(f"{path}: {f}")
        if errors or (args.strict and warnings):
            status = 1
            print(f"{path}: CORRUPT ({errors} errors, {warnings} warnings)")
        else:
            print(f"{path}: clean ({warnings} warnings)")
    return status


# -- repair ------------------------------------------------------------------

def cmd_repair(args) -> int:
    """Salvage the valid prefix of damaged archives (fsck's fixer twin).

    Exit 0 when every file ends up clean or repaired; 1 when anything is
    unrecoverable — or, under ``--dry-run``, when a repair *would* be
    needed (so scripts can probe without mutating).
    """
    status = 0
    for path in args.files:
        # A mangled manifest may not self-identify as a set — shard
        # files named for its stem are evidence enough to route it
        # through set repair (which can rebuild the manifest itself).
        if is_sharded_manifest(path) or sibling_shards_exist(path):
            results = repair_set(path, quarantine=not args.no_quarantine,
                                 dry_run=args.dry_run,
                                 sidecar=not args.no_sidecar,
                                 rebuild=args.rebuild)
        else:
            results = [repair_file(path, quarantine=not args.no_quarantine,
                                   dry_run=args.dry_run,
                                   sidecar=not args.no_sidecar)]
        for r in results:
            print(r)
            if r.action in ("unrecoverable", "would-repair",
                            "would-rebuild"):
                status = 1
    return status


# -- index -------------------------------------------------------------------

def cmd_index(args) -> int:
    status = 0
    for path in [p for f in args.files for p in _expand_set(f)]:
        sidecar = path + SIDECAR_SUFFIX
        if args.check:
            try:
                idx = ScdaIndex.load_sidecar(path)
                idx.verify(deep=True)
                if args.checksums and not idx.has_checksums():
                    _err(f"{sidecar}: fresh but records no payload "
                         f"checksums (write them with: scdatool index "
                         f"--checksums)")
                    status = 1
                else:
                    print(f"{sidecar}: fresh")
            except (ScdaError, OSError) as e:
                _err(f"{sidecar}: {e}")
                status = 1
            continue
        with fopen_read(None, path) as r:
            idx = r.index()
            if args.checksums:
                idx = idx.with_checksums(r)
        idx.write_sidecar()
        print(f"{sidecar}: {len(idx)} sections indexed"
              + (" (with payload checksums)" if args.checksums else ""))
    return status


# -- verify ------------------------------------------------------------------

def cmd_verify(args) -> int:
    """Validate archives against their sidecar checksum manifests.

    The reference-free integrity check (``diff`` needs a second copy;
    ``verify`` does not): loads the ``.scdax`` sidecar written by
    ``index --checksums``, confirms it still describes the file, then
    re-reads and re-decodes every payload and compares CRC32s.  Exit 1
    on any mismatch, unreadable section, missing checksum, or missing
    sidecar.
    """
    if args.timing:
        return _timed(args, _verify_body, "verify")
    return _verify_body(args)


def _verify_body(args) -> int:
    status = 0
    if args.chain:
        from repro.checkpoint.delta import verify_chain
        for path in args.files:
            try:
                problems = verify_chain(path)
            except (ScdaError, OSError, ValueError) as e:
                _err(f"{path}: {e}")
                status = 1
                continue
            for p in problems:
                print(f"{path}: {p}")
            if problems:
                status = 1
                print(f"{path}: FAILED ({len(problems)} problem"
                      f"{'s' if len(problems) != 1 else ''})")
            else:
                print(f"{path}: verified (chunk digests match across "
                      f"the chain)")
        return status
    for f in args.files:
        # Erasure-code health of sharded sets, named per shard — the
        # one-line answer to "is this checkpoint still restorable".
        if is_sharded_manifest(f):
            from repro.checkpoint import redundancy as red
            try:
                health, lost_data, lost_parity = red.set_health(f)
            except (ScdaError, OSError, ValueError):
                continue  # per-file loop below reports the breakage
            if health != "clean":
                lost = ", ".join(lost_data + lost_parity)
                print(f"{f}: set health: {health} — lost {lost}")
                if health == "unrecoverable":
                    status = 1
    for path in [p for f in args.files for p in _expand_set(f)]:
        sidecar = path + SIDECAR_SUFFIX
        try:
            idx = ScdaIndex.load_sidecar(path)
        except (ScdaError, OSError) as e:
            _err(f"{path}: cannot load checksum manifest {sidecar}: {e} "
                 f"(write one with: scdatool index --checksums)")
            status = 1
            continue
        try:
            problems = idx.verify_checksums()
        except (ScdaError, OSError) as e:
            _err(f"{path}: {e}")
            status = 1
            continue
        for p in problems:
            print(f"{path}: {p}")
        if problems:
            status = 1
            print(f"{path}: FAILED ({len(problems)} problem"
                  f"{'s' if len(problems) != 1 else ''})")
        else:
            print(f"{path}: verified ({len(idx)} sections, "
                  f"payload checksums match)")
    return status


# -- copy / append -----------------------------------------------------------

def _pump_sections(r, w, idx: ScdaIndex, recompress: bool,
                   decompress: bool) -> int:
    """Re-emit every section of ``r`` (indexed by ``idx``) through writer
    ``w`` — the shared engine of ``copy`` (mode 'w') and ``append``
    (mode 'a'); both produce sections byte-equivalent to writing the
    logical content directly."""
    for i, e in enumerate(idx):
        hdr = r.seek_section(i)
        if recompress:
            enc = True
        elif decompress:
            enc = False
        else:
            enc = e.decoded   # preserve each section's encoding
        if hdr.type == "I":
            w.write_inline(hdr.user_string, r.read_inline_data())
        elif hdr.type == "B":
            w.write_block(hdr.user_string, r.read_block_data(),
                          encode=enc)
        elif hdr.type == "A":
            data = r.read_array_data([hdr.N])
            w.write_array(hdr.user_string, data, [hdr.N], hdr.E,
                          indirect=True, encode=enc)
        else:  # V
            sizes = r.read_varray_sizes([hdr.N])
            data = r.read_varray_data([hdr.N], sizes)
            w.write_varray(hdr.user_string, data, [hdr.N], sizes,
                           encode=enc)
    return len(idx)


def cmd_copy(args) -> int:
    with fopen_read(None, args.src) as r:
        idx = r.index()
        with fopen_write(None, args.dst, user_string=r.user_string,
                         vendor=r.vendor) as w:
            _pump_sections(r, w, idx, args.recompress, args.decompress)
    if args.index:
        ScdaIndex.build(args.dst).write_sidecar()
    print(f"copied {len(idx)} sections: {args.src} -> {args.dst}")
    return 0


def cmd_append(args) -> int:
    """Grow DST in place: every section of each SRC is re-emitted through
    a mode-'a' writer, tail-validated first, so the result is identical
    to having written DST's and SRC's sections in one serial session.
    An existing ``.scdax`` sidecar is refreshed incrementally and
    atomically (suffix-only scan; payload CRCs are computed for the new
    sections iff the old sidecar recorded them, so ``scdatool verify``
    keeps passing)."""
    total = 0
    with fopen_append(None, args.dst, recover=args.recover) as w:
        base = w.base_sections
        for src in args.srcs:
            with fopen_read(None, src) as r:
                total += _pump_sections(r, w, r.index(),
                                        args.recompress, args.decompress)
    if args.index:
        ScdaIndex.build(args.dst).write_sidecar()
        refreshed = True
    else:
        refreshed = ScdaIndex.refresh_sidecar(args.dst) is not None
    print(f"appended {total} sections onto {args.dst} "
          f"({base} -> {base + total}"
          f"{', sidecar refreshed' if refreshed else ''})")
    return 0


# -- tail --------------------------------------------------------------------

def cmd_tail(args) -> int:
    """Print journal records (``repro.journal``) as JSON lines.

    Default: dump every record currently in the file and exit (the CI
    smoke mode).  ``--follow`` keeps polling: the index is extended
    incrementally (suffix-only scans) and records from newly landed
    sections stream out as the producer flushes them — ``tail -f`` for
    an archive that is being journaled."""
    from repro.journal import iter_records
    idx = ScdaIndex.cached(args.file, write=False)
    shown = 0
    for _, rec in iter_records(args.file, index=idx):
        print(json.dumps(rec, sort_keys=True))
        shown += 1
    if not args.follow:
        if not shown:
            _err(f"{args.file}: no journal records")
        return 0
    try:
        seen = len(idx.entries)
        while True:
            sys.stdout.flush()
            time.sleep(args.interval)
            if idx.staleness() == "fresh":
                continue
            try:
                idx = idx.extend()  # suffix scan; full rebuild on rewrite
                # A rebuild that SHRANK the table means the file was
                # rewritten (or a torn tail was truncated): re-stream the
                # new file's records rather than skipping unseen ones.
                if len(idx.entries) < seen:
                    seen = 0
                for _, rec in iter_records(args.file, start_section=seen,
                                           index=idx):
                    print(json.dumps(rec, sort_keys=True))
                seen = len(idx.entries)
            except (ScdaError, OSError):
                # tail -f semantics: a mid-append torn tail, a retention
                # delete, or a rewrite in progress is a reason to wait
                # for the next poll, not to die.
                continue
    except KeyboardInterrupt:
        return 0


# -- stats -------------------------------------------------------------------

def _entry_logical_bytes(r, e) -> Optional[int]:
    """Decoded (logical) payload size of one indexed section.

    Raw kinds carry it in the entry itself; ``zB``/``zA`` record it as
    ``raw_E`` / ``N*E``; ``zV`` needs the decoded element sizes, which
    live in the on-disk ``U`` count-entry table (parsed, not decoded)."""
    if e.kind in ("I", "B", "V"):
        return e.payload_bytes
    if e.kind in ("A", "zA"):
        return e.N * e.E
    if e.kind == "zB":
        return e.raw_E
    if e.kind == "zV":
        return sum(r._parse_entries(e.entries_start, 0, e.N, b"U"))
    return None


def cmd_stats(args) -> int:
    """Size/compression accounting and Chrome-trace summarization.

    With FILEs: a per-section table of stored (on-disk payload) vs
    logical (decoded) bytes and the compression ratio, from the seekable
    index — §3-encoded sections report real ratios, raw ones 1.00.
    Sharded-set manifests expand to the whole set.  With ``--trace``,
    summarizes a Chrome trace captured via ``REPRO_SCDA_TRACE`` (or
    ``benchmarks/run.py --trace``): per-stage time breakdown, syscall
    counts, bytes moved, MB/s.
    """
    if not args.files and not args.trace:
        _err("nothing to do: pass FILEs and/or --trace TRACE.json")
        return 2
    status = 0
    docs = []
    for path in [p for f in args.files for p in _expand_set(f)]:
        try:
            with fopen_read(None, path) as r:
                idx = r.index()
                rows = []
                for i, e in enumerate(idx):
                    logical = _entry_logical_bytes(r, e)
                    stored = e.payload_bytes
                    ratio = (logical / stored
                             if logical is not None and stored else None)
                    rows.append({"sec": i, "kind": e.kind,
                                 "stored": stored, "logical": logical,
                                 "ratio": ratio,
                                 "user": _printable(e.user_string)})
        except (ScdaError, OSError, ValueError) as e:
            _err(f"{path}: {e}")
            status = 1
            continue
        stored_t = sum(row["stored"] for row in rows)
        logical_t = sum(row["logical"] or 0 for row in rows)
        doc = {"file": path, "bytes": idx.file_size, "sections": rows,
               "stored_bytes": stored_t, "logical_bytes": logical_t,
               "ratio": (logical_t / stored_t) if stored_t else None}
        if args.json:
            docs.append(doc)
            continue
        print(f"# {path}: {len(idx)} sections, {idx.file_size} bytes on "
              f"disk, payload {stored_t} stored / {logical_t} logical"
              + (f" (ratio {logical_t / stored_t:.2f})" if stored_t
                 else ""))
        print(f"{'sec':>4} {'kind':>4} {'stored':>12} {'logical':>12} "
              f"{'ratio':>6}  user string")
        for row in rows:
            ratio = (f"{row['ratio']:.2f}" if row["ratio"] is not None
                     else "-")
            logical = row["logical"] if row["logical"] is not None else "-"
            print(f"{row['sec']:>4} {row['kind']:>4} {row['stored']:>12} "
                  f"{logical:>12} {ratio:>6}  {row['user']}")
    trace_doc = None
    if args.trace:
        try:
            summary = _trace.summarize_chrome(
                _trace.load_chrome(args.trace))
        except (OSError, ValueError) as e:
            _err(f"{args.trace}: {e}")
            return 1
        if args.json:
            trace_doc = summary
        else:
            print(f"# {args.trace}:")
            for line in _trace.format_summary(summary):
                print(line)
    if args.json:
        out = {"files": docs}
        if trace_doc is not None:
            out["trace"] = trace_doc
        print(json.dumps(out, indent=1, sort_keys=True))
    return status


# -- diff --------------------------------------------------------------------

_DIFF_CHUNK = 1 << 20  # bounded-memory payload comparison


def _stream_diff(ba, bb, off_a: int, off_b: int, na: int,
                 nb: int) -> Optional[int]:
    """First differing byte offset of two on-disk ranges, or None."""
    n = min(na, nb)
    pos = 0
    while pos < n:
        take = min(_DIFF_CHUNK, n - pos)
        ca = ba.pread(off_a + pos, take)
        cb = bb.pread(off_b + pos, take)
        if ca != cb:
            for i, (x, y) in enumerate(zip(ca, cb)):
                if x != y:
                    return pos + i
            return pos + min(len(ca), len(cb))
        pos += take
    return None if na == nb else n


def _fast_section_diff(ra, rb, ea, eb):
    """Same-kind fast path comparing count-entry values and payload data
    bytes (never headers or padding, whose bytes are line-break-style
    dependent).  Returns ``("equal", None)``, ``("differs", detail)``, or
    ``("decode", None)`` when raw encoded bytes differ but content may
    still match (zlib level / style) and a decoded pass must decide."""
    ba, bb = ra._backend, rb._backend
    kind = ea.kind
    if kind in ("I", "B", "A"):
        at = _stream_diff(ba, bb, ea.data_start, eb.data_start,
                          ea.payload_bytes, eb.payload_bytes)
        return ("equal", None) if at is None else \
            ("differs", f"payload differs (first at byte {at})")
    if kind == "V":
        sa = ra._parse_entries(ea.entries_start, 0, ea.N, b"E")
        sb = rb._parse_entries(eb.entries_start, 0, eb.N, b"E")
        if sa != sb:
            first = next(j for j, (x, y) in enumerate(zip(sa, sb))
                         if x != y)
            return ("differs",
                    f"element sizes differ (first at element {first})")
        at = _stream_diff(ba, bb, ea.data_start, eb.data_start,
                          ea.payload_bytes, eb.payload_bytes)
        return ("equal", None) if at is None else \
            ("differs", f"payload differs (first at byte {at})")
    if kind == "zV":
        ua = ra._parse_entries(ea.entries_start, 0, ea.N, b"U")
        ub = rb._parse_entries(eb.entries_start, 0, eb.N, b"U")
        if ua != ub:
            first = next(j for j, (x, y) in enumerate(zip(ua, ub))
                         if x != y)
            return ("differs",
                    f"element sizes differ (first at element {first})")
    # encoded kinds: identical compressed geometry + bytes prove equality;
    # anything else needs the decoded pass.
    if kind == "zB" and ea.raw_E != eb.raw_E:
        return ("decode", None)
    if kind in ("zA", "zV"):
        ca = ra._parse_entries(ea.v_entries_start, 0, ea.N, b"E")
        cb = rb._parse_entries(eb.v_entries_start, 0, eb.N, b"E")
        if ca != cb:
            return ("decode", None)
    start_a = ea.v_data_start if kind in ("zA", "zV") else ea.data_start
    start_b = eb.v_data_start if kind in ("zA", "zV") else eb.data_start
    at = _stream_diff(ba, bb, start_a, start_b,
                      ea.payload_bytes, eb.payload_bytes)
    return ("equal", None) if at is None else ("decode", None)


def _logical_payload_diff(ra, rb, i) -> Optional[str]:
    """Decoded (logical) payload comparison of section ``i`` of both
    archives — element batches through the pipelined ``read_batch``,
    bounded memory, never a full restore.  Encoded sections compare by
    content, so a recompressed copy is still equal.  Returns a
    human-readable difference, or None if equal."""
    ea = ra.index().entries[i]
    if ea.type == "I":
        ra.seek_section(i)
        rb.seek_section(i)
        if ra.read_inline_data() != rb.read_inline_data():
            return "inline data differs"
        return None
    if ea.type == "B":
        ra.seek_section(i)
        rb.seek_section(i)
        if ra.read_block_data() != rb.read_block_data():
            return "block payload differs"
        return None
    # A/V (raw or encoded): element windows via the batched reader — ONE
    # read_batch per archive (tables parsed once, windows streamed by the
    # pipeline in offset order with bounded in-flight memory), not one
    # call per window, which would re-parse the count-entry tables per
    # step (quadratic in N).
    if ea.type == "A":
        step = max(1, _DIFF_CHUNK // max(1, ea.E))
        windows = [(start, min(step, ea.N - start))
                   for start in range(0, ea.N, step)]
    else:
        # Varray elements are variable-size, so windows are bounded by
        # bytes, not element count — a fixed count per window would make
        # diff's memory proportional to the largest elements.
        sizes = ra._parse_entries(ea.entries_start, 0, ea.N,
                                  b"U" if ea.kind == "zV" else b"E")
        windows = []
        start = acc = 0
        for j, s in enumerate(sizes):
            acc += s
            if acc >= _DIFF_CHUNK:
                windows.append((start, j + 1 - start))
                start, acc = j + 1, 0
        if start < ea.N:
            windows.append((start, ea.N - start))
    reqs = [(i, [w]) for w in windows]
    for (pos, res_a), (_, res_b) in zip(ra.read_batch(reqs),
                                        rb.read_batch(reqs)):
        start, n = windows[pos]
        if ea.type == "A":
            wa, wb = res_a[0], res_b[0]
            if wa != wb:
                E = max(1, ea.E)
                first = next(j for j in range(n)
                             if wa[j * E:(j + 1) * E]
                             != wb[j * E:(j + 1) * E])
                return f"payload differs (first at element {start + first})"
        else:
            for j, (x, y) in enumerate(zip(res_a, res_b)):
                if x != y:
                    return (f"payload differs (first at element "
                            f"{start + j})")
    return None


def cmd_squash(args) -> int:
    """Materialize a delta chain into one self-contained archive —
    byte-identical to a direct full (hash-recording) save of the same
    state, so the output is itself a usable delta base."""
    from repro.checkpoint.delta import squash
    src = _checkpoint_summary(args.src)
    if (src or {}).get("format") == "repro-scda-sharded":
        from repro.checkpoint import sharding
        try:
            depth = sharding.chain_depth(
                sharding.load_set(args.src, verify=False))
        except (ScdaError, OSError, ValueError):
            depth = 0
    else:
        depth = int(((src or {}).get("delta") or {}).get("depth", 0))
    doc = squash(args.src, args.dst)
    if args.index:
        ScdaIndex.build(args.dst).write_sidecar()
    print(f"squashed {args.src} -> {args.dst} "
          f"({len(doc.get('leaves', []))} leaves, chain depth {depth} -> 0)")
    return 0


def cmd_diff(args) -> int:
    """Leaf-wise archive comparison via the seekable indexes.

    Section tables, user strings, and per-leaf payload bytes are compared
    without a full restore: raw extents first (cheap), decoded payloads
    only when the encodings differ (so a recompressed copy still compares
    equal leaf-wise).  Exit 1 on the first difference; ``--all`` keeps
    going and lists every one.

    ``--logical`` compares two *checkpoints* by the state they encode,
    resolving delta chains — a delta checkpoint equals the full (or
    squashed) checkpoint of the same state even though their section
    tables differ completely.
    """
    if args.logical:
        from repro.checkpoint.delta import checkpoint_diff
        diffs_ = checkpoint_diff(args.a, args.b)
        for d in diffs_:
            print(d)
        if diffs_:
            print(f"{args.a} and {args.b} differ logically "
                  f"({len(diffs_)} difference"
                  f"{'s' if len(diffs_) != 1 else ''} listed)")
            return 1
        print(f"{args.a} and {args.b} encode the same checkpoint state")
        return 0
    diffs = 0

    def report(msg: str) -> None:
        nonlocal diffs
        diffs += 1
        print(msg)

    with fopen_read(None, args.a) as ra, fopen_read(None, args.b) as rb:
        ia, ib = ra.index(), rb.index()
        if ra.user_string != rb.user_string:
            report(f"file user string differs: "
                   f"{_printable(ra.user_string)!r} vs "
                   f"{_printable(rb.user_string)!r}")
            if not args.all:
                return 1
        if len(ia) != len(ib):
            report(f"section count differs: {len(ia)} vs {len(ib)}")
            if not args.all:
                return 1
        for i in range(min(len(ia), len(ib))):
            ea, eb = ia.entries[i], ib.entries[i]
            name = _printable(ea.user_string)
            if (ea.type, ea.user_string, ea.N, ea.E) != \
                    (eb.type, eb.user_string, eb.N, eb.E):
                report(f"section {i} ({name!r}): headers differ: "
                       f"{ea.type} {_printable(ea.user_string)!r} "
                       f"N={ea.N} E={ea.E} vs "
                       f"{eb.type} {_printable(eb.user_string)!r} "
                       f"N={eb.N} E={eb.E}")
                if not args.all:
                    return 1
                continue
            if ea.kind == eb.kind:
                # Same physical encoding: compare count-entry values and
                # raw payload bytes without decoding anything.
                verdict, detail = _fast_section_diff(ra, rb, ea, eb)
                if verdict == "equal":
                    continue
                if verdict == "differs":
                    report(f"section {i} ({name!r}): {detail}")
                    if not args.all:
                        return 1
                    continue
                # "decode": raw encoded bytes differ but content may not
                # (zlib level, line-break style) — decide logically.
            msg = _logical_payload_diff(ra, rb, i)
            if msg is not None:
                report(f"section {i} ({name!r}): {msg}")
                if not args.all:
                    return 1
    if diffs:
        print(f"{args.a} and {args.b} differ ({diffs} difference"
              f"{'s' if diffs != 1 else ''} listed)")
        return 1
    print(f"{args.a} and {args.b} match leaf-wise")
    return 0


# -- entry point -------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="scdatool",
        description="inspect, validate, index, and rewrite scda archives")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("ls", help="list the section table")
    p.add_argument("file")
    p.add_argument("--json", action="store_true",
                   help="machine-readable output (includes checkpoint and "
                        "delta-chain metadata when present)")
    p.set_defaults(fn=cmd_ls)

    p = sub.add_parser("cat", help="dump one section's decoded payload")
    p.add_argument("file")
    p.add_argument("section", help="section number or user string")
    p.add_argument("--element", type=int, default=None,
                   help="single varray element index")
    p.add_argument("--extent", action="store_true",
                   help="dump the raw on-disk extent (headers included)")
    p.set_defaults(fn=cmd_cat)

    p = sub.add_parser("fsck", help="validate file structure")
    p.add_argument("files", nargs="+")
    p.add_argument("--fast", action="store_true",
                   help="skip payload decompression checks")
    p.add_argument("--no-sidecar", action="store_true",
                   help="do not verify .scdax sidecars")
    p.add_argument("--strict", action="store_true",
                   help="warnings also fail")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="print errors only")
    p.add_argument("--timing", action="store_true",
                   help="print per-phase wall time and bytes scanned "
                        "after the check")
    p.set_defaults(fn=cmd_fsck)

    p = sub.add_parser("repair",
                       help="salvage the valid prefix of damaged archives "
                            "(quarantines the torn tail, rebuilds sidecars; "
                            "sharded sets report per-shard damage)")
    p.add_argument("files", nargs="+")
    p.add_argument("-n", "--dry-run", action="store_true",
                   help="report what would be repaired without touching "
                        "anything (exit 1 if damage found)")
    p.add_argument("--no-quarantine", action="store_true",
                   help="discard the damaged tail instead of preserving it "
                        "in <file>.quarantine-<offset>")
    p.add_argument("--no-sidecar", action="store_true",
                   help="do not rebuild .scdax sidecars after the repair")
    p.add_argument("--rebuild", action="store_true",
                   help="re-materialize lost or rewritten shards of a "
                        "parity-carrying set from the survivors "
                        "(byte-identical, content-id verified)")
    p.set_defaults(fn=cmd_repair)

    p = sub.add_parser("index", help="write (or --check) .scdax sidecars")
    p.add_argument("files", nargs="+")
    p.add_argument("--check", action="store_true",
                   help="verify existing sidecars instead of writing")
    p.add_argument("--checksums", action="store_true",
                   help="also record per-section payload CRC32s "
                        "(enables 'scdatool verify')")
    p.set_defaults(fn=cmd_index)

    p = sub.add_parser("verify",
                       help="check archives against their sidecar "
                            "checksum manifests (no reference copy)")
    p.add_argument("files", nargs="+")
    p.add_argument("--chain", action="store_true",
                   help="digest-verify checkpoint chunk content across the "
                        "delta chain (CRC32 + SHA-256; follows base "
                        "archives)")
    p.add_argument("--timing", action="store_true",
                   help="print per-phase wall time and bytes scanned "
                        "after the check")
    p.set_defaults(fn=cmd_verify)

    p = sub.add_parser("copy", help="rewrite an archive section by section")
    p.add_argument("src")
    p.add_argument("dst")
    g = p.add_mutually_exclusive_group()
    g.add_argument("--recompress", action="store_true",
                   help="§3-encode every B/A/V payload")
    g.add_argument("--decompress", action="store_true",
                   help="store every payload raw")
    p.add_argument("--index", action="store_true",
                   help="also write the destination's .scdax sidecar")
    p.set_defaults(fn=cmd_copy)

    p = sub.add_parser("diff",
                       help="compare two archives leaf-wise via the index")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--all", action="store_true",
                   help="list every difference instead of stopping at the "
                        "first")
    p.add_argument("--logical", action="store_true",
                   help="compare checkpoints by encoded state, resolving "
                        "delta chains")
    p.set_defaults(fn=cmd_diff)

    p = sub.add_parser("squash",
                       help="materialize a delta checkpoint chain into one "
                            "self-contained archive")
    p.add_argument("src")
    p.add_argument("dst")
    p.add_argument("--index", action="store_true",
                   help="also write the destination's .scdax sidecar")
    p.set_defaults(fn=cmd_squash)

    p = sub.add_parser("append",
                       help="append SRC archives' sections onto DST in "
                            "place (mode 'a'; tail-validated)")
    p.add_argument("dst")
    p.add_argument("srcs", nargs="+", metavar="src")
    g = p.add_mutually_exclusive_group()
    g.add_argument("--recompress", action="store_true",
                   help="§3-encode every appended B/A/V payload")
    g.add_argument("--decompress", action="store_true",
                   help="store every appended payload raw")
    p.add_argument("--recover", action="store_true",
                   help="truncate a torn tail back to the last valid "
                        "section boundary instead of failing")
    p.add_argument("--index", action="store_true",
                   help="(re)write the destination's .scdax sidecar even "
                        "if none exists")
    p.set_defaults(fn=cmd_append)

    p = sub.add_parser("tail",
                       help="print journal records as JSON lines; "
                            "-f follows new sections as they land")
    p.add_argument("file")
    p.add_argument("-f", "--follow", action="store_true",
                   help="poll for appended journal sections forever")
    p.add_argument("--interval", type=float, default=1.0,
                   help="poll interval for --follow (seconds, default 1)")
    p.set_defaults(fn=cmd_tail)

    p = sub.add_parser("stats",
                       help="per-section stored/logical bytes and "
                            "compression ratios; --trace summarizes a "
                            "Chrome trace")
    p.add_argument("files", nargs="*")
    p.add_argument("--trace", metavar="TRACE.json", default=None,
                   help="summarize a Chrome trace captured with "
                        "REPRO_SCDA_TRACE (per-stage time, syscalls, "
                        "bytes, MB/s)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable output")
    p.set_defaults(fn=cmd_stats)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:  # | head etc.
        return 0
    except (ScdaError, OSError, ValueError) as e:
        _err(str(e))
        return 1


if __name__ == "__main__":
    sys.exit(main())
