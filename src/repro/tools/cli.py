"""``scdatool`` — archive CLI for scda files.

Subcommands::

    scdatool ls FILE                 # section table (via the seekable index)
    scdatool cat FILE SECTION        # decoded payload of one section
    scdatool fsck FILE...            # structural validation, non-zero on corruption
    scdatool index FILE...           # build/refresh (or --check) .scdax sidecars
    scdatool copy SRC DST            # rewrite; --recompress / --decompress

``SECTION`` is a section number (as printed by ``ls``) or a user string.
Installed as a console script via ``pyproject.toml``; equivalently
``python -m repro.tools.cli``.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.core import (ScdaError, ScdaErrorCode, ScdaIndex, fopen_read,
                        fopen_write)
from repro.core.index import SIDECAR_SUFFIX
from repro.tools.fsck import fsck_file


def _err(msg: str) -> None:
    print(f"scdatool: {msg}", file=sys.stderr)


def _printable(user: bytes) -> str:
    text = user.decode("latin-1")
    return text if text.isprintable() else repr(user)


# -- ls ----------------------------------------------------------------------

def cmd_ls(args) -> int:
    idx = ScdaIndex.build(args.file)
    print(f"# {args.file}: {len(idx)} sections, {idx.file_size} bytes, "
          f"scda version {idx.scda_version:#x}, "
          f"vendor {_printable(idx.vendor)!r}, "
          f"user {_printable(idx.user_string)!r}")
    print(f"{'sec':>4} {'kind':>4} {'N':>10} {'E':>10} {'payload':>12} "
          f"{'offset':>12}  user string")
    for i, e in enumerate(idx):
        print(f"{i:>4} {e.kind:>4} {e.N:>10} {e.E:>10} "
              f"{e.payload_bytes:>12} {e.start:>12}  "
              f"{_printable(e.user_string)}")
    return 0


# -- cat ---------------------------------------------------------------------

def _resolve_section(idx: ScdaIndex, token: str) -> int:
    if token.isdigit():
        i = int(token)
        if not 0 <= i < len(idx):
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"section {i} outside [0, {len(idx)})")
        return i
    i = idx.find(token.encode("latin-1"))
    if i < 0:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"no section with user string {token!r}")
    return i


def cmd_cat(args) -> int:
    out = sys.stdout.buffer
    with fopen_read(None, args.file) as r:
        idx = r.index()
        i = _resolve_section(idx, args.section)
        e = idx.entries[i]
        if args.element is not None and e.type != "V":
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"--element requires a varray section; "
                            f"section {i} has type {e.type}")
        if args.extent:
            out.write(r._backend.pread(e.start, e.end - e.start))
            return 0
        hdr = r.seek_section(i)
        if hdr.type == "I":
            out.write(r.read_inline_data())
        elif hdr.type == "B":
            out.write(r.read_block_data())
        elif hdr.type == "A":
            for chunk in r.read_array_data([hdr.N]):
                out.write(chunk)
        else:  # V
            if args.element is not None:
                out.write(r.read_varray_elements([args.element])[0])
            else:
                sizes = r.read_varray_sizes([hdr.N])
                for chunk in r.read_varray_data([hdr.N], sizes):
                    out.write(chunk)
    return 0


# -- fsck --------------------------------------------------------------------

def cmd_fsck(args) -> int:
    status = 0
    for path in args.files:
        findings = fsck_file(path, deep=not args.fast,
                             check_sidecar=not args.no_sidecar)
        errors = sum(f.severity == "error" for f in findings)
        warnings = len(findings) - errors
        for f in findings:
            if not args.quiet or f.severity == "error":
                print(f"{path}: {f}")
        if errors or (args.strict and warnings):
            status = 1
            print(f"{path}: CORRUPT ({errors} errors, {warnings} warnings)")
        else:
            print(f"{path}: clean ({warnings} warnings)")
    return status


# -- index -------------------------------------------------------------------

def cmd_index(args) -> int:
    status = 0
    for path in args.files:
        sidecar = path + SIDECAR_SUFFIX
        if args.check:
            try:
                ScdaIndex.load_sidecar(path).verify(deep=True)
                print(f"{sidecar}: fresh")
            except (ScdaError, OSError) as e:
                _err(f"{sidecar}: {e}")
                status = 1
            continue
        idx = ScdaIndex.build(path)
        idx.write_sidecar()
        print(f"{sidecar}: {len(idx)} sections indexed")
    return status


# -- copy --------------------------------------------------------------------

def cmd_copy(args) -> int:
    with fopen_read(None, args.src) as r:
        idx = r.index()
        with fopen_write(None, args.dst, user_string=r.user_string,
                         vendor=r.vendor) as w:
            for i, e in enumerate(idx):
                hdr = r.seek_section(i)
                if args.recompress:
                    enc = True
                elif args.decompress:
                    enc = False
                else:
                    enc = e.decoded   # preserve each section's encoding
                if hdr.type == "I":
                    w.write_inline(hdr.user_string, r.read_inline_data())
                elif hdr.type == "B":
                    w.write_block(hdr.user_string, r.read_block_data(),
                                  encode=enc)
                elif hdr.type == "A":
                    data = r.read_array_data([hdr.N])
                    w.write_array(hdr.user_string, data, [hdr.N], hdr.E,
                                  indirect=True, encode=enc)
                else:  # V
                    sizes = r.read_varray_sizes([hdr.N])
                    data = r.read_varray_data([hdr.N], sizes)
                    w.write_varray(hdr.user_string, data, [hdr.N], sizes,
                                   encode=enc)
    if args.index:
        ScdaIndex.build(args.dst).write_sidecar()
    print(f"copied {len(idx)} sections: {args.src} -> {args.dst}")
    return 0


# -- entry point -------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="scdatool",
        description="inspect, validate, index, and rewrite scda archives")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("ls", help="list the section table")
    p.add_argument("file")
    p.set_defaults(fn=cmd_ls)

    p = sub.add_parser("cat", help="dump one section's decoded payload")
    p.add_argument("file")
    p.add_argument("section", help="section number or user string")
    p.add_argument("--element", type=int, default=None,
                   help="single varray element index")
    p.add_argument("--extent", action="store_true",
                   help="dump the raw on-disk extent (headers included)")
    p.set_defaults(fn=cmd_cat)

    p = sub.add_parser("fsck", help="validate file structure")
    p.add_argument("files", nargs="+")
    p.add_argument("--fast", action="store_true",
                   help="skip payload decompression checks")
    p.add_argument("--no-sidecar", action="store_true",
                   help="do not verify .scdax sidecars")
    p.add_argument("--strict", action="store_true",
                   help="warnings also fail")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="print errors only")
    p.set_defaults(fn=cmd_fsck)

    p = sub.add_parser("index", help="write (or --check) .scdax sidecars")
    p.add_argument("files", nargs="+")
    p.add_argument("--check", action="store_true",
                   help="verify existing sidecars instead of writing")
    p.set_defaults(fn=cmd_index)

    p = sub.add_parser("copy", help="rewrite an archive section by section")
    p.add_argument("src")
    p.add_argument("dst")
    g = p.add_mutually_exclusive_group()
    g.add_argument("--recompress", action="store_true",
                   help="§3-encode every B/A/V payload")
    g.add_argument("--decompress", action="store_true",
                   help="store every payload raw")
    p.add_argument("--index", action="store_true",
                   help="also write the destination's .scdax sidecar")
    p.set_defaults(fn=cmd_copy)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except BrokenPipeError:  # | head etc.
        return 0
    except (ScdaError, OSError) as e:
        _err(str(e))
        return 1


if __name__ == "__main__":
    sys.exit(main())
