"""Archive tooling for scda files.

``scdatool`` (console entry point; also ``python -m repro.tools.cli``) is
the archivist's Swiss-army knife over the format: ``ls`` (section table),
``cat`` (payload extraction), ``fsck`` (structural validation), ``index``
(``.scdax`` sidecar management), and ``copy`` (rewrite, optionally
re/de-compressing every payload).
"""
from repro.tools.fsck import Finding, fsck_file

__all__ = ["Finding", "fsck_file"]
