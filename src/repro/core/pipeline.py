"""Overlapped restore engine — the asynchronous read pipeline (read side
of the PR-1 scatter-gather fast path).

The serial restore walk is pread → inflate → copy, one chunk at a time:
the disk idles while zlib runs and zlib idles while the disk seeks.  This
module overlaps the three stages:

* upcoming extents are handed to :meth:`FileBackend.prefetch`, a small
  background executor that double-buffers them into a bounded cache
  (``REPRO_SCDA_PREFETCH`` bytes; ``0`` disables and every caller falls
  back to the exact serial order);
* the foreground thread consumes extents via :meth:`FileBackend.
  read_scatter` (coalesced ``preadv``, served from the prefetch cache
  when warm) and immediately submits compressed chunks to the shared
  ``scda-codec`` pool (:func:`repro.core.codec.submit_decompress_batch`),
  so chunk k inflates while chunk k+1 is in flight from disk;
* fully consumed extents are released back to the kernel
  (:meth:`FileBackend.release` → ``posix_fadvise(DONTNEED)``) so a long
  restore never grows the page cache beyond the prefetch window.

Byte-identity is structural: the pipeline changes WHEN bytes are read and
WHERE they inflate, never WHAT is returned — every result equals the
forward-walk read, and any failure (truncated extent, corrupt chunk)
raises the same :class:`ScdaError` the serial path would, with all
in-flight futures drained first (no leaks, no hangs).

Consumers: :meth:`repro.core.reader.ScdaReader.read_batch` (batched
element reads) and the checkpoint restore scheduler in
:mod:`repro.checkpoint.pytree_io`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Tuple

from repro.core import codec
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.io_backend import BytesLike, FileBackend

#: (absolute file offset, byte length)
Extent = Tuple[int, int]


@dataclasses.dataclass
class ReadItem:
    """One schedulable unit of the pipeline (a leaf, a shard, a request).

    ``extents`` must be offset-sorted within the item, and callers should
    sort items by their first extent so consumption sweeps the file front
    to back (prefetch and ``release`` both assume forward progress).

    ``dst`` optionally supplies one writable buffer per extent — the raw
    leaf fast path, where payload bytes land directly in the shard buffer
    with zero copies.  Without it the engine allocates.  ``inflate`` runs
    each extent through §3 decompression (on the codec pool when the
    pipeline is live, inline when serial); ``expected_sizes`` then
    enforces per-extent uncompressed sizes, CORRUPT_CHECKSUM on mismatch.
    """
    key: Any
    extents: List[Extent]
    inflate: bool = False
    expected_sizes: Optional[Sequence[int]] = None
    dst: Optional[Sequence[BytesLike]] = None

    def start(self) -> int:
        return self.extents[0][0] if self.extents else 0


def run_pipeline(backend: FileBackend, items: Sequence[ReadItem],
                 prefetch_bytes: int,
                 depth: Optional[int] = None) -> Iterator[Tuple[Any, List]]:
    """Execute ``items`` against ``backend``; yield ``(key, results)``.

    ``results`` has one entry per extent: the filled ``dst`` buffer (or an
    allocated ``bytearray``) for raw items, inflated ``bytes`` for
    ``inflate`` items.  Results are yielded as they complete — raw items
    complete immediately, inflate items complete when their pool futures
    resolve, bounded by ``depth`` in-flight items (default: the codec pool
    width, so the queue can keep every pool thread busy).

    ``prefetch_bytes <= 0`` is the serial mode: no background reads, no
    pool, extents consumed strictly in order — the oracle the pipelined
    mode is tested against.
    """
    items = list(items)
    serial = prefetch_bytes <= 0
    width = max(1, codec.pool_width())
    depth = depth if depth is not None else max(2, width)
    flat: List[Extent] = [e for it in items for e in it.extents]
    pf_i = 0
    inflight: List[Tuple[Any, List, int]] = []  # (key, futures, est bytes)
    inflight_bytes = 0
    # In-flight jobs pin both their compressed buffers and their inflated
    # results until drained, so the queue is bounded by BYTES as well as
    # item count — the prefetch window only governs the read cache, and
    # a checkpoint of huge leaves must not hold pool-width whole leaves
    # in memory at once.  One item beyond the head always stays in
    # flight so read/inflate overlap survives the cap.
    byte_cap = max(4 * prefetch_bytes, 64 << 20)
    released = 0

    def _drain_head() -> Tuple[Any, List]:
        nonlocal inflight_bytes
        key, futs, est = inflight.pop(0)
        inflight_bytes -= est
        out: List[bytes] = []
        for f in futs:  # each future resolves to a batch of payloads
            out.extend(f.result())
        return key, out

    try:
        for idx, it in enumerate(items):
            if not serial:
                pf_i += backend.prefetch(flat, window=prefetch_bytes,
                                         start=pf_i)
            if it.dst is not None:
                bufs: List[BytesLike] = list(it.dst)
                backend.read_scatter(
                    zip((off for off, _ in it.extents), bufs))
            else:
                # no caller buffer to fill — serve prefetched extents as
                # zero-copy views instead of allocating and memcpy-ing
                bufs = backend.read_extents(it.extents)
            if not it.inflate:
                yield it.key, bufs
            elif serial:
                out = []
                for j, b in enumerate(bufs):
                    raw = codec.decompress(b)
                    if it.expected_sizes is not None \
                            and len(raw) != it.expected_sizes[j]:
                        raise ScdaError(
                            ScdaErrorCode.CORRUPT_CHECKSUM,
                            f"element inflated to {len(raw)}, "
                            f"U-entry says {it.expected_sizes[j]}")
                    out.append(raw)
                yield it.key, out
            else:
                # A few multi-chunk jobs instead of one future per chunk:
                # enough slices to keep every pool thread busy, few enough
                # that worker wakeups don't GIL-starve this thread.
                step = max(1, -(-len(bufs) // (2 * width)))
                futs = []
                for j in range(0, len(bufs), step):
                    sizes = (it.expected_sizes[j:j + step]
                             if it.expected_sizes is not None else None)
                    futs.append(codec.submit_decompress_batch(
                        bufs[j:j + step], sizes))
                est = (sum(n for _, n in it.extents)
                       + sum(it.expected_sizes or ()))
                inflight.append((it.key, futs, est))
                inflight_bytes += est
                while inflight and (len(inflight) > depth
                                    or (inflight_bytes > byte_cap
                                        and len(inflight) > 1)
                                    or all(f.done()
                                           for f in inflight[0][1])):
                    yield _drain_head()
            if not serial:
                # Everything before the next item's first extent has been
                # consumed (items are offset-sorted) — give it back in
                # half-window batches: big enough to amortize fadvise
                # (DONTNEED is not free, on network file systems in
                # particular), small enough that prefetch budget frees
                # mid-window and read-ahead of the next window overlaps
                # consumption of this one.  Capped at 4 MiB so huge
                # windows still release promptly.
                nxt = (items[idx + 1].start() if idx + 1 < len(items)
                       else max((o + n for o, n in it.extents), default=0))
                if nxt - released >= min(max(1, prefetch_bytes // 2),
                                         1 << 22) \
                        or idx + 1 == len(items):
                    backend.release(nxt)
                    released = nxt
        while inflight:
            yield _drain_head()
    finally:
        # Error or early close: no future may outlive the generator (the
        # backend fd is about to go away under the prefetcher and pool).
        for _, futs, _est in inflight:
            for f in futs:
                f.cancel()
        for _, futs, _est in inflight:
            for f in futs:
                if not f.cancelled():
                    try:
                        f.result()
                    except Exception:  # noqa: BLE001 - shutdown path
                        pass
        inflight.clear()
