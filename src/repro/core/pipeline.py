"""Overlapped restore and save engines — the asynchronous read and write
pipelines over the PR-1 scatter-gather fast path.

The serial restore walk is pread → inflate → copy, one chunk at a time:
the disk idles while zlib runs and zlib idles while the disk seeks.  This
module overlaps the three stages:

* upcoming extents are handed to :meth:`FileBackend.prefetch`, a small
  background executor that double-buffers them into a bounded cache
  (``REPRO_SCDA_PREFETCH`` bytes; ``0`` disables and every caller falls
  back to the exact serial order);
* the foreground thread consumes extents via :meth:`FileBackend.
  read_scatter` (coalesced ``preadv``, served from the prefetch cache
  when warm) and immediately submits compressed chunks to the shared
  ``scda-codec`` pool (:func:`repro.core.codec.submit_decompress_batch`),
  so chunk k inflates while chunk k+1 is in flight from disk;
* fully consumed extents are released back to the kernel
  (:meth:`FileBackend.release` → ``posix_fadvise(DONTNEED)``) so a long
  restore never grows the page cache beyond the prefetch window.

Byte-identity is structural: the pipeline changes WHEN bytes are read and
WHERE they inflate, never WHAT is returned — every result equals the
forward-walk read, and any failure (truncated extent, corrupt chunk)
raises the same :class:`ScdaError` the serial path would, with all
in-flight futures drained first (no leaks, no hangs).

The write half (:func:`run_write_pipeline`) is the mirror.  The serial
save walk is snapshot → deflate → pwrite, one leaf at a time: the codec
pool idles while the disk writes and the disk idles while zlib runs.
The engine overlaps the three stages —

* device→host snapshots run one item ahead on the shared pool (a double
  buffer, :func:`repro.core.codec.submit_task`), so leaf k+1 is on the
  host before leaf k finishes writing;
* compressed payloads deflate on the codec pool
  (:func:`repro.core.codec.submit_compress_batch` — deflate-only jobs,
  the write inverse of the inflate-only GIL discipline; stage-2 base64
  runs on this thread), bounded by in-flight bytes;
* finished fragments queue on :meth:`FileBackend.submit_write_gather`, a
  small writeback executor with a bounded in-flight window
  (``REPRO_SCDA_WRITE_PIPELINE`` bytes; ``0`` = the exact legacy serial
  order), and :meth:`FileBackend.drain_writes` is the completion drain.

Because serial equivalence fixes every section's extent from collective
parameters, item k+1's offsets need only item k's *planned* sizes, never
its completed write — ``plan`` callbacks run strictly in item order
while deflate and writeback float free.  Byte-identity is structural
here too: the pipeline changes WHEN payloads deflate and WHERE the
pwritev happens, never WHAT lands in the file, and any failure raises
the same :class:`ScdaError` as the serial path with every in-flight
future drained (no leaks, no hangs).

Consumers: :meth:`repro.core.reader.ScdaReader.read_batch` (batched
element reads) and the checkpoint restore/save schedulers in
:mod:`repro.checkpoint.pytree_io`.
"""
from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core import codec, spec
from repro.core import faults as _faults
from repro.core import trace as _trace
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.io_backend import BytesLike, FileBackend

#: (absolute file offset, byte length)
Extent = Tuple[int, int]


@dataclasses.dataclass
class ReadItem:
    """One schedulable unit of the pipeline (a leaf, a shard, a request).

    ``extents`` must be offset-sorted within the item, and callers should
    sort items by their first extent so consumption sweeps the file front
    to back (prefetch and ``release`` both assume forward progress).

    ``dst`` optionally supplies one writable buffer per extent — the raw
    leaf fast path, where payload bytes land directly in the shard buffer
    with zero copies.  Without it the engine allocates.  ``inflate`` runs
    each extent through §3 decompression (on the codec pool when the
    pipeline is live, inline when serial); ``expected_sizes`` then
    enforces per-extent uncompressed sizes, CORRUPT_CHECKSUM on mismatch.
    """
    key: Any
    extents: List[Extent]
    inflate: bool = False
    expected_sizes: Optional[Sequence[int]] = None
    dst: Optional[Sequence[BytesLike]] = None

    def start(self) -> int:
        return self.extents[0][0] if self.extents else 0


def run_pipeline(backend: FileBackend, items: Sequence[ReadItem],
                 prefetch_bytes: int,
                 depth: Optional[int] = None) -> Iterator[Tuple[Any, List]]:
    """Execute ``items`` against ``backend``; yield ``(key, results)``.

    ``results`` has one entry per extent: the filled ``dst`` buffer (or an
    allocated ``bytearray``) for raw items, inflated ``bytes`` for
    ``inflate`` items.  Results are yielded as they complete — raw items
    complete immediately, inflate items complete when their pool futures
    resolve, bounded by ``depth`` in-flight items (default: the codec pool
    width, so the queue can keep every pool thread busy).

    ``prefetch_bytes <= 0`` is the serial mode: no background reads, no
    pool, extents consumed strictly in order — the oracle the pipelined
    mode is tested against.
    """
    items = list(items)
    serial = prefetch_bytes <= 0
    width = max(1, codec.pool_width())
    depth = depth if depth is not None else max(2, width)
    flat: List[Extent] = [e for it in items for e in it.extents]
    pf_i = 0
    inflight: List[Tuple[Any, List, int]] = []  # (key, futures, est bytes)
    inflight_bytes = 0
    # In-flight jobs pin both their compressed buffers and their inflated
    # results until drained, so the queue is bounded by BYTES as well as
    # item count — the prefetch window only governs the read cache, and
    # a checkpoint of huge leaves must not hold pool-width whole leaves
    # in memory at once.  One item beyond the head always stays in
    # flight so read/inflate overlap survives the cap.
    byte_cap = max(4 * prefetch_bytes, 64 << 20)
    released = 0
    c = _trace.collector()

    def _drain_head() -> Tuple[Any, List]:
        nonlocal inflight_bytes
        key, futs, est = inflight.pop(0)
        inflight_bytes -= est
        if c is not None:
            c.counter("restore.in_flight_bytes", inflight_bytes)
        out: List[bytes] = []
        for f in futs:  # each future resolves to a batch of payloads
            out.extend(f.result())
        return key, out

    try:
        for idx, it in enumerate(items):
            if not serial:
                pf_i += backend.prefetch(flat, window=prefetch_bytes,
                                         start=pf_i)
            if it.dst is not None:
                bufs: List[BytesLike] = list(it.dst)
                backend.read_scatter(
                    zip((off for off, _ in it.extents), bufs))
            else:
                # no caller buffer to fill — serve prefetched extents as
                # zero-copy views instead of allocating and memcpy-ing
                bufs = backend.read_extents(it.extents)
            if not it.inflate:
                yield it.key, bufs
            elif serial:
                out = []
                for j, b in enumerate(bufs):
                    raw = codec.decompress(b)
                    if it.expected_sizes is not None \
                            and len(raw) != it.expected_sizes[j]:
                        raise ScdaError(
                            ScdaErrorCode.CORRUPT_CHECKSUM,
                            f"element inflated to {len(raw)}, "
                            f"U-entry says {it.expected_sizes[j]}")
                    out.append(raw)
                yield it.key, out
            else:
                # A few multi-chunk jobs instead of one future per chunk:
                # enough slices to keep every pool thread busy, few enough
                # that worker wakeups don't GIL-starve this thread.
                step = max(1, -(-len(bufs) // (2 * width)))
                futs = []
                for j in range(0, len(bufs), step):
                    sizes = (it.expected_sizes[j:j + step]
                             if it.expected_sizes is not None else None)
                    futs.append(codec.submit_decompress_batch(
                        bufs[j:j + step], sizes))
                est = (sum(n for _, n in it.extents)
                       + sum(it.expected_sizes or ()))
                inflight.append((it.key, futs, est))
                inflight_bytes += est
                if c is not None:
                    c.counter("restore.in_flight_bytes", inflight_bytes)
                    c.counter("restore.in_flight_items", len(inflight))
                while inflight and (len(inflight) > depth
                                    or (inflight_bytes > byte_cap
                                        and len(inflight) > 1)
                                    or all(f.done()
                                           for f in inflight[0][1])):
                    yield _drain_head()
            if not serial:
                # Everything before the next item's first extent has been
                # consumed (items are offset-sorted) — give it back in
                # half-window batches: big enough to amortize fadvise
                # (DONTNEED is not free, on network file systems in
                # particular), small enough that prefetch budget frees
                # mid-window and read-ahead of the next window overlaps
                # consumption of this one.  Capped at 4 MiB so huge
                # windows still release promptly.
                nxt = (items[idx + 1].start() if idx + 1 < len(items)
                       else max((o + n for o, n in it.extents), default=0))
                if nxt - released >= min(max(1, prefetch_bytes // 2),
                                         1 << 22) \
                        or idx + 1 == len(items):
                    backend.release(nxt)
                    released = nxt
        while inflight:
            yield _drain_head()
    finally:
        # Error or early close: no future may outlive the generator (the
        # backend fd is about to go away under the prefetcher and pool).
        for _, futs, _est in inflight:
            for f in futs:
                f.cancel()
        for _, futs, _est in inflight:
            for f in futs:
                if not f.cancelled():
                    try:
                        f.result()
                    except BaseException:  # noqa: BLE001 - shutdown path
                        pass  # primary error already propagating
        inflight.clear()


# --------------------------------------------------------------------------
# The write mirror: snapshot → deflate → pwritev
# --------------------------------------------------------------------------

@dataclasses.dataclass
class WriteItem:
    """One schedulable unit of the save pipeline (typically a leaf).

    ``snapshot`` produces the item's payload (device→host for jax
    arrays); the engine runs it one item ahead on the shared pool so the
    copy overlaps the previous item's deflate/write.  With ``deflate``
    the payload must be a sequence of independent chunk buffers; the
    engine compresses each with the §3.1 algorithm (``level``/``style``)
    on the codec pool and hands ``plan`` the finished streams.  Without
    it, ``plan`` receives the snapshot payload verbatim.

    ``plan(payload) -> [(offset, buffer), ...]`` turns the final payload
    into absolute-offset write fragments.  Plans are invoked STRICTLY in
    item order — an item's offsets may depend on every predecessor's
    planned size (the §3.4 compressed case), so schedulers keep their
    cursor in the closure and advance it per call.  The fragments are
    then queued out-of-order-safe on the writeback executor (positioned
    writes at disjoint offsets commute).
    """
    key: Any
    snapshot: Callable[[], Any]
    plan: Callable[[Any], List[Tuple[int, BytesLike]]]
    deflate: bool = False
    level: Optional[int] = None
    style: str = spec.UNIX


def run_write_pipeline(backend: FileBackend, items: Sequence[WriteItem],
                       window: int, depth: Optional[int] = None) -> int:
    """Execute write ``items`` against ``backend``; returns bytes queued.

    ``window <= 0`` is the serial mode: snapshot, deflate, and write run
    strictly in item order on this thread with plain synchronous
    :meth:`FileBackend.write_gather` — the oracle the pipelined mode is
    tested against.  Otherwise snapshots run one item ahead, in-flight
    items (snapshotted payloads and deflate jobs alike) are bounded by
    ``depth`` (default: codec pool width) AND by bytes
    (``max(4 * window, 64 MiB)`` of raw payload — a checkpoint of huge
    leaves must not pin pool-width whole leaves), and writes drain in
    the background within ``window`` in-flight bytes.

    The engine drains the writeback queue before returning, so every
    error — deflate, plan, or write — surfaces HERE as the serial
    path's :class:`ScdaError`, with no future left running.
    """
    items = list(items)
    total = 0
    if window <= 0:
        for it in items:
            payload = it.snapshot()
            if it.deflate:
                payload = [codec.compress(c, it.style,
                                          _level(it)) for c in payload]
            frags = it.plan(payload)
            total += sum(len(b) for _, b in frags)
            backend.write_gather(frags)
        return total

    width = max(1, codec.pool_width())
    depth = depth if depth is not None else max(2, width)
    byte_cap = max(4 * window, 64 << 20)
    snaps = {}    # idx -> snapshot Future
    pend = {}     # idx -> (deflate futures or None, payload, est bytes)
    pend_bytes = 0
    sub = 0       # next item to move snapshot → deflate
    c = _trace.collector()

    def _ensure_snap(j: int) -> None:
        if j < len(items) and j not in snaps and j not in pend:
            fn = items[j].snapshot
            if c is not None:
                def fn(snap=fn, j=j):  # traced worker-side span
                    with c.span("snapshot", "pipeline", item=j):
                        return snap()
            snaps[j] = codec.submit_task(fn)

    try:
        for idx, it in enumerate(items):
            # Submission runs ahead of emission: move items onto the
            # codec pool until the in-flight caps say stop.  The current
            # item (sub == idx) always submits, and one item beyond the
            # head always stays in flight so deflate/write overlap
            # survives the cap.
            while sub < len(items) and (
                    sub <= idx
                    or (sub - idx <= depth and pend_bytes <= byte_cap)):
                jt = items[sub]
                _ensure_snap(sub)
                _ensure_snap(sub + 1)  # the double buffer
                payload = snaps.pop(sub).result()
                if jt.deflate:
                    chunks = list(payload)
                    est = sum(len(c) for c in chunks)
                    # A few multi-chunk jobs, as on the read side: enough
                    # slices to keep the pool busy, few enough that
                    # worker wakeups don't GIL-starve this thread.
                    step = max(1, -(-len(chunks) // (2 * width)))
                    futs = [codec.submit_compress_batch(
                        chunks[j:j + step], _level(jt))
                        for j in range(0, len(chunks), step)]
                    pend[sub] = (futs, None, est)
                else:
                    est = _est_bytes(payload)
                    pend[sub] = (None, payload, est)
                pend_bytes += est
                sub += 1
                if c is not None:
                    c.counter("save.pend_bytes", pend_bytes)
                    c.counter("save.pend_items", len(pend))
            futs, payload, est = pend.pop(idx)
            pend_bytes -= est
            if futs is not None:
                streams: List[bytes] = []
                t0 = c.now() if c is not None else 0
                for f in futs:
                    streams.extend(codec.encode_stage2(s1, it.style)
                                   for s1 in f.result())
                if c is not None:
                    c.end("encode", "codec", t0,
                          {"elements": len(streams),
                           "bytes": sum(map(len, streams))})
                frags = it.plan(streams)
            else:
                frags = it.plan(payload)
            total += sum(len(b) for _, b in frags)
            backend.submit_write_gather(frags, window)
        backend.drain_writes()
        return total
    finally:
        # Error or early exit: no future may outlive this call (the
        # backend fd is about to go away under the writeback pool).
        leaked = list(snaps.values())
        for futs, _, _ in pend.values():
            leaked.extend(futs or ())
        for f in leaked:
            f.cancel()
        for f in leaked:
            if not f.cancelled():
                try:
                    f.result()
                except BaseException:  # noqa: BLE001 - shutdown path
                    pass  # primary error already propagating
        snaps.clear()
        pend.clear()
        try:
            backend.drain_writes()
        except (ScdaError, _faults.SimulatedCrash):
            # the primary error is already propagating; the drain only
            # guarantees quiescence here
            pass


def _level(it: WriteItem) -> int:
    return codec.DEFAULT_LEVEL if it.level is None else it.level


def _est_bytes(payload) -> int:
    """Best-effort size of a raw snapshot payload for the in-flight byte
    cap: a buffer, or a list/tuple of buffers / ``(offset, buffer)``
    fragments (the checkpoint scheduler's window lists).  Anything else
    — notably one-shot iterables, which must reach ``plan`` unconsumed —
    counts 0: the item-depth cap still bounds it, just not by bytes."""
    if isinstance(payload, (bytes, bytearray, memoryview)):
        return len(payload)
    if not isinstance(payload, (list, tuple)):
        return 0
    try:
        total = 0
        for entry in payload:
            total += len(entry[-1] if isinstance(entry, tuple) else entry)
        return total
    except TypeError:
        return 0
