"""Communicator abstraction — the MPI role in the paper's API (§A.2–A.3).

The scda API is collective over an MPI communicator.  This module provides
the minimal collective surface the format needs (barrier / broadcast /
allgather) behind one interface with three implementations:

  * :class:`SerialComm` — one rank; the common case inside a single JAX
    process (all local devices' shards are addressable, one writer).
  * :class:`ThreadComm` — P genuine concurrent ranks backed by threads.
    Used by tests and benchmarks to demonstrate partition-independent
    parallel writes against one shared file, byte-for-byte.
  * :class:`JaxProcessComm` — multi-host deployments: one rank per JAX
    process, collectives via ``jax.experimental.multihost_utils``.  On a
    single-process runtime it degrades to SerialComm semantics.

Only *values needed for file layout* travel through these collectives
(section parameters, compressed sizes); bulk data never does — each rank
writes its own windows, which is what makes the design scale to thousands
of nodes.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Sequence


class Communicator:
    """Minimal collective interface (mirrors the paper's mpicomm role)."""

    rank: int = 0
    size: int = 1

    def barrier(self) -> None:
        raise NotImplementedError

    def bcast(self, value: Any, root: int = 0) -> Any:
        raise NotImplementedError

    def allgather(self, value: Any) -> List[Any]:
        raise NotImplementedError

    # Convenience used by the compression path: allgather + flatten.
    def allgather_concat(self, values: Sequence[int]) -> List[int]:
        out: List[int] = []
        for part in self.allgather(list(values)):
            out.extend(part)
        return out


class SerialComm(Communicator):
    """Single rank — the degenerate (but most common) communicator."""

    def __init__(self) -> None:
        self.rank, self.size = 0, 1

    def barrier(self) -> None:
        pass

    def bcast(self, value: Any, root: int = 0) -> Any:
        return value

    def allgather(self, value: Any) -> List[Any]:
        return [value]


class _ThreadGroup:
    """Shared state for one ThreadComm group."""

    def __init__(self, size: int) -> None:
        self.size = size
        self.barrier = threading.Barrier(size)
        self.slots: List[Any] = [None] * size
        self.lock = threading.Lock()


class ThreadComm(Communicator):
    """One rank of a P-rank group executing in threads.

    Construction: ``ThreadComm.group(P)`` returns P communicators sharing
    one barrier; run each rank's workload in its own thread via
    :func:`run_ranks`.
    """

    def __init__(self, group: _ThreadGroup, rank: int) -> None:
        self._g = group
        self.rank = rank
        self.size = group.size

    @staticmethod
    def group(size: int) -> List["ThreadComm"]:
        g = _ThreadGroup(size)
        return [ThreadComm(g, r) for r in range(size)]

    def barrier(self) -> None:
        self._g.barrier.wait()

    def bcast(self, value: Any, root: int = 0) -> Any:
        if self.rank == root:
            self._g.slots[root] = value
        self._g.barrier.wait()
        out = self._g.slots[root]
        self._g.barrier.wait()
        return out

    def allgather(self, value: Any) -> List[Any]:
        self._g.slots[self.rank] = value
        self._g.barrier.wait()
        out = list(self._g.slots)
        self._g.barrier.wait()
        return out


def run_ranks(comms: Sequence[ThreadComm],
              fn: Callable[[ThreadComm], Any],
              timeout: Optional[float] = 60.0) -> List[Any]:
    """Run ``fn(comm)`` on every rank concurrently; re-raise any failure.

    A failing rank breaks the shared barrier so siblings do not deadlock.
    """
    results: List[Any] = [None] * len(comms)
    errors: List[BaseException] = []

    def _target(i: int, c: ThreadComm) -> None:
        try:
            results[i] = fn(c)
        except BaseException as e:  # noqa: BLE001 - propagated below
            errors.append(e)
            c._g.barrier.abort()

    threads = [threading.Thread(target=_target, args=(i, c), daemon=True)
               for i, c in enumerate(comms)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout)
    if errors:
        raise errors[0]
    return results


class JaxProcessComm(Communicator):
    """One rank per JAX process (multi-host).  Collectives cross hosts.

    In a real deployment ``jax.distributed.initialize`` has run and
    ``multihost_utils`` provides the collectives; in a single-process
    runtime this is SerialComm semantics with the live process indices.
    """

    def __init__(self) -> None:
        import jax
        self.rank = jax.process_index()
        self.size = jax.process_count()

    def barrier(self) -> None:
        if self.size == 1:
            return
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices("scda-barrier")

    def bcast(self, value: Any, root: int = 0) -> Any:
        if self.size == 1:
            return value
        from jax.experimental import multihost_utils
        return multihost_utils.broadcast_one_to_all(
            value, is_source=self.rank == root)

    def allgather(self, value: Any) -> List[Any]:
        if self.size == 1:
            return [value]
        from jax.experimental import multihost_utils
        return list(multihost_utils.process_allgather(value))
