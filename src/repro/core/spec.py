"""Byte-exact primitives of the scda format (paper §2).

Everything in this module is a pure function over ``bytes`` — no I/O, no
parallelism.  The parallel writer/reader and the serial oracle encoder are
built strictly on top of these primitives, so format conformance is testable
in one place.

Layout summary (paper Figures 1–5):

  file header F (128 B) = magic(7) ' ' pad('-', vendor → 24)        | 32 B
                          'F' ' ' pad('-', user → 62)               | 64 B
                          pad('=', 0 data bytes → 32)               | 32 B
  inline I     (96 B)  = 'I' ' ' pad('-', user → 62)  + data(32)
  block B              = 'B' ' ' pad('-', user → 62)
                         'E' ' ' pad('-', decimal E → 30)
                         data(E) + pad('=')
  array A              = 'A' header + 'N' entry + 'E' entry + data(N·E) + pad('=')
  varray V             = 'V' header + 'N' entry + N × 'E' entries + data(ΣEᵢ) + pad('=')

Two padding disciplines (§2.1):
  pad('-' to d):  input n ≤ d−4 →  ' ' + (p−3)ד-” + q,  p = d−n,
                  q = "-\n" (Unix) | "\r\n" (MIME).  Invertible from the right.
  pad('=' mod D): D = 32, p ∈ [7, 38] unique with (n+p) % 32 == 0,
                  = P + Qד=” + R with P/Q/R per Table 1; ends in a blank line.
"""
from __future__ import annotations

import dataclasses
import functools as _functools
from typing import List, Optional, Sequence, Tuple

try:  # numpy powers the vectorized count-entry fast paths; optional.
    import numpy as _np
except ImportError:  # pragma: no cover - numpy is a hard dep elsewhere
    _np = None

from repro.core.errors import ScdaError, ScdaErrorCode

# --------------------------------------------------------------------------
# Format constants (paper §2, Figure 1)
# --------------------------------------------------------------------------

#: scda format identifier byte (paper Fig. 1): (da)₁₆ = 218.
MAGIC_IDENT = 0xDA
#: Current format version (paper Fig. 1): counts from (a0)₁₆ to (ff)₁₆.
FORMAT_VERSION = 0xA0
#: The 7 magic bytes, ``sc%02xt%02x`` → b"scdata0" for version a0.
MAGIC = b"sc%02xt%02x" % (MAGIC_IDENT, FORMAT_VERSION)
assert MAGIC == b"scdata0" and len(MAGIC) == 7

#: Entry geometry.
VENDOR_FIELD = 24          # vendor string padded width (Fig. 1)
VENDOR_MAX = VENDOR_FIELD - 4          # = 20
USER_FIELD = 62            # user string padded width (Figs. 1–5)
USER_MAX = USER_FIELD - 4              # = 58
COUNT_FIELD = 30           # decimal count padded width (Figs. 3–5)
COUNT_MAX_DIGITS = COUNT_FIELD - 4     # = 26
COUNT_MAX = 10**COUNT_MAX_DIGITS - 1
COUNT_ENTRY_BYTES = 32     # letter + ' ' + padded count
SECTION_HEADER_BYTES = 64  # type letter + ' ' + padded user string
DATA_PAD_DIV = 32          # D in §2.1.2
FILE_HEADER_BYTES = 128
INLINE_DATA_BYTES = 32
INLINE_SECTION_BYTES = SECTION_HEADER_BYTES + INLINE_DATA_BYTES  # 96

SECTION_TYPES = (b"I", b"B", b"A", b"V")

#: Line-break styles (§2.1): the writer chooses; readers accept either.
UNIX = "unix"
MIME = "mime"
_FIXED_Q = {UNIX: b"-\n", MIME: b"\r\n"}


# --------------------------------------------------------------------------
# §2.1.1 — '-' padding of strings and counts to a fixed width
# --------------------------------------------------------------------------

def pad_fixed(data: bytes, d: int, style: str = UNIX) -> bytes:
    """Right-pad ``data`` (n ≤ d−4) to exactly ``d`` bytes per §2.1.1 (1)."""
    n = len(data)
    if n > d - 4:
        raise ScdaError(ScdaErrorCode.ARG_USER_STRING,
                        f"{n} bytes exceeds field capacity {d - 4}")
    p = d - n
    return data + b" " + b"-" * (p - 3) + _FIXED_Q[style]


def unpad_fixed(padded: bytes, d: int) -> bytes:
    """Invert :func:`pad_fixed`: parse from the right to infer p, return data.

    Either line-break style is accepted (§2.1: the writer's choice has no
    effect on reading).  Raises CORRUPT_PADDING on malformed padding.
    """
    if len(padded) != d:
        raise ScdaError(ScdaErrorCode.CORRUPT_PADDING,
                        f"field is {len(padded)} bytes, expected {d}")
    q = padded[-2:]
    if q not in (b"-\n", b"\r\n"):
        raise ScdaError(ScdaErrorCode.CORRUPT_PADDING,
                        f"bad terminal bytes {q!r}")
    # Scan dashes backwards from d-3 until the single space separator.
    i = d - 3
    while i >= 0 and padded[i:i + 1] == b"-":
        i -= 1
    if i < 0 or padded[i:i + 1] != b" ":
        raise ScdaError(ScdaErrorCode.CORRUPT_PADDING,
                        "missing space before dash padding")
    n = i
    p = d - n
    if p < 4:
        raise ScdaError(ScdaErrorCode.CORRUPT_PADDING,
                        f"padding only {p} bytes, minimum is 4")
    return padded[:n]


# --------------------------------------------------------------------------
# §2.1.2 — '=' padding of data bytes to a multiple of 32
# --------------------------------------------------------------------------

def data_pad_length(n: int) -> int:
    """The unique p ∈ [7, 38] with (n + p) divisible by 32."""
    p = (-n) % DATA_PAD_DIV
    if p < 7:
        p += DATA_PAD_DIV
    return p


def pad_data(n: int, last_byte: Optional[int], style: str = UNIX) -> bytes:
    """The data padding for ``n`` input bytes whose final byte is ``last_byte``.

    ``last_byte`` is ``None`` iff n == 0.  Per §2.1.2 and Table 1:
    P = "==" if the input ends in a line feed, else "\\n=" (Unix) / "\\r\\n"
    (MIME); then Q '=' bytes and R = "\\n\\n" (Unix) / "\\r\\n\\r\\n" (MIME).
    """
    # The padding depends only on (n mod 32, ends-in-LF, style) — memoize.
    return _pad_data_cached(n % DATA_PAD_DIV,
                            n > 0 and last_byte == 0x0A, style)


@_functools.lru_cache(maxsize=None)  # 32 × 2 × 2 keys
def _pad_data_cached(n: int, ends_lf: bool, style: str) -> bytes:
    p = data_pad_length(n)
    if ends_lf:
        head = b"=="
    elif style == MIME:
        head = b"\r\n"
    else:
        head = b"\n="
    if style == MIME:
        return head + b"=" * (p - 6) + b"\r\n\r\n"
    return head + b"=" * (p - 4) + b"\n\n"


def check_data_pad(pad: bytes, n: int, last_byte: Optional[int]) -> None:
    """Validate data padding leniently.

    §2.1.2: "If neither MIME nor Unix line endings are desired, the data
    padding may consist of p arbitrary bytes" — so only the *length* is
    normative.  We still sanity-check the length (the byte count is always
    inferable from the preceding file contents).
    """
    if len(pad) != data_pad_length(n):
        raise ScdaError(ScdaErrorCode.CORRUPT_PADDING,
                        f"data padding is {len(pad)} bytes, expected "
                        f"{data_pad_length(n)} for {n} data bytes")


# --------------------------------------------------------------------------
# Count entries ('E', 'N', and the §3 'U' convention)
# --------------------------------------------------------------------------

def format_count(value: int) -> bytes:
    """Decimal without leading spaces or zeros (§2.4), ≤ 26 digits."""
    if not isinstance(value, int) or isinstance(value, bool):
        raise ScdaError(ScdaErrorCode.ARG_COUNT_RANGE, f"{value!r} not an int")
    if value < 0 or value > COUNT_MAX:
        raise ScdaError(ScdaErrorCode.ARG_COUNT_RANGE, str(value))
    return str(value).encode("ascii")


def count_entry(letter: bytes, value: int, style: str = UNIX) -> bytes:
    """A 32-byte count entry: letter, ' ', decimal padded('-' to 30)."""
    if type(value) is int:  # excludes bool / np integers: uncached strict path
        return _count_entry_cached(letter, value, style)
    return _count_entry_impl(letter, value, style)


def _count_entry_impl(letter: bytes, value: int, style: str) -> bytes:
    assert len(letter) == 1
    return letter + b" " + pad_fixed(format_count(value), COUNT_FIELD, style)


_count_entry_cached = _functools.lru_cache(maxsize=4096)(_count_entry_impl)


# -- vectorized batch codec for count entries --------------------------------
# Varrays carry one 32-byte 'E' entry per element; generating/parsing them
# one Python call at a time is the O(N) hot loop the §A.4.4/§A.5.5 paths hit
# hardest.  These batch versions are byte-identical to count_entry /
# parse_count_entry (the scalar functions remain the oracle and the
# fallback for exotic inputs: values beyond int64, malformed entries).

#: Smallest batch worth the numpy fixed overhead.
_VEC_MIN = 4
#: 10^1 .. 10^18 — decimal-length table covering the whole int64 range.
_P10 = None if _np is None else 10 ** _np.arange(1, 19, dtype=_np.int64)
#: 10^0 .. 10^18 — positional-weight lookup for the batch parser.
_P10_W = None if _np is None else 10 ** _np.arange(0, 19, dtype=_np.int64)
_P10_DESC: dict = {}
_ENTRY_TEMPLATE: dict = {}


def _is_plain_int(v) -> bool:
    return type(v) is int


def _entry_template(letter: int, style: str):
    key = (letter, style)
    t = _ENTRY_TEMPLATE.get(key)
    if t is None:
        q = _FIXED_Q[style]
        t = _np.full(COUNT_ENTRY_BYTES, ord("-"), _np.uint8)
        t[0], t[1] = letter, 0x20
        t[30], t[31] = q[0], q[1]
        _ENTRY_TEMPLATE[key] = t
    return t


def _p10_desc(L: int):
    p = _P10_DESC.get(L)
    if p is None:
        p = 10 ** _np.arange(L - 1, -1, -1, dtype=_np.int64)
        _P10_DESC[L] = p
    return p


def count_entries(letter: bytes, values: Sequence[int],
                  style: str = UNIX, trusted_ints: bool = False) -> bytes:
    """``b"".join(count_entry(letter, v, style) for v in values)``, fast.

    Vectorized with numpy for int64-representable values; falls back to the
    scalar oracle otherwise (including for range/type errors, so error
    behavior is identical).  ``trusted_ints`` skips the per-element plain-int
    pre-screen — pass it ONLY for lists built from ``len()`` (a float/bool
    smuggled into a trusted list could be coerced instead of rejected).
    """
    n = len(values)
    if n == 0:
        return b""
    is_int_list = (type(values) in (list, tuple)
                   and (trusted_ints or all(map(_is_plain_int, values))))
    if is_int_list and n >= _VEC_MIN:
        first = values[0]
        if first >= 0 and values.count(first) == n:
            # Uniform Python-int values: replicate one oracle entry with
            # no numpy round-trip at all.
            return count_entry(letter, first, style) * n
    vals = None
    if _np is not None and n >= _VEC_MIN and (
            is_int_list or isinstance(values, _np.ndarray)):
        # Lists are pre-screened for plain ints above so np.asarray can
        # never silently coerce a float/bool the scalar oracle rejects.
        arr = _np.asarray(values)
        if (arr.ndim == 1 and arr.dtype.kind in "iu"
                and not (arr.dtype.kind == "u" and arr.dtype.itemsize == 8
                         and int(arr.max()) > 2 ** 63 - 1)):
            vals = arr.astype(_np.int64, copy=False)
            first = int(vals[0])
            if first >= 0 and bool((vals == first).all()):
                # Uniform values — the dominant real shape (fixed-size
                # chunks, U-entry arrays): one oracle entry, replicated.
                return count_entry(letter, first, style) * n
            if int(vals.min()) < 0:
                vals = None  # scalar path raises ARG_COUNT_RANGE
    if vals is None:
        # numpy integer scalars are not Python ints; unwrap them so the
        # scalar oracle's type validation stays strict for everything else.
        return b"".join(
            count_entry(letter,
                        int(v) if _np is not None
                        and isinstance(v, _np.integer) else v, style)
            for v in values)

    lens = _np.searchsorted(_P10, vals, side="right") + 1
    min_l, max_l = int(lens.min()), int(lens.max())
    buf = _np.empty((n, COUNT_ENTRY_BYTES), _np.uint8)
    buf[:] = _entry_template(letter[0], style)
    digs = vals[:, None] // _p10_desc(max_l)
    digs %= 10
    digs += ord("0")
    if min_l == max_l:  # uniform digit count — direct placement
        buf[:, 2:2 + max_l] = digs
        buf[:, 2 + max_l] = 0x20
    else:
        # ``digs`` is right-aligned (leading zeros); build a wide row of
        # [digits | ' ' | dashes] and gather each row's 28-byte tail
        # (digits + ' ' + dashes always total 28) with one shifted take.
        src = _np.full((n, max_l + COUNT_FIELD - 2), ord("-"), _np.uint8)
        src[:, :max_l] = digs
        src[:, max_l] = 0x20
        idx = _np.arange(COUNT_FIELD - 2)[None, :] + (max_l - lens)[:, None]
        buf[:, 2:COUNT_FIELD] = _np.take_along_axis(src, idx, 1)
    return buf.tobytes()


def parse_count_entries(raw: bytes, letter: Optional[bytes],
                        n: int) -> List[int]:
    """Parse ``n`` consecutive 32-byte count entries from ``raw``.

    ``letter=None`` accepts any entry letter (the §A.5.1 skip path).  Any
    malformed entry routes through the scalar parser so the error code and
    message match :func:`parse_count_entry` exactly.
    """
    if n == 0:
        return []
    if len(raw) != n * COUNT_ENTRY_BYTES:
        raise ScdaError(ScdaErrorCode.CORRUPT_TRUNCATED,
                        f"entry batch is {len(raw)} bytes, expected "
                        f"{n * COUNT_ENTRY_BYTES}")
    if _np is None or n < _VEC_MIN:
        return _parse_count_entries_scalar(raw, letter, n)
    a = _np.frombuffer(raw, _np.uint8).reshape(n, COUNT_ENTRY_BYTES)
    ok = a[:, 1] == 0x20
    if letter is not None:
        ok &= a[:, 0] == letter[0]
    q0, q1 = a[:, 30], a[:, 31]
    ok &= ((q0 == 0x2D) & (q1 == 0x0A)) | ((q0 == 0x0D) & (q1 == 0x0A))
    body = a[:, 2:COUNT_FIELD]
    isdig = (body >= 0x30) & (body <= 0x39)
    lens = isdig.argmin(1)  # first non-digit column == digit count
    ok &= (lens >= 1) & (lens <= COUNT_MAX_DIGITS)
    j = _np.arange(COUNT_FIELD - 2)
    after = j[None, :] - lens[:, None]  # <0 digit, ==0 space, >0 dash
    ok &= _np.where(after < 0, isdig,
                    _np.where(after == 0, body == 0x20,
                              body == 0x2D)).all(1)
    ok &= (a[:, 2] != 0x30) | (lens == 1)  # no leading zeros
    # Values with >18 digits overflow int64 — punt those to the scalar
    # parser too (legal up to 26 digits, just astronomically rare).
    max_l = int(lens.max())
    if not bool(ok.all()) or max_l > 18:
        return _parse_count_entries_scalar(raw, letter, n)
    exp = lens[:, None] - 1 - j[:max_l]
    weights = _P10_W[_np.clip(exp, 0, 18)]
    weights[exp < 0] = 0
    digits = body[:, :max_l].astype(_np.int64)
    digits -= 0x30
    vals = (digits * weights).sum(1)
    return vals.tolist()


def _parse_count_entries_scalar(raw: bytes, letter: Optional[bytes],
                                n: int) -> List[int]:
    out = []
    for i in range(n):
        entry = raw[i * COUNT_ENTRY_BYTES:(i + 1) * COUNT_ENTRY_BYTES]
        out.append(parse_count_entry(
            entry, entry[0:1] if letter is None else letter))
    return out


def parse_count_entry(entry: bytes, letter: bytes) -> int:
    """Parse and validate a 32-byte count entry."""
    if len(entry) != COUNT_ENTRY_BYTES:
        raise ScdaError(ScdaErrorCode.CORRUPT_TRUNCATED,
                        f"count entry is {len(entry)} bytes")
    if entry[0:1] != letter or entry[1:2] != b" ":
        raise ScdaError(ScdaErrorCode.CORRUPT_COUNT,
                        f"expected {letter!r} entry, got {entry[:2]!r}")
    digits = unpad_fixed(entry[2:], COUNT_FIELD)
    if not digits or not digits.isdigit():
        raise ScdaError(ScdaErrorCode.CORRUPT_COUNT, repr(digits))
    if len(digits) > COUNT_MAX_DIGITS:
        raise ScdaError(ScdaErrorCode.CORRUPT_COUNT,
                        f"{len(digits)} digits exceeds {COUNT_MAX_DIGITS}")
    value = int(digits)
    if str(value).encode() != digits:  # no leading zeros (except "0")
        raise ScdaError(ScdaErrorCode.CORRUPT_COUNT,
                        f"leading zeros in {digits!r}")
    return value


# --------------------------------------------------------------------------
# Section headers and the file header
# --------------------------------------------------------------------------

def section_header(type_letter: bytes, user_string: bytes,
                   style: str = UNIX) -> bytes:
    """The 64-byte 'section type and user string' entry."""
    return _section_header_cached(bytes(type_letter), bytes(user_string),
                                  style)


@_functools.lru_cache(maxsize=1024)
def _section_header_cached(type_letter: bytes, user_string: bytes,
                           style: str) -> bytes:
    assert len(type_letter) == 1
    if len(user_string) > USER_MAX:
        raise ScdaError(ScdaErrorCode.ARG_USER_STRING,
                        f"{len(user_string)} > {USER_MAX}")
    return type_letter + b" " + pad_fixed(user_string, USER_FIELD, style)


def parse_section_header(entry: bytes) -> Tuple[bytes, bytes]:
    """Parse a 64-byte section header → (type letter, user string)."""
    if len(entry) != SECTION_HEADER_BYTES:
        raise ScdaError(ScdaErrorCode.CORRUPT_TRUNCATED,
                        f"section header is {len(entry)} bytes")
    letter = entry[0:1]
    if entry[1:2] != b" ":
        raise ScdaError(ScdaErrorCode.CORRUPT_SECTION_TYPE,
                        f"missing separator after type {letter!r}")
    user = unpad_fixed(entry[2:], USER_FIELD)
    return letter, user


def file_header(vendor: bytes, user_string: bytes, style: str = UNIX,
                version: int = FORMAT_VERSION) -> bytes:
    """The 128-byte file header section F (paper Fig. 1)."""
    if len(vendor) > VENDOR_MAX:
        raise ScdaError(ScdaErrorCode.ARG_VENDOR_STRING,
                        f"{len(vendor)} > {VENDOR_MAX}")
    if not (0xA0 <= version <= 0xFF):
        raise ScdaError(ScdaErrorCode.ARG_COUNT_RANGE,
                        f"version {version:#x} outside [a0, ff]")
    magic = b"sc%02xt%02x" % (MAGIC_IDENT, version)
    row1 = magic + b" " + pad_fixed(vendor, VENDOR_FIELD, style)
    row2 = section_header(b"F", user_string, style)
    row3 = pad_data(0, None, style)  # zero data bytes → 32 pad bytes
    out = row1 + row2 + row3
    assert len(out) == FILE_HEADER_BYTES
    return out


@dataclasses.dataclass(frozen=True)
class FileHeader:
    version: int
    vendor: bytes
    user_string: bytes


def detect_style(header: bytes) -> str:
    """Infer the writer's line-break style from a 128-byte file header.

    §2.1 leaves the style to the writer and makes reading independent of
    it — but mode-'a' appends must *reproduce* the original choice so the
    grown file stays byte-identical to a single serial session.  The
    vendor field's terminal bytes (q = "-\\n" Unix, "\\r\\n" MIME) carry
    exactly that bit.
    """
    if len(header) < VENDOR_FIELD + 8:
        raise ScdaError(ScdaErrorCode.CORRUPT_TRUNCATED,
                        f"file header is {len(header)} bytes")
    q = header[VENDOR_FIELD + 8 - 2:VENDOR_FIELD + 8]
    if q == _FIXED_Q[MIME]:
        return MIME
    if q == _FIXED_Q[UNIX]:
        return UNIX
    raise ScdaError(ScdaErrorCode.CORRUPT_PADDING,
                    f"vendor field terminal bytes {q!r} match neither "
                    f"line-break style")


def parse_file_header(buf: bytes) -> FileHeader:
    """Parse and validate the 128-byte file header."""
    if len(buf) != FILE_HEADER_BYTES:
        raise ScdaError(ScdaErrorCode.CORRUPT_TRUNCATED,
                        f"file header is {len(buf)} bytes")
    magic = buf[:7]
    if magic[:2] != b"sc" or magic[4:5] != b"t":
        raise ScdaError(ScdaErrorCode.CORRUPT_MAGIC, repr(magic))
    try:
        ident = int(magic[2:4], 16)
        version = int(magic[5:7], 16)
    except ValueError as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_MAGIC, repr(magic)) from e
    if ident != MAGIC_IDENT:
        raise ScdaError(ScdaErrorCode.CORRUPT_MAGIC,
                        f"identifier {ident:#x} is not scda ({MAGIC_IDENT:#x})")
    if not (0xA0 <= version <= 0xFF):
        raise ScdaError(ScdaErrorCode.CORRUPT_MAGIC,
                        f"version {version:#x} outside [a0, ff]")
    if buf[7:8] != b" ":
        raise ScdaError(ScdaErrorCode.CORRUPT_MAGIC, "missing magic separator")
    vendor = unpad_fixed(buf[8:32], VENDOR_FIELD)
    letter, user = parse_section_header(buf[32:96])
    if letter != b"F":
        raise ScdaError(ScdaErrorCode.CORRUPT_SECTION_TYPE,
                        f"file header section letter {letter!r}")
    check_data_pad(buf[96:128], 0, None)
    return FileHeader(version=version, vendor=vendor, user_string=user)


# --------------------------------------------------------------------------
# Section size arithmetic (used by writer/reader cursor bookkeeping)
# --------------------------------------------------------------------------

def padded_data_bytes(n: int) -> int:
    """Bytes occupied on disk by an n-byte data payload plus its padding."""
    return n + data_pad_length(n)


def inline_section_bytes() -> int:
    return INLINE_SECTION_BYTES


def block_section_bytes(E: int) -> int:
    return SECTION_HEADER_BYTES + COUNT_ENTRY_BYTES + padded_data_bytes(E)


def array_section_bytes(N: int, E: int) -> int:
    return (SECTION_HEADER_BYTES + 2 * COUNT_ENTRY_BYTES
            + padded_data_bytes(N * E))


def varray_section_bytes(N: int, total_data: int) -> int:
    return (SECTION_HEADER_BYTES + (1 + N) * COUNT_ENTRY_BYTES
            + padded_data_bytes(total_data))


# §3 encoded sections span two physical sections; their combined extents
# (scdatool fsck cross-checks the reader's cursor walk against these).

def encoded_block_section_bytes(compressed_E: int) -> int:
    """§3.2 — I(magic, U-entry) followed by B(user, compressed)."""
    return INLINE_SECTION_BYTES + block_section_bytes(compressed_E)


def encoded_array_section_bytes(N: int, total_compressed: int) -> int:
    """§3.3 — I(magic, U-entry) followed by the carrier V section."""
    return INLINE_SECTION_BYTES + varray_section_bytes(N, total_compressed)


def encoded_varray_section_bytes(N: int, total_compressed: int) -> int:
    """§3.4 — A(magic, N, 32, U-entries) followed by the carrier V."""
    return (array_section_bytes(N, COUNT_ENTRY_BYTES)
            + varray_section_bytes(N, total_compressed))
