"""End-to-end I/O telemetry — spans, counters, and Chrome traces.

The stack runs five overlapping asynchronous engines (iovec writer,
writeback executor, prefetch pipeline, codec pool, sharded/parity
commit); this module is the one place they all report to, so a save or
restore can be profiled per stage instead of bisected.  Three sinks:

* **In-memory metrics** — :class:`Metrics` aggregates counters and
  latency histograms; ``Metrics.snapshot()`` returns a plain dict
  (``scdatool verify --timing`` and the benchmark harness read it).
* **Chrome ``trace_event`` JSON** — every span becomes a complete
  ("X") event with real thread ids, so the codec/writeback/prefetch
  pools show up as separate tracks in ``chrome://tracing`` / Perfetto.
* **Journal records** — :meth:`TraceCollector.commit_record` returns
  the per-commit counter deltas as a flat scalar pytree; the checkpoint
  manager flushes them into the archive's own journal
  (``repro.journal``), so telemetry is archived in-format.

Activation mirrors :mod:`repro.core.faults`: the quiet path is one
module-global load plus one environ lookup and allocates nothing —
``collector()`` returns None and every instrumentation site bails.
``REPRO_SCDA_TRACE=mem`` (or ``1``) collects in memory;
``REPRO_SCDA_TRACE=/path/trace.json`` additionally exports the Chrome
trace at process exit (and on :func:`flush`).  Programmatic use:
``install()`` / ``uninstall()`` / ``scoped()`` (what
``pytree_io.save(trace=...)`` rides).

Tracing never perturbs bytes: instrumented code paths are fuzzed
byte-identical to untraced runs by ``tests/test_trace.py``.

:func:`warn` is the single user-facing warning channel (degraded reads,
stale-lock takeover): logging-backed (logger ``repro.scda`` — capture
it with ``caplog`` in tests; without handlers it still lands on stderr
via logging's last-resort handler), rate-limited per message key, and
counted in the active collector's metrics.
"""
from __future__ import annotations

import atexit
import json
import logging
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

#: ``REPRO_SCDA_TRACE``: ``mem``/``1`` = collect in memory; any other
#: value = also export Chrome trace JSON to that path at process exit.
TRACE_ENV = "REPRO_SCDA_TRACE"

#: Event cap per collector — beyond it events drop (counted), metrics
#: keep aggregating.  A full sharded+parity save is ~10k events.
DEFAULT_MAX_EVENTS = 1_000_000

logger = logging.getLogger("repro.scda")

_collector: Optional["TraceCollector"] = None
_atexit_registered = False


# --------------------------------------------------------------------------
# Metrics: counters + latency histograms
# --------------------------------------------------------------------------

class Metrics:
    """Aggregated counters and log2-bucket latency histograms.

    Thread-safe; update cost is one lock + two dict ops, which is noise
    next to the syscalls being measured.  ``snapshot()`` is the read
    API — a plain nested dict, JSON-able as-is.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        # name -> [count, total, min, max, {bucket: count}] (µs values)
        self._hists: Dict[str, list] = {}

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def observe(self, name: str, value_us: float) -> None:
        """Record one latency/size observation (microseconds by
        convention for ``*.us`` names)."""
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = [0, 0.0, value_us, value_us, {}]
                self._hists[name] = h
            h[0] += 1
            h[1] += value_us
            if value_us < h[2]:
                h[2] = value_us
            if value_us > h[3]:
                h[3] = value_us
            b = max(0, int(value_us)).bit_length()
            h[4][b] = h[4].get(b, 0) + 1

    def get(self, name: str) -> int:
        with self._lock:
            return self._counters.get(name, 0)

    def snapshot(self) -> Dict[str, Any]:
        """``{"counters": {...}, "histograms": {name: {count, total_us,
        mean_us, min_us, max_us, p50_us, p99_us}}}`` — a stable plain
        dict copy."""
        with self._lock:
            counters = dict(self._counters)
            hists = {k: (h[0], h[1], h[2], h[3], dict(h[4]))
                     for k, h in self._hists.items()}
        out_h: Dict[str, Any] = {}
        for name, (count, total, mn, mx, buckets) in hists.items():
            out_h[name] = {
                "count": count,
                "total_us": round(total, 3),
                "mean_us": round(total / count, 3) if count else 0.0,
                "min_us": round(mn, 3),
                "max_us": round(mx, 3),
                "p50_us": _bucket_quantile(buckets, count, 0.50),
                "p99_us": _bucket_quantile(buckets, count, 0.99),
            }
        return {"counters": counters, "histograms": out_h}


def _bucket_quantile(buckets: Dict[int, int], count: int,
                     q: float) -> float:
    """Upper bound of the log2 bucket holding quantile ``q`` (µs)."""
    if not count:
        return 0.0
    want = max(1, int(count * q))
    seen = 0
    for b in sorted(buckets):
        seen += buckets[b]
        if seen >= want:
            return float(1 << b)
    return float(1 << max(buckets))


# --------------------------------------------------------------------------
# The collector
# --------------------------------------------------------------------------

class _Span:
    """Context manager emitting one complete event on exit."""
    __slots__ = ("_c", "_name", "_cat", "_args", "_t0")

    def __init__(self, c: "TraceCollector", name: str, cat: str,
                 args: Optional[Dict[str, Any]]) -> None:
        self._c, self._name, self._cat, self._args = c, name, cat, args
        self._t0 = 0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def add(self, **kw: Any) -> None:
        """Attach args discovered mid-span (e.g. a result size)."""
        if self._args is None:
            self._args = kw
        else:
            self._args.update(kw)

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.add(error=f"{exc_type.__name__}: {exc}")
        self._c.end(self._name, self._cat, self._t0, self._args)


class TraceCollector:
    """One trace session: an event buffer plus aggregated metrics.

    Event emission is designed for the hot paths: a tuple append under
    the GIL (no lock) plus a locked metrics update.  Thread ids are
    real (:func:`threading.get_ident`), so the ``scda-codec`` /
    ``scda-writeback`` / ``scda-prefetch`` pools get their own Chrome
    tracks.  ``path`` (optional) is where :meth:`export` writes the
    Chrome JSON by default.
    """

    def __init__(self, path: Optional[str] = None,
                 max_events: int = DEFAULT_MAX_EVENTS) -> None:
        self.path = path
        self.max_events = max_events
        self.metrics = Metrics()
        # (name, cat, ph, ts_ns, dur_ns, tid, args-or-None)
        self._events: List[tuple] = []
        self._dropped = 0
        self._pid = os.getpid()
        self._epoch_ns = time.perf_counter_ns()
        self._commit_base: Dict[str, int] = {}
        self._commit_lock = threading.Lock()

    # -- emission ----------------------------------------------------------

    @staticmethod
    def now() -> int:
        """Span start timestamp (ns); pair with :meth:`end`/``io_op``."""
        return time.perf_counter_ns()

    def _emit(self, name: str, cat: str, ph: str, ts: int, dur: int,
              args: Optional[Dict[str, Any]]) -> None:
        if len(self._events) >= self.max_events:
            self._dropped += 1
            return
        self._events.append(
            (name, cat, ph, ts, dur, threading.get_ident(), args))

    def end(self, name: str, cat: str, t0: int,
            args: Optional[Dict[str, Any]] = None) -> None:
        """Close a span opened at ``t0 = now()`` — one "X" event plus
        per-stage call/latency (and bytes, when given) metrics."""
        t1 = time.perf_counter_ns()
        m = self.metrics
        key = f"{cat}.{name}"
        m.count(key + ".calls")
        m.observe(key + ".us", (t1 - t0) / 1000.0)
        if args:
            b = args.get("bytes")
            if b:
                m.count(key + ".bytes", int(b))
        self._emit(name, cat, "X", t0, t1 - t0, args)

    def span(self, name: str, cat: str = "ckpt",
             **args: Any) -> _Span:
        return _Span(self, name, cat, args or None)

    def io_op(self, op: str, path: str, offset: int, nbytes: int,
              t0: int, error: Optional[str] = None) -> None:
        """One syscall through the :mod:`repro.core.faults` choke
        point: op kind, path, offset, bytes moved, latency."""
        t1 = time.perf_counter_ns()
        m = self.metrics
        m.count(f"io.{op}.calls")
        if nbytes:
            m.count(f"io.{op}.bytes", nbytes)
        m.observe(f"io.{op}.us", (t1 - t0) / 1000.0)
        args: Dict[str, Any] = {"path": path, "offset": offset,
                                "bytes": nbytes}
        if error is not None:
            m.count(f"io.{op}.errors")
            args["error"] = error
        self._emit(op, "io", "X", t0, t1 - t0, args)

    def event(self, name: str, cat: str = "ckpt", **args: Any) -> None:
        """Instant event (lifecycle marks: commit, takeover, …)."""
        self.metrics.count(f"{cat}.{name}")
        self._emit(name, cat, "i", time.perf_counter_ns(), 0,
                   args or None)

    def counter(self, name: str, value: int,
                cat: str = "pipeline") -> None:
        """Chrome "C" counter sample (queue depth, in-flight bytes)."""
        self._emit(name, cat, "C", time.perf_counter_ns(), 0,
                   {"value": int(value)})

    # -- sinks -------------------------------------------------------------

    def chrome(self) -> Dict[str, Any]:
        """The Chrome ``trace_event`` document (object form)."""
        events: List[Dict[str, Any]] = []
        for name, cat, ph, ts, dur, tid, args in list(self._events):
            ev: Dict[str, Any] = {
                "name": name, "cat": cat, "ph": ph,
                "pid": self._pid, "tid": tid,
                "ts": (ts - self._epoch_ns) / 1000.0,
            }
            if ph == "X":
                ev["dur"] = dur / 1000.0
            if args:
                ev["args"] = args
            events.append(ev)
        doc = {"traceEvents": events, "displayTimeUnit": "ms",
               "otherData": {"tool": "repro-scda",
                             "dropped_events": self._dropped}}
        return doc

    def export(self, path: Optional[str] = None) -> str:
        """Write the Chrome trace JSON; returns the path written."""
        target = path or self.path
        if not target:
            raise ValueError("no export path: pass one or construct "
                             "the collector with path=")
        with open(target, "w") as fh:
            json.dump(self.chrome(), fh)
            fh.write("\n")
        return target

    def commit_record(self) -> Dict[str, int]:
        """Counter deltas since the previous call — the per-commit
        metric record the checkpoint manager journals.  First call
        returns the totals so far."""
        snap = self.metrics.snapshot()["counters"]
        with self._commit_lock:
            base = self._commit_base
            delta = {k: v - base.get(k, 0) for k, v in snap.items()
                     if v - base.get(k, 0)}
            self._commit_base = snap
        return delta


# --------------------------------------------------------------------------
# Module-level activation (the faults.py pattern)
# --------------------------------------------------------------------------

def collector() -> Optional["TraceCollector"]:
    """The active collector, or None (the common, quiet case).

    The quiet path is one global load and one environ lookup —
    zero-allocation, the same discipline as ``faults._quiet()``.  When
    ``REPRO_SCDA_TRACE`` is set and nothing is installed yet, a
    collector is installed lazily from the environment.
    """
    c = _collector
    if c is not None:
        return c
    if not os.environ.get(TRACE_ENV):
        return None
    return _install_from_env()


def _install_from_env() -> "TraceCollector":
    global _atexit_registered
    raw = os.environ.get(TRACE_ENV, "").strip()
    path = None if raw in ("1", "mem", "memory") else raw or None
    c = install(TraceCollector(path=path))
    if path and not _atexit_registered:
        _atexit_registered = True
        atexit.register(flush)
    return c


def install(c: Optional["TraceCollector"] = None) -> "TraceCollector":
    """Install ``c`` (or a fresh collector) as the process-wide sink."""
    global _collector
    if c is None:
        c = TraceCollector()
    _collector = c
    return c


def uninstall() -> Optional["TraceCollector"]:
    """Deactivate tracing; returns the collector that was active."""
    global _collector
    c = _collector
    _collector = None
    return c


def flush() -> Optional[str]:
    """Export the active collector's Chrome trace to its path (no-op
    without a collector or path) — also the atexit hook for
    ``REPRO_SCDA_TRACE=/path.json`` runs."""
    c = _collector
    if c is not None and c.path:
        try:
            return c.export()
        except OSError:
            return None
    return None


class scoped:
    """``with trace.scoped(tc):`` — install for the duration, restore
    the previous sink after.  ``tc`` may be a :class:`TraceCollector`
    or a path string (a fresh collector exporting there on exit).
    What ``pytree_io.save(trace=...)`` uses."""

    def __init__(self, tc) -> None:
        if isinstance(tc, TraceCollector):
            self.collector = tc
            self._export = False
        else:
            self.collector = TraceCollector(path=str(tc))
            self._export = True
        self._prev: Optional[TraceCollector] = None

    def __enter__(self) -> TraceCollector:
        global _collector
        self._prev = _collector
        _collector = self.collector
        return self.collector

    def __exit__(self, exc_type, exc, tb) -> None:
        global _collector
        _collector = self._prev
        if self._export and self.collector.path:
            try:
                self.collector.export()
            except OSError:
                pass


# Convenience wrappers for lifecycle (cold) call sites.  Hot paths
# should hold the collector and guard explicitly instead — these build
# kwargs dicts before the quiet check.

class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def add(self, **kw: Any) -> None:
        pass

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


_NULL_SPAN = _NullSpan()


def span(name: str, cat: str = "ckpt", **args: Any):
    c = collector()
    return _NULL_SPAN if c is None else c.span(name, cat, **args)


def event(name: str, cat: str = "ckpt", **args: Any) -> None:
    c = collector()
    if c is not None:
        c.event(name, cat, **args)


# --------------------------------------------------------------------------
# warn(): the single user-facing warning channel
# --------------------------------------------------------------------------

_warn_lock = threading.Lock()
_warn_last: Dict[str, float] = {}
_warn_suppressed: Dict[str, int] = {}

#: Default suppression window for repeated warnings with the same key.
WARN_INTERVAL_S = 60.0


def warn(msg: str, *, key: Optional[str] = None,
         interval: float = WARN_INTERVAL_S) -> bool:
    """Emit one user-facing warning line; returns True if emitted.

    Logging-backed (logger ``repro.scda`` at WARNING — without
    configured handlers, logging's last-resort handler still writes it
    to ``sys.stderr``, preserving the historical loud behavior), and
    rate-limited: repeats with the same ``key`` (default: the message
    itself) within ``interval`` seconds are suppressed and counted.
    ``interval=0`` disables the limit for that call.  The active
    collector counts every call (``warn.emitted`` / ``warn.suppressed``)
    and records emitted warnings as instant events.
    """
    k = key if key is not None else msg
    now = time.monotonic()
    if interval > 0:
        with _warn_lock:
            last = _warn_last.get(k)
            if last is not None and now - last < interval:
                _warn_suppressed[k] = _warn_suppressed.get(k, 0) + 1
                c = _collector
                if c is not None:
                    c.metrics.count("warn.suppressed")
                return False
            _warn_last[k] = now
    logger.warning("repro: %s", msg)
    c = _collector
    if c is not None:
        c.metrics.count("warn.emitted")
        c.event("warn", "warn", message=msg)
    return True


def reset_warn_limits() -> None:
    """Forget rate-limit state (test isolation)."""
    with _warn_lock:
        _warn_last.clear()
        _warn_suppressed.clear()


# --------------------------------------------------------------------------
# Chrome-trace summarization (scdatool stats --trace / bench --trace)
# --------------------------------------------------------------------------

def load_chrome(path: str) -> List[Dict[str, Any]]:
    """The event list of a Chrome trace file (object or array form)."""
    with open(path) as fh:
        doc = json.load(fh)
    events = doc.get("traceEvents", doc) if isinstance(doc, dict) \
        else doc
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a trace_event document")
    return events


def summarize_chrome(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Per-stage breakdown of a Chrome trace: for every complete-event
    ``cat.name``, total/self time, call count, bytes moved, effective
    MB/s — plus wall time (first ts → last ts+dur) and syscall totals.
    """
    stages: Dict[str, Dict[str, Any]] = {}
    t_min = None
    t_max = None
    for ev in events:
        if ev.get("ph") != "X":
            continue
        ts = float(ev.get("ts", 0.0))
        dur = float(ev.get("dur", 0.0))
        t_min = ts if t_min is None else min(t_min, ts)
        t_max = ts + dur if t_max is None else max(t_max, ts + dur)
        key = f"{ev.get('cat', '?')}.{ev.get('name', '?')}"
        st = stages.setdefault(key, {"calls": 0, "total_us": 0.0,
                                     "bytes": 0})
        st["calls"] += 1
        st["total_us"] += dur
        b = (ev.get("args") or {}).get("bytes")
        if b:
            st["bytes"] += int(b)
    for st in stages.values():
        st["total_us"] = round(st["total_us"], 1)
        if st["bytes"] and st["total_us"]:
            st["MBps"] = round(
                st["bytes"] / (st["total_us"] / 1e6) / 1e6, 1)
    wall = round((t_max - t_min), 1) if t_min is not None else 0.0
    io_calls = sum(st["calls"] for k, st in stages.items()
                   if k.startswith("io."))
    io_bytes = sum(st["bytes"] for k, st in stages.items()
                   if k.startswith("io."))
    return {"wall_us": wall, "stages": stages,
            "io_calls": io_calls, "io_bytes": io_bytes}


def format_summary(summary: Dict[str, Any]) -> Iterator[str]:
    """Human-readable lines of a :func:`summarize_chrome` result."""
    wall = summary["wall_us"]
    yield (f"wall {wall / 1e3:.1f} ms, {summary['io_calls']} syscalls, "
           f"{summary['io_bytes']} bytes moved")
    yield (f"{'stage':<28} {'calls':>7} {'total':>10} {'%wall':>6} "
           f"{'bytes':>12} {'MB/s':>8}")
    items: List[Tuple[str, Dict[str, Any]]] = sorted(
        summary["stages"].items(),
        key=lambda kv: -kv[1]["total_us"])
    for name, st in items:
        pct = 100.0 * st["total_us"] / wall if wall else 0.0
        mbps = st.get("MBps")
        yield (f"{name:<28} {st['calls']:>7} "
               f"{st['total_us'] / 1e3:>8.1f}ms {pct:>5.1f}% "
               f"{st['bytes']:>12} "
               f"{mbps if mbps is not None else '-':>8}")
