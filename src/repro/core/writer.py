"""Parallel scda writer (paper §A.3–A.4).

All methods are collective over the communicator and must be called in the
same order on every rank with identical collective parameters (paper §A.2:
"it is an unchecked runtime error if they are indeed not collective" — we
*do* check what is cheaply checkable).  Every rank computes the identical
section layout from collective parameters and writes only its own windows
via positioned writes; rank 0 writes section metadata; the rank owning the
final data byte writes the '='-padding (its value depends on that byte).

This mirrors MPI_File_write_at usage in the reference libsc implementation
and keeps the file bytes invariant under the writing partition — the
serial-equivalence property at the heart of the paper.

Fast path: every section write assembles a scatter-gather list of
``(offset, buffer)`` fragments — header entries, count entries, payload
*views*, padding — and hands it to :meth:`FileBackend.write_gather`, which
coalesces adjacent fragments into single ``pwritev`` calls.  Payload bytes
are never concatenated or copied in user space; on one rank a whole
section is one syscall.  Varray count entries are generated vectorized
(:func:`repro.core.spec.count_entries`).

Durability: like MPI-IO (``MPI_File_sync`` is a separate, explicit call),
closing a file does *not* imply fsync.  Pass ``sync=True`` to
:func:`fopen_write`/:meth:`ScdaWriter.close` (or set ``REPRO_SCDA_FSYNC=1``)
for a collective close where every rank fsyncs after the final barrier —
the checkpoint layer does this before its atomic rename.

Mode 'a' (:func:`fopen_append`) reopens an existing archive, validates
its tail, and resumes the cursor so appended sections are byte-identical
to having written the longer file in one serial session — the journal
subsystem (:mod:`repro.journal`) streams training telemetry into the
same file a checkpoint lives in through exactly this path.
"""
from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Sequence, Tuple, Union

from repro.core import codec, partition, spec
from repro.core import encode as _encode
from repro.core.comm import Communicator, SerialComm
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.io_backend import BytesLike, FileBackend, as_byte_view

DEFAULT_VENDOR = b"repro scda-jax 0.1"
assert len(DEFAULT_VENDOR) <= spec.VENDOR_MAX

#: Close-time fsync default (overridable per file / per close).
DEFAULT_SYNC = os.environ.get("REPRO_SCDA_FSYNC", "0") not in ("0", "", "no")

#: A window is (element_start, buffer): ``buffer`` covers elements
#: [element_start, element_start + len/E) of the section's global data.
Window = Tuple[int, BytesLike]

#: A write fragment: (absolute file offset, buffer view).
Frag = Tuple[int, BytesLike]


_as_bytes = as_byte_view


@dataclasses.dataclass(frozen=True)
class _TailInfo:
    """What mode-'a' tail validation learned about an existing archive."""
    end: int                 # resume cursor: one past the last valid section
    sections: int            # number of logical sections before the append
    style: str               # line-break style the original writer chose
    version: int
    vendor: bytes
    user_string: bytes
    truncate_to: Optional[int] = None  # recover=True: drop a torn tail here


def _validate_append_tail(path: str, recover: bool = False) -> _TailInfo:
    """Validate an archive's tail before appending (rank-local).

    Fast path: a fresh ``.scdax`` sidecar pins every section boundary, so
    only the *last* section needs re-validation — its on-disk header is
    re-read (stale sidecars fail loudly, as on every seek), its count
    entries and extent arithmetic are re-walked, and the section must end
    exactly on end of file.  Without a usable sidecar the whole stream is
    walked header-only (which also discovers the resume cursor).

    A truncated or garbage tail raises the exact :class:`ScdaError` the
    reader taxonomy defines (CORRUPT_TRUNCATED / CORRUPT_* with the
    failing byte offset attached); with ``recover`` the validated prefix
    boundary is returned in ``truncate_to`` instead, so the caller may
    drop a torn tail (the journal's self-healing append) — a corrupt
    *file header* is never recoverable.
    """
    from repro.core.index import ScdaIndex
    from repro.core.reader import fopen_read
    with fopen_read(None, path) as r:
        style = spec.detect_style(
            r._backend.pread(0, spec.FILE_HEADER_BYTES))

        def info(end: int, sections: int,
                 truncate_to: Optional[int] = None) -> _TailInfo:
            return _TailInfo(end=end, sections=sections, style=style,
                             version=r.version, vendor=r.vendor,
                             user_string=r.user_string,
                             truncate_to=truncate_to)

        idx = None
        try:
            idx = ScdaIndex.load_sidecar(path)  # size-verified
        except (ScdaError, OSError):
            idx = None
        if idx is not None and (idx.scda_version != r.version
                                or idx.vendor != r.vendor
                                or idx.user_string != r.user_string):
            idx = None  # same-size rewrite: fall back to the full walk
        if idx is not None:
            if not idx.entries:
                # Sidecar verified the size; an empty table means a bare
                # file header, which fopen_read above already validated.
                return info(spec.FILE_HEADER_BYTES, 0)
            try:
                r.set_index(idx)
                r.seek_section(len(idx.entries) - 1)  # on-disk header check
                r.skip_data()  # count entries + extent arithmetic
            except ScdaError:
                idx = None  # stale in a way the size probe missed
            else:
                if r.cursor != r._file_size:
                    if recover:
                        return info(r.cursor, len(idx.entries),
                                    truncate_to=r.cursor)
                    raise ScdaError(
                        ScdaErrorCode.CORRUPT_TRUNCATED,
                        f"{path}: {r._file_size - r.cursor} trailing bytes "
                        f"past the last section", offset=r.cursor)
                return info(r.cursor, len(idx.entries))
        # Full header-only walk: finds the resume cursor and validates
        # every section boundary on the way.
        r._pending = None
        r.cursor = spec.FILE_HEADER_BYTES
        sections = 0
        while not r.at_eof:
            boundary = r.cursor
            try:
                r.read_section_header(decode=True)
                r.skip_data()
            except ScdaError as e:
                if not recover:
                    raise e.at(boundary)
                return info(boundary, sections, truncate_to=boundary)
            sections += 1
        return info(r.cursor, sections)


#: Public aliases: ``scdatool repair`` and the crash-consistency harness
#: reuse the mode-'a' tail validator as the salvage primitive.
TailInfo = _TailInfo


def validate_tail(path: str, recover: bool = False) -> _TailInfo:
    """Validate an archive tail without opening it for append.

    With ``recover=False`` a damaged tail raises the reader's exact
    ``ScdaError``; with ``recover=True`` the result's ``truncate_to``
    marks the end of the longest valid section prefix (None when the
    whole file is clean).  A corrupt *file header* always raises.
    """
    return _validate_append_tail(path, recover=recover)


class ScdaWriter:
    """File context for modes 'w' (create/overwrite) and 'a' (append —
    reserved by the paper's fopen, implemented here): both resume the
    same positioned-write fast path, differing only in how the starting
    cursor is established."""

    def __init__(self, comm: Communicator, path: str,
                 user_string: bytes = b"",
                 vendor: bytes = DEFAULT_VENDOR,
                 style: str = spec.UNIX,
                 sync: Optional[bool] = None,
                 mode: str = "w",
                 recover: bool = False) -> None:
        self.comm = comm
        self.sync = DEFAULT_SYNC if sync is None else sync
        self._closed = False
        self.mode = mode
        if mode == "a":
            # Every rank validates the tail rank-locally (identical bytes
            # ⇒ identical verdicts, the §A.5.1 metadata pattern); only
            # then is the writable descriptor opened, so a corrupt file
            # is never opened for writing at all.  The original style is
            # detected from the file header: appended padding must match
            # it or the grown file would not be byte-identical to one
            # serial session.
            tail = _validate_append_tail(path, recover=recover)
            self._backend = FileBackend(path, "a", create=False)
            self.style = tail.style
            self.version = tail.version
            self.vendor = tail.vendor
            self.user_string = tail.user_string
            self.base_sections = tail.sections
            self.base_size = tail.end
            comm.barrier()
            if tail.truncate_to is not None and comm.rank == 0:
                self._backend.truncate(tail.truncate_to)
            self.cursor = tail.end
            comm.barrier()
            return
        if mode != "w":
            raise ScdaError(ScdaErrorCode.ARG_MODE,
                            f"unsupported open mode {mode!r}")
        self.style = style
        self.version = spec.FORMAT_VERSION
        self.vendor = vendor
        self.user_string = user_string
        self.base_sections = 0
        self.base_size = spec.FILE_HEADER_BYTES
        self._backend = FileBackend(path, "w", create=(comm.rank == 0))
        self.cursor = 0
        # Root lays down the file header (Fig. 1); everyone syncs before any
        # section writes so the truncate cannot clobber them.
        comm.barrier()
        if comm.rank == 0:
            header = spec.file_header(vendor, user_string, style)
            self._backend.truncate(0)
            self._backend.pwrite(0, header)
        self.cursor = spec.FILE_HEADER_BYTES
        comm.barrier()

    # -- context manager ----------------------------------------------------
    def __enter__(self) -> "ScdaWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ------------------------------------------------------------------ I --
    def write_inline(self, user_string: bytes, data: Optional[BytesLike],
                     root: int = 0) -> None:
        """§A.4.1 — MPI_Bcast semantics: data is significant on root only."""
        self._check_open()
        if self.comm.rank == root:
            if data is None or len(_as_bytes(data)) != spec.INLINE_DATA_BYTES:
                raise ScdaError(ScdaErrorCode.ARG_INLINE_SIZE,
                                f"got {0 if data is None else len(data)}")
            self._backend.pwritev(
                self.cursor,
                _encode.iov_inline(user_string, _as_bytes(data), self.style))
        else:
            spec.section_header(b"I", user_string, self.style)  # arg check
        self.cursor += spec.INLINE_SECTION_BYTES

    # ------------------------------------------------------------------ B --
    def write_block(self, user_string: bytes, data: Optional[BytesLike],
                    E: Optional[int] = None, root: int = 0,
                    encode: bool = False) -> None:
        """§A.4.2 — global data block from ``root``; optional §3 encoding."""
        self._check_open()
        if encode:
            self._write_block_encoded(user_string, data, root)
            return
        if E is None:
            E = self.comm.bcast(
                len(_as_bytes(data)) if self.comm.rank == root else None, root)
        if self.comm.rank == root:
            view = _as_bytes(data)
            if len(view) != E:
                raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                                f"block data {len(view)} != E {E}")
            self._backend.pwritev(
                self.cursor, _encode.iov_block(user_string, view, self.style))
        self.cursor += spec.block_section_bytes(E)

    def _write_block_encoded(self, user_string: bytes,
                             data: Optional[BytesLike], root: int) -> None:
        """§3.2 — I(magic, U-entry) followed by B(user, compressed)."""
        if self.comm.rank == root:
            view = _as_bytes(data)
            u = len(view)
            compressed = codec.compress(view, self.style)
            meta = codec.uncompressed_size_entry(u, self.style)
            self.write_inline(codec.MAGIC_BLOCK, meta, root)
            # Compressed size must reach all ranks for cursor bookkeeping.
            self.comm.bcast(len(compressed), root)
            self.write_block(user_string, compressed, len(compressed), root)
        else:
            self.write_inline(codec.MAGIC_BLOCK, None, root)
            csize = self.comm.bcast(None, root)
            self.write_block(user_string, None, csize, root)

    # ------------------------------------------------------------------ A --
    def write_array(self, user_string: bytes,
                    local_data: Union[BytesLike, Sequence[BytesLike], None],
                    counts: Sequence[int], E: int,
                    indirect: bool = False, encode: bool = False) -> None:
        """§A.4.3 — fixed-size array under partition (N_q)_{<P}.

        ``local_data``: the rank's N_p elements — one contiguous buffer, or
        a sequence of N_p element buffers when ``indirect`` is true (lists
        and tuples are auto-detected as indirect).
        """
        self._check_open()
        indirect = indirect or isinstance(local_data, (list, tuple))
        if len(counts) != self.comm.size:
            raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                            f"{len(counts)} counts for {self.comm.size} ranks")
        N = sum(counts)
        if encode:
            elements = self._local_elements(local_data, counts, E, indirect)
            self.write_inline(
                codec.MAGIC_ARRAY,
                codec.uncompressed_size_entry(E, self.style)
                if self.comm.rank == 0 else None, 0)
            compressed = codec.compress_elements(elements, self.style)
            self._write_varray_raw(user_string, compressed, counts, N)
            return
        views, nbytes, last_byte = self._local_views(
            local_data, counts, E, indirect)
        frags: List[Frag] = []
        data_start = self._array_header_frags(frags, b"A", user_string, N, E)
        off, length = partition.byte_range(counts, E, self.comm.rank)
        if nbytes != length:
            raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                            f"local data {nbytes} != N_p*E {length}")
        pos = data_start + off
        for v in views:
            frags.append((pos, v))
            pos += len(v)
        self._append_padding(frags, data_start, N * E,
                             [c * E for c in counts], last_byte)
        self._backend.write_gather(frags)
        self.cursor = data_start + spec.padded_data_bytes(N * E)

    def write_array_windows(self, user_string: bytes,
                            windows: Sequence[Window],
                            N: int, E: int,
                            pad_last_byte: Optional[int] = None) -> None:
        """Generalized A-section write for non-contiguous ownership.

        The checkpoint layer uses this for 2-D-sharded tensors whose shards
        decompose into multiple contiguous runs of the canonical (row-major)
        element order.  ``windows`` are this rank's runs; collectively the
        runs must tile [0, N) exactly once.  ``pad_last_byte`` must be the
        value of the final data byte on the rank owning element N-1 (that
        rank writes the padding); pass None elsewhere.  This is a strict
        superset of :meth:`write_array` (which is the paper's contiguous
        case) and writes byte-identical files.

        Windows are written in ascending element order; adjacent windows
        coalesce into single vectored writes.
        """
        self._check_open()
        frags, next_cursor = self.plan_array_windows(
            user_string, windows, N, E, pad_last_byte, self.cursor)
        self._backend.write_gather(frags)
        self.cursor = next_cursor

    def plan_array_windows(self, user_string: bytes,
                           windows: Sequence[Window], N: int, E: int,
                           pad_last_byte: Optional[int] = None,
                           cursor: Optional[int] = None) \
            -> Tuple[List[Frag], int]:
        """This rank's :meth:`write_array_windows` fragments at ``cursor``
        — ``(frags, next_cursor)`` — without writing anything.

        The overlapped save engine's planning primitive: section offsets
        are fully determined by the collective parameters, so the
        scheduler plans every leaf's extents up front and emits the
        bodies out of order while the serial writer (which calls this
        exact method, then writes immediately) remains the byte oracle.
        """
        if cursor is None:
            cursor = self.cursor
        frags: List[Frag] = []
        data_start = self._array_header_frags(frags, b"A", user_string,
                                              N, E, cursor)
        owns_last = False
        for start, buf in sorted(windows, key=lambda w: w[0]):
            view = _as_bytes(buf)
            if len(view) % E:
                raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                                f"window not a multiple of E={E}")
            if start * E + len(view) > N * E:
                raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                                "window exceeds array extent")
            if len(view):
                frags.append((data_start + start * E, view))
                if start * E + len(view) == N * E:
                    owns_last = True
                    if pad_last_byte is None:
                        pad_last_byte = view[-1]
        n = N * E
        if owns_last:
            frags.append((data_start + n,
                          spec.pad_data(n, pad_last_byte, self.style)))
        elif n == 0 and self.comm.rank == 0:
            frags.append((data_start, spec.pad_data(0, None, self.style)))
        return frags, data_start + spec.padded_data_bytes(n)

    # ------------------------------------------------------------------ V --
    def write_varray(self, user_string: bytes,
                     local_data: Union[BytesLike, Sequence[BytesLike], None],
                     counts: Sequence[int],
                     local_sizes: Sequence[int],
                     per_rank_bytes: Optional[Sequence[int]] = None,
                     indirect: bool = False, encode: bool = False) -> None:
        """§A.4.4 — variable-size array.

        ``local_sizes`` are (E_i) for this rank's elements; ``per_rank_bytes``
        is the collective (S_q)_{<P} — per the paper we leave the allgather
        to the caller, but compute it if None is passed.  Lists/tuples are
        auto-detected as indirect addressing.
        """
        self._check_open()
        indirect = indirect or isinstance(local_data, (list, tuple))
        if len(counts) != self.comm.size:
            raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                            f"{len(counts)} counts for {self.comm.size} ranks")
        if len(local_sizes) != counts[self.comm.rank]:
            raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                            f"{len(local_sizes)} sizes != N_p "
                            f"{counts[self.comm.rank]}")
        elements = self._split(local_data, local_sizes, indirect)
        N = sum(counts)
        if encode:
            # §3.4 — A(magic, N, 32, U-entries) then V(user, compressed…).
            self._write_u_entry_array(counts, local_sizes, N)
            compressed = codec.compress_elements(
                [bytes(e) for e in elements], self.style)
            self._write_varray_raw(user_string, compressed, counts, N)
            return
        if per_rank_bytes is None:
            per_rank_bytes = self.comm.allgather(sum(local_sizes))
        self._write_varray_raw(user_string, elements, counts, N,
                               per_rank_bytes)

    def _write_varray_raw(self, user_string: bytes,
                          local_elements: Sequence[BytesLike],
                          counts: Sequence[int], N: int,
                          per_rank_bytes: Optional[Sequence[int]] = None) \
            -> None:
        """Shared raw-V writer (also the §3.3/§3.4 compressed-data carrier)."""
        local_views = [_as_bytes(e) for e in local_elements]
        local_sizes = [len(v) for v in local_views]
        if per_rank_bytes is None:
            per_rank_bytes = self.comm.allgather(sum(local_sizes))
        partition.validate(counts, N)
        offs = partition.offsets(counts)
        rank = self.comm.rank
        frags: List[Frag] = []
        entries_start = (self.cursor + spec.SECTION_HEADER_BYTES
                         + spec.COUNT_ENTRY_BYTES)
        data_start = entries_start + N * spec.COUNT_ENTRY_BYTES
        # Header built on every rank (collective argument validation),
        # enqueued only on rank 0.
        header = (spec.section_header(b"V", user_string, self.style),
                  spec.count_entry(b"N", N, self.style))
        if rank == 0:
            frags.append((self.cursor, header[0]))
            frags.append((self.cursor + spec.SECTION_HEADER_BYTES,
                          header[1]))
        # Each rank writes its own E_i entries (one vectorized buffer) …
        if counts[rank]:
            frags.append(
                (entries_start + offs[rank] * spec.COUNT_ENTRY_BYTES,
                 spec.count_entries(b"E", local_sizes, self.style,
                                    trusted_ints=True)))
        # … and its own data window, element views passed through untouched.
        my_off, my_len = partition.var_byte_ranges(
            counts, local_sizes, per_rank_bytes, rank)
        if my_len:
            pos = data_start + my_off
            last_local: Optional[int] = None
            for v in local_views:
                if len(v):
                    frags.append((pos, v))
                    pos += len(v)
                    last_local = v[-1]
        else:
            last_local = None
        total = sum(per_rank_bytes)
        self._append_varray_padding(frags, data_start, total, per_rank_bytes,
                                    last_local)
        self._backend.write_gather(frags)
        self.cursor = data_start + spec.padded_data_bytes(total)

    def plan_encoded_varray(self, user_string: bytes,
                            usizes: Sequence[int],
                            streams: Sequence[BytesLike],
                            cursor: Optional[int] = None) \
            -> Tuple[List[Frag], int]:
        """Single-rank planning mirror of ``write_varray(encode=True)``:
        the §3.4 A(U-entries) + V(compressed streams) section pair as
        ``(frags, next_cursor)``, nothing written.

        ``usizes`` are the uncompressed element sizes (the U entries —
        known from the layout before any byte deflates), ``streams`` the
        finished §3.1 streams.  The overlapped save engine calls this
        once a leaf's deflate futures resolve; byte-identity with the
        serial path holds because both build from the same
        :mod:`repro.core.encode` iovec oracles.
        """
        if self.comm.size != 1:
            raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                            "encoded-varray planning is single-rank "
                            "(matching write_varray(encode=True) use)")
        if cursor is None:
            cursor = self.cursor
        frags: List[Frag] = []
        u_entries = spec.count_entries(b"U", list(usizes), self.style)
        for part in _encode.iov_array(
                codec.MAGIC_VARRAY, u_entries, len(usizes),
                spec.COUNT_ENTRY_BYTES, self.style):
            if len(part):
                frags.append((cursor, part))
            cursor += len(part)
        for part in _encode.iov_varray(user_string, streams, self.style):
            if len(part):
                frags.append((cursor, part))
            cursor += len(part)
        return frags, cursor

    def plan_varray(self, user_string: bytes,
                    elements: Sequence[BytesLike],
                    cursor: Optional[int] = None) \
            -> Tuple[List[Frag], int]:
        """Single-rank planning mirror of the raw ``write_varray`` path:
        one V section holding ``elements`` as ``(frags, next_cursor)``,
        nothing written.

        The delta-checkpoint placement uses this for the changed-chunk
        subset of an uncompressed leaf — the same role
        :meth:`plan_encoded_varray` plays for deflated chunks.  Byte
        identity with :meth:`write_varray` holds because both derive the
        entry table and padding from the same :mod:`repro.core.spec`
        arithmetic.
        """
        if self.comm.size != 1:
            raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                            "varray planning is single-rank (matching the "
                            "delta placement's use)")
        if cursor is None:
            cursor = self.cursor
        views = [_as_bytes(e) for e in elements]
        sizes = [len(v) for v in views]
        N = len(views)
        frags: List[Frag] = []
        entries_start = (cursor + spec.SECTION_HEADER_BYTES
                         + spec.COUNT_ENTRY_BYTES)
        data_start = entries_start + N * spec.COUNT_ENTRY_BYTES
        frags.append((cursor,
                      spec.section_header(b"V", user_string, self.style)))
        frags.append((cursor + spec.SECTION_HEADER_BYTES,
                      spec.count_entry(b"N", N, self.style)))
        if N:
            frags.append((entries_start,
                          spec.count_entries(b"E", sizes, self.style,
                                             trusted_ints=True)))
        pos = data_start
        last: Optional[int] = None
        for v in views:
            if len(v):
                frags.append((pos, v))
                pos += len(v)
                last = v[-1]
        total = sum(sizes)
        frags.append((data_start + total,
                      spec.pad_data(total, last, self.style)))
        return frags, data_start + spec.padded_data_bytes(total)

    def _write_u_entry_array(self, counts: Sequence[int],
                             local_sizes: Sequence[int], N: int) -> None:
        """The A("V compressed scda 00", N, 32, U-entries) metadata section."""
        entries = spec.count_entries(b"U", local_sizes, self.style)
        view = memoryview(entries)
        self.write_array(
            codec.MAGIC_VARRAY,
            [view[i * spec.COUNT_ENTRY_BYTES:(i + 1) * spec.COUNT_ENTRY_BYTES]
             for i in range(len(local_sizes))],
            counts, spec.COUNT_ENTRY_BYTES, indirect=True)

    # -- shared helpers -------------------------------------------------------
    def _array_header_frags(self, frags: List[Frag], letter: bytes,
                            user_string: bytes, N: int, E: int,
                            cursor: Optional[int] = None) -> int:
        """Build the A-section header entries and return data_start.

        The entries are constructed on *every* rank so argument validation
        stays collective (all ranks raise together, none runs ahead into a
        diverged file state); only rank 0 enqueues them for writing.
        """
        if cursor is None:
            cursor = self.cursor
        header = (spec.section_header(letter, user_string, self.style),
                  spec.count_entry(b"N", N, self.style),
                  spec.count_entry(b"E", E, self.style))
        if self.comm.rank == 0:
            frags.append((cursor, header[0]))
            frags.append((cursor + spec.SECTION_HEADER_BYTES, header[1]))
            frags.append((cursor + spec.SECTION_HEADER_BYTES
                          + spec.COUNT_ENTRY_BYTES, header[2]))
        return (cursor + spec.SECTION_HEADER_BYTES
                + 2 * spec.COUNT_ENTRY_BYTES)

    def _append_padding(self, frags: List[Frag], data_start: int, n: int,
                        rank_bytes: Sequence[int],
                        last_byte: Optional[int]) -> None:
        last_rank = partition.last_nonempty_rank(rank_bytes)
        if last_rank < 0:
            if self.comm.rank == 0:
                frags.append((data_start,
                              spec.pad_data(0, None, self.style)))
        elif self.comm.rank == last_rank:
            frags.append((data_start + n,
                          spec.pad_data(n, last_byte, self.style)))

    def _append_varray_padding(self, frags: List[Frag], data_start: int,
                               total: int, per_rank_bytes: Sequence[int],
                               last_local: Optional[int]) -> None:
        last_rank = partition.last_nonempty_rank(per_rank_bytes)
        if last_rank < 0:
            if self.comm.rank == 0:
                frags.append((data_start,
                              spec.pad_data(0, None, self.style)))
        elif self.comm.rank == last_rank:
            frags.append((data_start + total,
                          spec.pad_data(total, last_local, self.style)))

    def _local_views(self, local_data, counts, E, indirect) \
            -> Tuple[List[memoryview], int, Optional[int]]:
        """This rank's data as a list of views: (views, nbytes, last_byte).

        Zero-copy: indirect element buffers stay separate fragments of one
        gathered write instead of being joined.
        """
        if indirect:
            elems = [_as_bytes(e) for e in (local_data or [])]
            if len(elems) != counts[self.comm.rank]:
                raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                                f"{len(elems)} buffers != N_p")
            for e in elems:
                if len(e) != E:
                    raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                                    f"element is {len(e)} bytes, E={E}")
            nbytes = E * len(elems)
            last = elems[-1][-1] if elems and E else None
            return elems, nbytes, last
        view = _as_bytes(local_data if local_data is not None else b"")
        if len(view) == 0:
            return [], 0, None
        return [view], len(view), view[-1]

    def _local_elements(self, local_data, counts, E, indirect) -> List[bytes]:
        views, nbytes, _ = self._local_views(local_data, counts, E, indirect)
        np_ = counts[self.comm.rank]
        if nbytes != np_ * E:
            raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                            f"local data {nbytes} != N_p*E {np_ * E}")
        if indirect:
            return [bytes(v) for v in views]
        flat = views[0] if views else memoryview(b"")
        return [bytes(flat[i * E:(i + 1) * E]) for i in range(np_)]

    def _split(self, local_data, local_sizes, indirect) -> List[memoryview]:
        if indirect:
            elems = [_as_bytes(e) for e in (local_data or [])]
            if [len(e) for e in elems] != list(local_sizes):
                raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                                "indirect buffer sizes != local_sizes")
            return elems
        flat = _as_bytes(local_data if local_data is not None else b"")
        if len(flat) != sum(local_sizes):
            raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                            f"flat data {len(flat)} != Σ sizes "
                            f"{sum(local_sizes)}")
        out, pos = [], 0
        for s in local_sizes:
            out.append(flat[pos:pos + s])
            pos += s
        return out

    def _check_open(self) -> None:
        if self._closed:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE, "writer is closed")

    def close(self, sync: Optional[bool] = None) -> None:
        """Collective close (§A.3.2).

        With ``sync`` (argument > constructor default > REPRO_SCDA_FSYNC)
        every rank fsyncs its descriptor after the final barrier — on a
        parallel file system each client must flush its own cache, so a
        single-rank fsync would not be durable multi-host.
        """
        if self._closed:
            return
        sync = self.sync if sync is None else sync
        # Quiesce BEFORE the barrier: with the overlapped save engine a
        # rank may still have queued background writes, and the final
        # barrier's contract is "all data is on its way to the kernel on
        # every rank" — a reader on another rank may open the file the
        # moment its own close returns.  A failed background write must
        # not leak the descriptor or skip the barriers (the other ranks
        # are waiting); it is re-raised once the close is complete.
        err: Optional[ScdaError] = None
        try:
            self._backend.drain_writes()
        except ScdaError as e:
            err = e
        self.comm.barrier()
        self._backend.close(sync=sync and err is None)
        self._closed = True
        self.comm.barrier()
        if err is not None:
            raise err


def fopen_write(comm: Optional[Communicator], path: str,
                user_string: bytes = b"", vendor: bytes = DEFAULT_VENDOR,
                style: str = spec.UNIX,
                sync: Optional[bool] = None) -> ScdaWriter:
    """``scda_fopen(..., 'w')`` — collective create/overwrite."""
    return ScdaWriter(comm or SerialComm(), path, user_string, vendor, style,
                      sync=sync)


def fopen_append(comm: Optional[Communicator], path: str,
                 sync: Optional[bool] = None,
                 recover: bool = False) -> ScdaWriter:
    """``scda_fopen(..., 'a')`` — collective append to an existing archive.

    The file's tail is validated first (magic, the last section's header,
    count entries, and extent/padding arithmetic; a fresh ``.scdax``
    sidecar makes this O(last section) instead of a full header walk) and
    the cursor resumes exactly where a single longer serial session would
    stand.  New sections then go through the identical planner/iovec fast
    path, so the grown file is byte-for-byte what one session writing all
    sections would have produced — under *any* appending partition.

    Vendor, user string, line-break style, and format version are
    inherited from the existing file header (they are already on disk).
    A truncated or garbage tail raises the reader's CORRUPT_* error with
    the failing byte offset; ``recover=True`` instead truncates a torn
    tail back to the last valid section boundary (never past the file
    header) before appending — the journal's self-healing mode.
    """
    return ScdaWriter(comm or SerialComm(), path, sync=sync, mode="a",
                      recover=recover)
