"""Positioned file I/O — the MPI-IO role (``MPI_File_write_at``) in scda.

Every rank holds its own descriptor onto the shared file and performs
positioned reads/writes at offsets computed *deterministically* from
collective section parameters.  No rank ever seeks relative to another —
that independence is what makes the write path scale and the bytes
partition-independent.

On a parallel file system (Lustre, GPFS) this maps 1:1 to MPI-IO or
per-node POSIX pwrite; on this container it is plain POSIX.  File-system
errors are translated to the paper's group-2 error codes.

Fast-path machinery (all byte-transparent):

* :meth:`FileBackend.pwritev` — vectored positioned writes (``os.pwritev``):
  a section's header, count entries, payload view, and padding go down in
  one syscall without concatenating (= copying) the payload.  Falls back to
  a sequential ``pwrite`` loop where the platform lacks ``pwritev``.
* :meth:`FileBackend.write_gather` — takes a scatter-gather list of
  ``(offset, buffer)`` fragments and coalesces *adjacent* fragments into
  single vectored writes, so a whole contiguous section becomes one syscall.
* :meth:`FileBackend.read_scatter` — the read mirror of ``write_gather``:
  fills ``(offset, buffer)`` fragments via ``os.preadv``, coalescing
  adjacent fragments into single vectored reads (IOV_MAX batching, partial
  reads resumed, EOF raises CORRUPT_TRUNCATED instead of spinning).
* A configurable readahead cache for mode ``'r'`` so metadata scans
  (64-byte section headers, 32-byte count entries) stop issuing tiny
  ``pread`` syscalls.  ``REPRO_SCDA_READAHEAD`` (bytes) tunes it; ``0``
  disables.  Large payload reads bypass the cache entirely.  The window is
  seek-aware: :meth:`FileBackend.refit_readahead` drops and re-fits it at a
  jump target instead of serving the first post-seek reads cold.
* A background prefetch executor (:meth:`FileBackend.prefetch`) that
  double-buffers upcoming extents for the overlapped restore engine
  (:mod:`repro.core.pipeline`): reads land in a bounded cache consulted by
  ``pread``/``read_scatter``; :meth:`FileBackend.release` drops consumed
  buffers and hands the pages back with ``posix_fadvise(DONTNEED)``.
* The write mirror of the prefetcher: :meth:`FileBackend.submit_write_gather`
  queues gather writes on a small background executor with BOUNDED
  in-flight bytes (``REPRO_SCDA_WRITE_PIPELINE`` window; submission blocks
  while the window is full), so the overlapped save engine can deflate
  leaf k+1 while leaf k's ``pwritev`` is still on its way to disk.
  :meth:`FileBackend.drain_writes` is the completion drain: it waits for
  every queued write and raises the first failure as the exact
  :class:`ScdaError` the foreground write would have raised.  Positioned
  writes at disjoint offsets commute, so background completion order never
  affects the bytes.
"""
from __future__ import annotations

import errno as _errno
import os
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.core import faults as _faults
from repro.core import trace as _trace
from repro.core.errors import (TRANSIENT_ERRNOS, ScdaError, ScdaErrorCode,
                               os_error_detail)

BytesLike = Union[bytes, bytearray, memoryview]

#: Consecutive zero-progress pwrite/pwritev returns tolerated before the
#: backend gives up with FS_WRITE (a 0-byte return must never spin forever).
MAX_ZERO_PROGRESS = 8

#: Default bound on transient-errno retries (EINTR immediately, EAGAIN
#: with exponential backoff) before a syscall aborts as a group-2 error;
#: ``REPRO_SCDA_RETRIES`` overrides.  Non-transient errnos — ENOSPC and
#: EIO above all — are never retried: retrying cannot unfill a disk, and
#: the caller's cleanup contract (tmp sweep) wants the error promptly.
DEFAULT_RETRIES = 16


def max_retries() -> int:
    """The effective transient-retry bound, read from the environment per
    call (cheap, and lets tests flip the knob without re-importing)."""
    raw = os.environ.get("REPRO_SCDA_RETRIES", "")
    try:
        return max(0, int(raw)) if raw else DEFAULT_RETRIES
    except ValueError:
        return DEFAULT_RETRIES

#: Default readahead window for mode-'r' backends (bytes); env-overridable.
DEFAULT_READAHEAD = int(os.environ.get("REPRO_SCDA_READAHEAD", str(64 << 10)))

#: Default prefetch window for the overlapped restore engine (bytes).
#: ``REPRO_SCDA_PREFETCH`` overrides; ``0`` disables prefetch entirely,
#: which makes every pipelined code path degrade to the serial read order.
DEFAULT_PREFETCH = 4 << 20


def prefetch_window() -> int:
    """The effective prefetch window, read from the environment per call
    (cheap, and lets tests flip the knob without re-importing)."""
    raw = os.environ.get("REPRO_SCDA_PREFETCH", "")
    try:
        return max(0, int(raw)) if raw else DEFAULT_PREFETCH
    except ValueError:
        return DEFAULT_PREFETCH


#: Default in-flight byte window for the overlapped save engine.
#: ``REPRO_SCDA_WRITE_PIPELINE`` overrides; ``0`` disables pipelined
#: writes entirely — every save degrades to the exact legacy serial
#: write order, which is the byte oracle the pipeline is tested against.
#: 32 MiB: large enough that two whole default-chunked leaves can be in
#: flight on both writeback workers (an 8 MiB window measured *slower*
#: than serial on raw saves — one leaf filled it and serialized the
#: queue), small enough to bound a save's extra memory.
DEFAULT_WRITE_PIPELINE = 32 << 20


def write_pipeline_window() -> int:
    """The effective write-pipeline window (bytes), read per call like
    :func:`prefetch_window`; ``0`` = serial saves."""
    raw = os.environ.get("REPRO_SCDA_WRITE_PIPELINE", "")
    try:
        return max(0, int(raw)) if raw else DEFAULT_WRITE_PIPELINE
    except ValueError:
        return DEFAULT_WRITE_PIPELINE


_HAS_PWRITEV = hasattr(os, "pwritev")
_HAS_PREADV = hasattr(os, "preadv")
try:
    _IOV_MAX = os.sysconf("SC_IOV_MAX")
    if _IOV_MAX <= 0:
        _IOV_MAX = 1024
except (AttributeError, ValueError, OSError):  # pragma: no cover
    _IOV_MAX = 1024

#: Consecutive fragments at or below this size are concatenated in user
#: space before the vectored write: copying a few KB costs less than the
#: kernel's per-iovec-segment processing, while big payload views are
#: always passed through zero-copy.
_JOIN_SMALL = 8 << 10


def as_byte_view(data: BytesLike) -> memoryview:
    """Normalize any buffer to a flat uint8 memoryview (zero-copy)."""
    v = memoryview(data)
    return v if v.format == "B" and v.ndim == 1 else v.cast("B")


_as_view = as_byte_view


class FileBackend:
    """One rank's positioned-I/O handle on the shared file."""

    def __init__(self, path: str, mode: str, create: bool,
                 readahead: Optional[int] = None) -> None:
        self.path = path
        self.mode = mode
        # Per-backend fault injector (faults.FaultBackend sets it); the
        # instrumented syscall wrappers also consult the process-wide /
        # REPRO_SCDA_FAULTS plans, so this stays None in production.
        self._inj = None
        flags = os.O_RDONLY
        if mode == "w":
            # fopen('w') semantics (§A.3): create new or truncate existing.
            flags = os.O_RDWR | os.O_CREAT
            if create:
                flags |= os.O_TRUNC
        elif mode == "a":
            # fopen('a') semantics: the file must already exist and is
            # never truncated at open — the writer validates the tail and
            # resumes its cursor there.  Reads (tail checks, probes) and
            # positioned writes both work on the one descriptor; the
            # writeback executor is available exactly as in mode 'w'.
            flags = os.O_RDWR
        try:
            self.fd = _faults.os_open(path, flags, 0o644)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_OPEN, f"{path}: {e}") from e
        # Readahead only makes sense for mode 'r': the file is immutable
        # while a reader holds it, so a stale-cache hazard cannot arise.
        self._readahead = (DEFAULT_READAHEAD if readahead is None
                           else readahead) if mode == "r" else 0
        self._cache: bytes = b""
        self._cache_off = 0
        # Prefetch state (mode 'r' only; executor is created lazily on the
        # first prefetch() call so serial readers never pay for a thread).
        self._pf_lock = threading.Lock()
        self._pf: Dict[int, Tuple[int, "Future"]] = {}  # off -> (len, fut)
        self._pf_pool = None
        # Writeback state (mode 'w' only; executor created lazily on the
        # first submit_write_gather so serial writers never pay for it).
        self._wb_lock = threading.Lock()
        # (future, bytes queued, start offset) — the offset rides along so
        # a background failure can name the fragment run that was lost.
        self._wb: List[Tuple["Future", int, int]] = []
        self._wb_pool = None
        self._wb_error: Optional[BaseException] = None
        # Sticky copy of the first failure: _wb_error is cleared once
        # drain_writes has delivered it, but the file stays poisoned —
        # later submissions must keep failing fast (a lost fragment
        # cannot be unlost by writing more).  ScdaError, or a
        # SimulatedCrash from the fault harness (never wrapped).
        self._wb_poison: Optional[BaseException] = None

    def _transient_retry(self, e: OSError, code: ScdaErrorCode,
                         offset: Optional[int], attempt: int) -> int:
        """Classify an OSError mid-loop: transient errnos (EINTR/EAGAIN)
        are always retried — EINTR immediately, per POSIX restart
        semantics; EAGAIN with capped exponential backoff — up to
        ``REPRO_SCDA_RETRIES`` times.  Everything else (ENOSPC, EIO, …)
        aborts NOW as the exact taxonomy error with the failing byte
        offset attached.  Returns the next attempt count."""
        if e.errno in TRANSIENT_ERRNOS and attempt < max_retries():
            c = _trace.collector()
            if c is not None:
                c.metrics.count("io.retries")
                c.event("retry", "io", path=self.path, errno=e.errno)
            if e.errno != _errno.EINTR:  # EINTR immediate; EAGAIN backs off
                time.sleep(min(0.001 * (1 << min(attempt, 6)), 0.05))
            return attempt + 1
        raise ScdaError(code, os_error_detail(self.path, offset, e, attempt),
                        offset=offset) from e

    # -- writes ---------------------------------------------------------------
    def pwrite(self, offset: int, data: BytesLike) -> None:
        view = _as_view(data)
        written, stalls, attempt = 0, 0, 0
        while written < len(view):
            try:
                n = _faults.os_pwrite(self.fd, view[written:],
                                      offset + written, path=self.path,
                                      inj=self._inj)
            except OSError as e:
                attempt = self._transient_retry(
                    e, ScdaErrorCode.FS_WRITE, offset + written, attempt)
                continue
            attempt = 0
            if n == 0:
                stalls += 1
                if stalls >= MAX_ZERO_PROGRESS:
                    raise ScdaError(
                        ScdaErrorCode.FS_WRITE,
                        f"{self.path}@{offset + written}: no write progress "
                        f"after {stalls} attempts",
                        offset=offset + written)
            else:
                stalls = 0
            written += n

    def pwritev(self, offset: int, buffers: Sequence[BytesLike]) -> None:
        """Write ``buffers`` contiguously at ``offset`` in as few syscalls
        as possible, without concatenating them in user space."""
        views: List[memoryview] = []
        small: List[memoryview] = []
        for b in buffers:
            v = _as_view(b)
            if not len(v):
                continue
            if len(v) <= _JOIN_SMALL:
                small.append(v)
                continue
            if small:  # join the run of small fragments, keep v zero-copy
                views.append(small[0] if len(small) == 1
                             else memoryview(b"".join(small)))
                small = []
            views.append(v)
        if small:
            views.append(small[0] if len(small) == 1
                         else memoryview(b"".join(small)))
        if not views:
            return
        # A run whose fragments all pre-joined used to collapse to ONE
        # view and silently degrade to pwrite — a different syscall with
        # its own stall counter, invisible to fault injection (and
        # accounting) at the pwritev layer.  Small-fragment runs now stay
        # on the vectored path whenever the platform has one, so every
        # gathered write shares a single zero-progress budget.
        if not _HAS_PWRITEV:  # pragma: no cover - exercised on exotic hosts
            for v in views:
                self.pwrite(offset, v)
                offset += len(v)
            return
        i, stalls, attempt = 0, 0, 0
        while i < len(views):
            batch = views[i:i + _IOV_MAX]
            try:
                n = _faults.os_pwritev(self.fd, batch, offset,
                                       path=self.path, inj=self._inj)
            except OSError as e:
                attempt = self._transient_retry(
                    e, ScdaErrorCode.FS_WRITE, offset, attempt)
                continue
            attempt = 0
            if n == 0:
                stalls += 1
                if stalls >= MAX_ZERO_PROGRESS:
                    raise ScdaError(
                        ScdaErrorCode.FS_WRITE,
                        f"{self.path}@{offset}: no write progress after "
                        f"{stalls} attempts", offset=offset)
                continue
            stalls = 0
            offset += n
            # Consume n bytes of the iovec list (partial writes resume
            # mid-buffer on the next iteration).
            while i < len(views) and n >= len(views[i]):
                n -= len(views[i])
                i += 1
            if i < len(views) and n:
                views[i] = views[i][n:]

    @staticmethod
    def _coalesce_runs(frags: Iterable[Tuple[int, BytesLike]]):
        """Group ``(offset, buffer)`` fragments into maximal contiguous
        runs, yielding ``(run_offset, run_bytes, buffers)``.  Fragments
        must arrive in non-decreasing offset order; zero-length buffers
        are skipped.  Shared by :meth:`write_gather` and
        :meth:`read_scatter` so the two sides can never diverge."""
        run_off = 0
        run_end = None
        bufs: List[BytesLike] = []
        for off, buf in frags:
            length = len(buf)
            if length == 0:
                continue
            if run_end is not None and off != run_end:
                yield run_off, run_end - run_off, bufs
                bufs = []
                run_end = None
            if run_end is None:
                run_off = run_end = off
            bufs.append(buf)
            run_end += length
        if bufs:
            yield run_off, run_end - run_off, bufs

    def write_gather(self,
                     frags: Iterable[Tuple[int, BytesLike]]) -> None:
        """Write ``(offset, buffer)`` fragments, coalescing adjacent runs.

        Fragments must arrive in non-decreasing offset order; each maximal
        contiguous run becomes a single vectored write.  Zero-length
        buffers are skipped.  Buffers must be bytes-like with ``len()`` in
        bytes (i.e. flat uint8 views — what the writer produces).
        """
        for run_off, _, bufs in self._coalesce_runs(frags):
            self.pwritev(run_off, bufs)

    # -- background writeback (the overlapped save engine's drain) ------------
    def submit_write_gather(self,
                            frags: Iterable[Tuple[int, BytesLike]],
                            window: int) -> None:
        """Queue ``frags`` for a background :meth:`write_gather`.

        The write mirror of :meth:`prefetch`: fragments are handed to a
        small executor and this call returns as soon as the queue has
        room — it BLOCKS (oldest-first) while more than ``window`` bytes
        are in flight, which is the pipeline's memory bound and the
        back-pressure that keeps a fast producer from buffering a whole
        checkpoint.  The caller's buffers are pinned by the queued job
        and must not be mutated until :meth:`drain_writes`.

        A failed background write surfaces as the exact
        :class:`ScdaError` the foreground :meth:`write_gather` would have
        raised — here on the next submission, or at the latest from
        :meth:`drain_writes`/:meth:`close`.  After a failure all later
        submissions fail fast without queueing, permanently — the
        poison survives :meth:`drain_writes` delivering the error (the
        file is already missing fragments; more writes cannot unpoison
        it), including submissions on the ``window <= 0`` serial path.

        ``window <= 0`` degrades to a plain synchronous
        :meth:`write_gather` — the serial oracle.
        """
        with self._wb_lock:
            self._reap_done_locked()
            self._raise_poison_locked()
        if window <= 0:
            self.write_gather(frags)
            return
        frags = [(off, buf) for off, buf in frags if len(buf)]
        nbytes = sum(len(buf) for _, buf in frags)
        off0 = frags[0][0] if frags else 0
        c = _trace.collector()
        if c is None:
            job = self.write_gather
        else:
            def job(frags=frags):  # traced worker-side span
                with c.span("writeback", "pipeline", path=self.path,
                            offset=off0, bytes=nbytes):
                    self.write_gather(frags)
        with self._wb_lock:
            if self._wb_pool is None:
                from concurrent.futures import ThreadPoolExecutor
                # Two workers: one write landing while the next queues.
                self._wb_pool = ThreadPoolExecutor(
                    max_workers=2, thread_name_prefix="scda-writeback")
        while True:
            with self._wb_lock:
                self._reap_done_locked()
                self._raise_poison_locked()
                inflight = sum(t[1] for t in self._wb)
                if not self._wb or inflight + nbytes <= window:
                    self._wb.append((
                        self._wb_pool.submit(job, frags) if c is None
                        else self._wb_pool.submit(job), nbytes, off0))
                    if c is not None:
                        c.counter("writeback.in_flight_bytes",
                                  inflight + nbytes)
                        c.counter("writeback.queue_depth", len(self._wb))
                    return
                head = self._wb[0][0]
            if c is not None:
                c.metrics.count("pipeline.writeback.stalls")
            # Oldest-first wait OUTSIDE the lock (the reap and
            # pending_write_bytes must stay reachable meanwhile):
            # submission order is also file order, so draining the head
            # frees window budget soonest.
            try:
                head.result()
            except BaseException:  # noqa: BLE001 - reap owns delivery
                pass  # recorded by the next reap; raised after accounting

    def _raise_poison_locked(self) -> None:
        """Fail fast on a poisoned backend, consuming the one-shot
        ``_wb_error`` delivery so a later drain/close does not re-raise
        an error this submission already handed to the caller."""
        if self._wb_poison is not None:
            self._wb_error = None
            raise self._wb_poison

    def _reap_done_locked(self) -> None:
        """Drop completed writeback jobs; record the first failure.

        A failure that crossed the executor boundary has lost the
        submitting stack, so the submission-time op context (stage, path,
        offset, bytes) is re-attached here: as ``op_context``/``stage``
        attributes plus an exception note (3.11+), never by rewriting the
        message — background errors must stay byte-identical to the
        foreground ones the pipeline fuzz compares against.
        """
        still = []
        for fut, n, off in self._wb:
            if fut.done():
                err = fut.exception()
                if err is not None and self._wb_poison is None:
                    # A SimulatedCrash must stay a crash — wrapping it in
                    # FS_WRITE would let the taxonomy "handle" power loss.
                    if isinstance(err, (ScdaError, _faults.SimulatedCrash)):
                        self._wb_poison = err
                    else:
                        wrapped = ScdaError(
                            ScdaErrorCode.FS_WRITE,
                            f"{self.path}: background writeback of {n} "
                            f"bytes @ {off}: {err}")
                        wrapped.__cause__ = err
                        self._wb_poison = wrapped
                    self._attach_op_context(
                        self._wb_poison, "writeback", off, n)
                    self._wb_error = self._wb_poison
            else:
                still.append((fut, n, off))
        self._wb[:] = still

    def _attach_op_context(self, err: BaseException, stage: str,
                           offset: int, nbytes: int) -> None:
        """Pin the failed stage onto an error surfaced from a pool worker
        (satellite of the telemetry PR): ``err.op_context`` for callers,
        an exception note for tracebacks, and a trace event when live."""
        err.stage = stage
        err.op_context = {"stage": stage, "path": self.path,
                          "offset": offset, "bytes": nbytes}
        note = getattr(err, "add_note", None)
        if note is not None:  # Python 3.11+
            try:
                note(f"stage: {stage} ({self.path} @ {offset}, "
                     f"{nbytes} bytes)")
            except TypeError:  # pragma: no cover - exotic BaseExceptions
                pass
        c = _trace.collector()
        if c is not None:
            c.event("error", "pipeline", stage=stage, path=self.path,
                    offset=offset, bytes=nbytes, error=str(err))

    def drain_writes(self) -> None:
        """Wait for every queued background write; raise the first error.

        The save engine's completion drain: a successful return means
        every submitted fragment is handed to the kernel (durability is
        still :meth:`fsync`'s job, exactly as for foreground writes).
        Idempotent and a no-op when nothing was ever submitted; an error
        is delivered once (so a close after a handled failure does not
        re-raise and mask it), but the backend stays poisoned for
        further submissions.
        """
        with self._wb_lock:
            pending = list(self._wb)
        for fut, _, _ in pending:
            try:
                fut.result()
            except BaseException:  # noqa: BLE001 - reap owns delivery
                pass  # recorded by the reap below
        with self._wb_lock:
            self._reap_done_locked()
            err, self._wb_error = self._wb_error, None
        if err is not None:
            raise err

    def pending_write_bytes(self) -> int:
        """Bytes queued or in flight on the writeback executor (test hook —
        a clean shutdown must leave this at 0)."""
        with self._wb_lock:
            self._reap_done_locked()
            return sum(t[1] for t in self._wb)

    # -- reads ----------------------------------------------------------------
    def pread(self, offset: int, n: int) -> bytes:
        if n <= 0:
            return b""
        if self._pf:
            hit = self._take_prefetched(offset, n)
            if hit is not None:
                return bytes(hit)
        ra = self._readahead
        if ra and n <= ra:
            lo, cache = self._cache_off, self._cache
            if lo <= offset and offset + n <= lo + len(cache):
                i = offset - lo
                return cache[i:i + n]
            cache = self._pread_upto(offset, ra)
            self._cache_off, self._cache = offset, cache
            if len(cache) < n:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_TRUNCATED,
                    f"{self.path}: EOF at {offset + len(cache)}, wanted {n}",
                    offset=offset + len(cache))
            return cache[:n]
        return self._pread_exact(offset, n)

    def _pread_exact(self, offset: int, n: int) -> bytes:
        out = self._pread_upto(offset, n)
        if len(out) < n:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_TRUNCATED,
                f"{self.path}: EOF at {offset + len(out)}, wanted {n}",
                offset=offset + len(out))
        return out

    def _pread_upto(self, offset: int, n: int) -> bytes:
        """Read up to ``n`` bytes; short only at end of file."""
        chunks: List[bytes] = []
        got, attempt = 0, 0
        while got < n:
            try:
                chunk = _faults.os_pread(self.fd, n - got, offset + got,
                                         path=self.path, inj=self._inj)
            except OSError as e:
                attempt = self._transient_retry(
                    e, ScdaErrorCode.FS_READ, offset + got, attempt)
                continue
            attempt = 0
            if not chunk:
                break
            chunks.append(chunk)
            got += len(chunk)
        if len(chunks) == 1:
            return chunks[0]
        return b"".join(chunks)

    def preadv(self, offset: int, bufs: Sequence[memoryview]) -> int:
        """Fill writable buffers contiguously from ``offset`` in as few
        syscalls as possible; returns bytes read (short only at EOF).

        The read mirror of :meth:`pwritev`: IOV_MAX batching and partial
        reads resumed mid-buffer.  A 0-byte return is EOF, never a stall,
        so the zero-progress guard here is simply to stop — callers decide
        whether a short fill is CORRUPT_TRUNCATED.
        """
        views = [v if isinstance(v, memoryview) else memoryview(v)
                 for v in bufs if len(v)]
        if not _HAS_PREADV:  # pragma: no cover - exercised on exotic hosts
            got = 0
            for v in views:
                data = self._pread_upto(offset + got, len(v))
                v[:len(data)] = data
                got += len(data)
                if len(data) < len(v):
                    break
            return got
        i, got, attempt = 0, 0, 0
        while i < len(views):
            batch = views[i:i + _IOV_MAX]
            try:
                n = _faults.os_preadv(self.fd, batch, offset + got,
                                      path=self.path, inj=self._inj)
            except OSError as e:
                attempt = self._transient_retry(
                    e, ScdaErrorCode.FS_READ, offset + got, attempt)
                continue
            attempt = 0
            if n == 0:  # EOF — no spinning possible on reads
                break
            got += n
            while i < len(views) and n >= len(views[i]):
                n -= len(views[i])
                i += 1
            if i < len(views) and n:
                views[i] = views[i][n:]
        return got

    def read_scatter(self,
                     frags: Iterable[Tuple[int, BytesLike]]) -> None:
        """Fill ``(offset, buffer)`` fragments, coalescing adjacent runs.

        The read mirror of :meth:`write_gather`: fragments must arrive in
        non-decreasing offset order; each maximal contiguous run becomes a
        single vectored read straight into the caller's buffers (no user
        space concatenation or copy).  Runs covered by a prefetched extent
        are served from the prefetch cache without a syscall.  A run that
        cannot be filled completely raises CORRUPT_TRUNCATED, exactly as
        :meth:`pread` would.
        """
        for run_off, total, bufs in self._coalesce_runs(frags):
            self._read_run(run_off, total, bufs)

    def _read_run(self, offset: int, total: int,
                  bufs: List[BytesLike]) -> None:
        if self._pf:
            hit = self._take_prefetched(offset, total)
            if hit is not None:
                pos = 0
                for b in bufs:
                    v = memoryview(b)
                    v[:] = hit[pos:pos + len(v)]
                    pos += len(v)
                return
        got = self.preadv(offset, [memoryview(b) for b in bufs])
        if got < total:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_TRUNCATED,
                f"{self.path}: EOF at {offset + got}, wanted {total}",
                offset=offset + got)

    def read_extents(self, extents: Sequence[Tuple[int, int]]) \
            -> List[BytesLike]:
        """Read ``(offset, length)`` extents into per-extent buffers.

        Extents covered by a prefetched run are returned as ZERO-COPY
        views of the prefetch buffer (the §3 decode path only reads
        them); misses fall back to exact preads.  Raises
        CORRUPT_TRUNCATED on short data, like :meth:`pread`.
        """
        out: List[BytesLike] = []
        for off, n in extents:
            if n <= 0:
                out.append(b"")
                continue
            hit = self._take_prefetched(off, n) if self._pf else None
            out.append(hit if hit is not None
                       else self._pread_exact(off, n))
        return out

    # -- background prefetch (the overlapped restore engine's feeder) ---------
    def prefetch(self, extents: Sequence[Tuple[int, int]],
                 window: int, start: int = 0) -> int:
        """Schedule background reads of ``(offset, length)`` extents,
        beginning at index ``start``.

        Adjacent extents coalesce into single jobs; scheduling stops once
        ``window`` bytes are buffered or in flight (the double-buffering
        bound — :meth:`release` returns budget as the consumer advances).
        Returns how many extents past ``start`` were accepted (a prefix),
        so a caller can resume from the first unaccepted extent later by
        advancing ``start`` — without re-slicing its extent list each
        call.  Purely advisory: a failed or short prefetch read is
        re-issued (and its error raised) by the foreground
        ``pread``/``read_scatter`` that actually consumes the extent.
        No-op outside mode 'r'.
        """
        if self.mode != "r" or window <= 0 or self.fd < 0:
            return 0
        accepted = 0
        with self._pf_lock:
            budget = window - sum(ln for ln, _ in self._pf.values())
            if budget <= 0:
                return 0
            run_off = run_len = 0
            for k in range(start, len(extents)):
                off, n = extents[k]
                if n <= 0:
                    accepted += 1
                    continue
                if n > window:
                    # Never buffer an extent bigger than the whole window;
                    # count it accepted so the pipeline moves past it and
                    # the foreground read handles it directly.
                    if run_len:
                        budget -= self._submit_prefetch(run_off, run_len)
                        run_len = 0
                    accepted += 1
                    continue
                if run_len and off == run_off + run_len:
                    run_len += n
                else:
                    if run_len:
                        budget -= self._submit_prefetch(run_off, run_len)
                    run_off, run_len = off, n
                accepted += 1
                if run_len >= budget:  # window full (open run included)
                    break
            if run_len:
                self._submit_prefetch(run_off, run_len)
        return accepted

    def _submit_prefetch(self, offset: int, length: int) -> int:
        """Submit one coalesced run (caller holds the lock); returns the
        number of bytes newly scheduled (0 if already covered).

        A run whose head overlaps buffered/in-flight entries is trimmed
        to the uncovered tail — overwriting the dict entry instead (runs
        are keyed by offset) would orphan a still-running job and read
        the shared bytes twice, exactly on the boundary chunks adjacent
        items have in common."""
        trimmed = True
        while trimmed and length > 0:
            trimmed = False
            for po, (plen, _) in self._pf.items():
                if po <= offset < po + plen:
                    cut = min(po + plen - offset, length)
                    offset += cut
                    length -= cut
                    trimmed = True
                    break
        if length <= 0:
            return 0
        if self._pf_pool is None:
            from concurrent.futures import ThreadPoolExecutor
            # Two workers: one extent landing while the next is in flight.
            self._pf_pool = ThreadPoolExecutor(
                max_workers=2, thread_name_prefix="scda-prefetch")
        fd, path, inj = self.fd, self.path, self._inj

        def _job() -> bytes:
            # Routed through the fault layer so injected read faults hit
            # background prefetch too; a failing job is dropped by
            # _take_prefetched and the extent is re-read in the
            # foreground, which raises the exact ScdaError (with byte
            # offset) a never-prefetched read would have.
            chunks, got = [], 0
            while got < length:
                chunk = _faults.os_pread(fd, length - got, offset + got,
                                         path=path, inj=inj)
                if not chunk:
                    break  # short at EOF; consumer re-reads and raises
                chunks.append(chunk)
                got += len(chunk)
            return b"".join(chunks)

        c = _trace.collector()
        if c is not None:
            inner = _job

            def _job() -> bytes:  # noqa: F811 - traced worker-side span
                with c.span("prefetch", "pipeline", path=path,
                            offset=offset, bytes=length):
                    return inner()

        self._pf[offset] = (length, self._pf_pool.submit(_job))
        if c is not None:
            c.counter("prefetch.extents", len(self._pf))
        return length

    def _take_prefetched(self, offset: int, n: int) -> Optional[memoryview]:
        """A zero-copy view of [offset, offset+n) if a prefetched extent
        fully covers it, else None (the caller falls back to a real read).
        Waits for an in-flight job covering the range; a job that failed
        (OSError) is dropped so the foreground read reports the error."""
        with self._pf_lock:
            found = None
            for po, (plen, fut) in self._pf.items():
                if po <= offset and offset + n <= po + plen:
                    found = (po, plen, fut)
                    break
            if found is None:
                return None
        po, plen, fut = found
        try:
            data = fut.result()
        except OSError as e:
            # The foreground re-read owns error delivery; name the stage
            # that actually failed so diagnostics don't blame the re-read.
            self._attach_op_context(e, "prefetch", po, plen)
            with self._pf_lock:
                self._pf.pop(po, None)
            return None
        if offset + n > po + len(data):  # short at EOF
            return None
        return memoryview(data)[offset - po:offset - po + n]

    def release(self, upto: int) -> None:
        """Drop prefetched extents that end at or before ``upto`` and hand
        their pages back to the kernel (``DONTNEED``) — the restore engine
        calls this as it consumes the file front to back, so a long restore
        never grows the page cache beyond the prefetch window."""
        dropped = []
        with self._pf_lock:
            for po in list(self._pf):
                plen, fut = self._pf[po]
                if po + plen <= upto and fut.done():
                    del self._pf[po]
                    dropped.append((po, plen))
        for po, plen in dropped:
            self.advise(po, plen, "dontneed")
        if self._cache and self._cache_off + len(self._cache) <= upto:
            self._cache = b""

    def pending_prefetch(self) -> int:
        """Number of prefetch extents buffered or in flight (test hook —
        a clean shutdown must leave this at 0)."""
        with self._pf_lock:
            return len(self._pf)

    def refit_readahead(self, offset: int) -> None:
        """Seek-aware readahead: drop the window and re-fit it at ``offset``
        when a jump lands outside it, so post-seek metadata reads (the
        64-byte header check, count entries) are warm instead of each
        paying a cold miss.  No-op when readahead is disabled or the
        target is already inside the current window."""
        ra = self._readahead
        if not ra:
            return
        lo = self._cache_off
        if lo <= offset < lo + len(self._cache):
            return
        self._cache_off, self._cache = offset, self._pread_upto(offset, ra)

    # -- access-pattern hints -------------------------------------------------
    _ADVICE = {}
    if hasattr(os, "posix_fadvise"):  # pragma: no branch - platform constant
        _ADVICE = {
            "willneed": os.POSIX_FADV_WILLNEED,
            "sequential": os.POSIX_FADV_SEQUENTIAL,
            "random": os.POSIX_FADV_RANDOM,
            "dontneed": os.POSIX_FADV_DONTNEED,
        }

    def advise(self, offset: int, length: int, advice: str) -> None:
        """Advisory readahead hint (``posix_fadvise``); silently a no-op
        where the platform lacks it or the kernel declines.

        The index layer issues ``sequential`` for its one header-only scan
        and ``willneed`` for the extent of a section about to be read after
        a seek — random access should not pay sequential-readahead
        misprediction on a parallel file system.
        """
        fadv = self._ADVICE.get(advice)
        if fadv is None or self.fd < 0:
            return
        try:
            os.posix_fadvise(self.fd, offset, max(0, length), fadv)
        except OSError:  # advisory only — never an scda error
            pass

    # -- metadata / lifecycle -------------------------------------------------
    def size(self) -> int:
        try:
            return os.fstat(self.fd).st_size
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_READ, str(e)) from e

    def truncate(self, n: int) -> None:
        try:
            _faults.os_ftruncate(self.fd, n, path=self.path, inj=self._inj)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_WRITE,
                            os_error_detail(self.path, n, e)) from e
        self._cache = b""  # cached bytes past the cut are stale

    def fsync(self) -> None:
        attempt = 0
        while True:
            try:
                _faults.os_fsync(self.fd, path=self.path, inj=self._inj)
                return
            except OSError as e:
                attempt = self._transient_retry(
                    e, ScdaErrorCode.FS_WRITE, None, attempt)

    def close(self, sync: bool = False) -> None:
        if self.fd < 0:
            return
        # Drain the prefetcher FIRST: background jobs read self.fd, so the
        # descriptor must stay open until every job has finished or been
        # cancelled.  shutdown(wait=True) guarantees no leaked futures.
        if self._pf_pool is not None:
            self._pf_pool.shutdown(wait=True, cancel_futures=True)
            self._pf_pool = None
        with self._pf_lock:
            self._pf.clear()
        # Same for the writeback executor: every queued write must reach
        # the kernel before fsync/close, and a failed one must surface as
        # the ScdaError the foreground write would have raised (after the
        # fd is closed — never leak it on the error path).
        wb_err: Optional[BaseException] = None
        if self._wb_pool is not None:
            try:
                self.drain_writes()
            except (ScdaError, _faults.SimulatedCrash) as e:
                wb_err = e
            self._wb_pool.shutdown(wait=True)
            self._wb_pool = None
        try:
            if sync and wb_err is None:
                try:
                    self.fsync()   # transient errnos retried like any fsync
                except ScdaError:
                    os.close(self.fd)   # never leak the fd on give-up
                    raise
            os.close(self.fd)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_CLOSE, str(e)) from e
        finally:
            self.fd = -1
            self._cache = b""
        if wb_err is not None:
            raise wb_err


# -- durable metadata helpers -------------------------------------------------
# An atomic rename is only the commit point once the *directory entry* is on
# disk: POSIX lets a power cut after os.replace() roll the rename back unless
# the parent directory is fsynced.  Every commit in the repo (checkpoint file,
# sidecar refresh, sharded manifest) goes through these helpers.

def fsync_dir(path: str) -> None:
    """fsync a directory so renames inside it survive a power cut."""
    try:
        _faults.os_fsync_dir(path or ".")
    except OSError as e:
        raise ScdaError(ScdaErrorCode.FS_WRITE,
                        f"{path}: directory fsync: {e}") from e


def replace_file(src: str, dst: str) -> None:
    """os.replace with the ScdaError taxonomy (and fault injection)."""
    try:
        _faults.os_replace(src, dst)
    except OSError as e:
        raise ScdaError(ScdaErrorCode.FS_WRITE,
                        f"{src} -> {dst}: {e}") from e


def replace_durable(src: str, dst: str) -> None:
    """Atomic rename plus parent-directory fsync: the full commit point."""
    replace_file(src, dst)
    fsync_dir(os.path.dirname(os.path.abspath(dst)))
