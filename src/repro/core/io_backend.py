"""Positioned file I/O — the MPI-IO role (``MPI_File_write_at``) in scda.

Every rank holds its own descriptor onto the shared file and performs
positioned reads/writes at offsets computed *deterministically* from
collective section parameters.  No rank ever seeks relative to another —
that independence is what makes the write path scale and the bytes
partition-independent.

On a parallel file system (Lustre, GPFS) this maps 1:1 to MPI-IO or
per-node POSIX pwrite; on this container it is plain POSIX.  File-system
errors are translated to the paper's group-2 error codes.

Fast-path machinery (all byte-transparent):

* :meth:`FileBackend.pwritev` — vectored positioned writes (``os.pwritev``):
  a section's header, count entries, payload view, and padding go down in
  one syscall without concatenating (= copying) the payload.  Falls back to
  a sequential ``pwrite`` loop where the platform lacks ``pwritev``.
* :meth:`FileBackend.write_gather` — takes a scatter-gather list of
  ``(offset, buffer)`` fragments and coalesces *adjacent* fragments into
  single vectored writes, so a whole contiguous section becomes one syscall.
* A configurable readahead cache for mode ``'r'`` so metadata scans
  (64-byte section headers, 32-byte count entries) stop issuing tiny
  ``pread`` syscalls.  ``REPRO_SCDA_READAHEAD`` (bytes) tunes it; ``0``
  disables.  Large payload reads bypass the cache entirely.
"""
from __future__ import annotations

import os
from typing import Iterable, List, Optional, Sequence, Tuple, Union

from repro.core.errors import ScdaError, ScdaErrorCode

BytesLike = Union[bytes, bytearray, memoryview]

#: Consecutive zero-progress pwrite/pwritev returns tolerated before the
#: backend gives up with FS_WRITE (a 0-byte return must never spin forever).
MAX_ZERO_PROGRESS = 8

#: Default readahead window for mode-'r' backends (bytes); env-overridable.
DEFAULT_READAHEAD = int(os.environ.get("REPRO_SCDA_READAHEAD", str(64 << 10)))

_HAS_PWRITEV = hasattr(os, "pwritev")
try:
    _IOV_MAX = os.sysconf("SC_IOV_MAX")
    if _IOV_MAX <= 0:
        _IOV_MAX = 1024
except (AttributeError, ValueError, OSError):  # pragma: no cover
    _IOV_MAX = 1024

#: Consecutive fragments at or below this size are concatenated in user
#: space before the vectored write: copying a few KB costs less than the
#: kernel's per-iovec-segment processing, while big payload views are
#: always passed through zero-copy.
_JOIN_SMALL = 8 << 10


def as_byte_view(data: BytesLike) -> memoryview:
    """Normalize any buffer to a flat uint8 memoryview (zero-copy)."""
    v = memoryview(data)
    return v if v.format == "B" and v.ndim == 1 else v.cast("B")


_as_view = as_byte_view


class FileBackend:
    """One rank's positioned-I/O handle on the shared file."""

    def __init__(self, path: str, mode: str, create: bool,
                 readahead: Optional[int] = None) -> None:
        self.path = path
        self.mode = mode
        flags = os.O_RDONLY
        if mode == "w":
            # fopen('w') semantics (§A.3): create new or truncate existing.
            flags = os.O_RDWR | os.O_CREAT
            if create:
                flags |= os.O_TRUNC
        try:
            self.fd = os.open(path, flags, 0o644)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_OPEN, f"{path}: {e}") from e
        # Readahead only makes sense for mode 'r': the file is immutable
        # while a reader holds it, so a stale-cache hazard cannot arise.
        self._readahead = (DEFAULT_READAHEAD if readahead is None
                           else readahead) if mode == "r" else 0
        self._cache: bytes = b""
        self._cache_off = 0

    # -- writes ---------------------------------------------------------------
    def pwrite(self, offset: int, data: BytesLike) -> None:
        view = _as_view(data)
        written, stalls = 0, 0
        while written < len(view):
            try:
                n = os.pwrite(self.fd, view[written:], offset + written)
            except OSError as e:
                raise ScdaError(ScdaErrorCode.FS_WRITE,
                                f"{self.path}@{offset}: {e}") from e
            if n == 0:
                stalls += 1
                if stalls >= MAX_ZERO_PROGRESS:
                    raise ScdaError(
                        ScdaErrorCode.FS_WRITE,
                        f"{self.path}@{offset + written}: no write progress "
                        f"after {stalls} attempts")
            else:
                stalls = 0
            written += n

    def pwritev(self, offset: int, buffers: Sequence[BytesLike]) -> None:
        """Write ``buffers`` contiguously at ``offset`` in as few syscalls
        as possible, without concatenating them in user space."""
        views: List[memoryview] = []
        small: List[memoryview] = []
        for b in buffers:
            v = _as_view(b)
            if not len(v):
                continue
            if len(v) <= _JOIN_SMALL:
                small.append(v)
                continue
            if small:  # join the run of small fragments, keep v zero-copy
                views.append(small[0] if len(small) == 1
                             else memoryview(b"".join(small)))
                small = []
            views.append(v)
        if small:
            views.append(small[0] if len(small) == 1
                         else memoryview(b"".join(small)))
        if not views:
            return
        if len(views) == 1 or not _HAS_PWRITEV:
            for v in views:
                self.pwrite(offset, v)
                offset += len(v)
            return
        i, stalls = 0, 0
        while i < len(views):
            batch = views[i:i + _IOV_MAX]
            try:
                n = os.pwritev(self.fd, batch, offset)
            except OSError as e:
                raise ScdaError(ScdaErrorCode.FS_WRITE,
                                f"{self.path}@{offset}: {e}") from e
            if n == 0:
                stalls += 1
                if stalls >= MAX_ZERO_PROGRESS:
                    raise ScdaError(
                        ScdaErrorCode.FS_WRITE,
                        f"{self.path}@{offset}: no write progress after "
                        f"{stalls} attempts")
                continue
            stalls = 0
            offset += n
            # Consume n bytes of the iovec list (partial writes resume
            # mid-buffer on the next iteration).
            while i < len(views) and n >= len(views[i]):
                n -= len(views[i])
                i += 1
            if i < len(views) and n:
                views[i] = views[i][n:]

    def write_gather(self,
                     frags: Iterable[Tuple[int, BytesLike]]) -> None:
        """Write ``(offset, buffer)`` fragments, coalescing adjacent runs.

        Fragments must arrive in non-decreasing offset order; each maximal
        contiguous run becomes a single vectored write.  Zero-length
        buffers are skipped.  Buffers must be bytes-like with ``len()`` in
        bytes (i.e. flat uint8 views — what the writer produces).
        """
        run_off = 0
        run_end = None
        bufs: List[BytesLike] = []
        for off, buf in frags:
            length = len(buf)
            if length == 0:
                continue
            if run_end is not None and off != run_end:
                self.pwritev(run_off, bufs)
                bufs = []
                run_end = None
            if run_end is None:
                run_off = run_end = off
            bufs.append(buf)
            run_end += length
        if bufs:
            self.pwritev(run_off, bufs)

    # -- reads ----------------------------------------------------------------
    def pread(self, offset: int, n: int) -> bytes:
        if n <= 0:
            return b""
        ra = self._readahead
        if ra and n <= ra:
            lo, cache = self._cache_off, self._cache
            if lo <= offset and offset + n <= lo + len(cache):
                i = offset - lo
                return cache[i:i + n]
            cache = self._pread_upto(offset, ra)
            self._cache_off, self._cache = offset, cache
            if len(cache) < n:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_TRUNCATED,
                    f"{self.path}: EOF at {offset + len(cache)}, wanted {n}")
            return cache[:n]
        return self._pread_exact(offset, n)

    def _pread_exact(self, offset: int, n: int) -> bytes:
        out = self._pread_upto(offset, n)
        if len(out) < n:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_TRUNCATED,
                f"{self.path}: EOF at {offset + len(out)}, wanted {n}")
        return out

    def _pread_upto(self, offset: int, n: int) -> bytes:
        """Read up to ``n`` bytes; short only at end of file."""
        try:
            chunks = []
            got = 0
            while got < n:
                chunk = os.pread(self.fd, n - got, offset + got)
                if not chunk:
                    break
                chunks.append(chunk)
                got += len(chunk)
            if len(chunks) == 1:
                return chunks[0]
            return b"".join(chunks)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_READ,
                            f"{self.path}@{offset}: {e}") from e

    # -- access-pattern hints -------------------------------------------------
    _ADVICE = {}
    if hasattr(os, "posix_fadvise"):  # pragma: no branch - platform constant
        _ADVICE = {
            "willneed": os.POSIX_FADV_WILLNEED,
            "sequential": os.POSIX_FADV_SEQUENTIAL,
            "random": os.POSIX_FADV_RANDOM,
            "dontneed": os.POSIX_FADV_DONTNEED,
        }

    def advise(self, offset: int, length: int, advice: str) -> None:
        """Advisory readahead hint (``posix_fadvise``); silently a no-op
        where the platform lacks it or the kernel declines.

        The index layer issues ``sequential`` for its one header-only scan
        and ``willneed`` for the extent of a section about to be read after
        a seek — random access should not pay sequential-readahead
        misprediction on a parallel file system.
        """
        fadv = self._ADVICE.get(advice)
        if fadv is None or self.fd < 0:
            return
        try:
            os.posix_fadvise(self.fd, offset, max(0, length), fadv)
        except OSError:  # advisory only — never an scda error
            pass

    # -- metadata / lifecycle -------------------------------------------------
    def size(self) -> int:
        try:
            return os.fstat(self.fd).st_size
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_READ, str(e)) from e

    def truncate(self, n: int) -> None:
        try:
            os.ftruncate(self.fd, n)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_WRITE, str(e)) from e

    def fsync(self) -> None:
        try:
            os.fsync(self.fd)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_WRITE, str(e)) from e

    def close(self, sync: bool = False) -> None:
        if self.fd < 0:
            return
        try:
            if sync:
                os.fsync(self.fd)
            os.close(self.fd)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_CLOSE, str(e)) from e
        finally:
            self.fd = -1
            self._cache = b""
