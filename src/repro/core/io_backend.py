"""Positioned file I/O — the MPI-IO role (``MPI_File_write_at``) in scda.

Every rank holds its own descriptor onto the shared file and performs
positioned reads/writes at offsets computed *deterministically* from
collective section parameters.  No rank ever seeks relative to another —
that independence is what makes the write path scale and the bytes
partition-independent.

On a parallel file system (Lustre, GPFS) this maps 1:1 to MPI-IO or
per-node POSIX pwrite; on this container it is plain POSIX.  File-system
errors are translated to the paper's group-2 error codes.
"""
from __future__ import annotations

import os
from typing import Union

from repro.core.errors import ScdaError, ScdaErrorCode

BytesLike = Union[bytes, bytearray, memoryview]


class FileBackend:
    """One rank's positioned-I/O handle on the shared file."""

    def __init__(self, path: str, mode: str, create: bool) -> None:
        self.path = path
        self.mode = mode
        flags = os.O_RDONLY
        if mode == "w":
            # fopen('w') semantics (§A.3): create new or truncate existing.
            flags = os.O_RDWR | os.O_CREAT
            if create:
                flags |= os.O_TRUNC
        try:
            self.fd = os.open(path, flags, 0o644)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_OPEN, f"{path}: {e}") from e

    def pwrite(self, offset: int, data: BytesLike) -> None:
        try:
            view = memoryview(data)
            written = 0
            while written < len(view):
                written += os.pwrite(self.fd, view[written:], offset + written)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_WRITE,
                            f"{self.path}@{offset}: {e}") from e

    def pread(self, offset: int, n: int) -> bytes:
        try:
            chunks = []
            got = 0
            while got < n:
                chunk = os.pread(self.fd, n - got, offset + got)
                if not chunk:
                    raise ScdaError(
                        ScdaErrorCode.CORRUPT_TRUNCATED,
                        f"{self.path}: EOF at {offset + got}, wanted {n}")
                chunks.append(chunk)
                got += len(chunk)
            return b"".join(chunks)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_READ,
                            f"{self.path}@{offset}: {e}") from e

    def size(self) -> int:
        try:
            return os.fstat(self.fd).st_size
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_READ, str(e)) from e

    def truncate(self, n: int) -> None:
        try:
            os.ftruncate(self.fd, n)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_WRITE, str(e)) from e

    def fsync(self) -> None:
        try:
            os.fsync(self.fd)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_WRITE, str(e)) from e

    def close(self, sync: bool = False) -> None:
        if self.fd < 0:
            return
        try:
            if sync:
                os.fsync(self.fd)
            os.close(self.fd)
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_CLOSE, str(e)) from e
        finally:
            self.fd = -1
