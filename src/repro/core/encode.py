"""Serial (in-memory) section encoders — the byte oracle for the format.

These functions produce the complete on-disk bytes of each section from the
*global* data.  They define serial-equivalence: the parallel writer must
produce byte-identical output for any partition.  Tests compare the parallel
writer against these oracles, and the parallel writer itself reuses them for
rank-0-owned metadata.

Each section also has an ``iov_*`` variant returning the section as a
scatter-gather list (iovec) of buffers in file order, with payload buffers
passed through by reference — zero copies.  ``encode_* = join(iov_*)``.
The parallel writer hands ``iov_inline``/``iov_block`` fragment lists
straight to ``FileBackend.pwritev`` for its root-owned sections (one
syscall, payload never concatenated); for the partitioned A/V sections it
assembles per-rank ``(offset, buffer)`` fragments from the same spec
primitives, since each rank owns only a slice of the section.  Varray
count entries are generated vectorized
(:func:`repro.core.spec.count_entries`) instead of one Python call per
element.
"""
from __future__ import annotations

from typing import List, Sequence

from repro.core import spec
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.io_backend import BytesLike


def iov_inline(user_string: bytes, data: BytesLike,
               style: str = spec.UNIX) -> List[BytesLike]:
    """Inline section I (paper §2.3, Fig. 2): exactly 32 unpadded data bytes."""
    if len(data) != spec.INLINE_DATA_BYTES:
        raise ScdaError(ScdaErrorCode.ARG_INLINE_SIZE, f"{len(data)} bytes")
    return [spec.section_header(b"I", user_string, style), data]


def iov_block(user_string: bytes, data: BytesLike,
              style: str = spec.UNIX) -> List[BytesLike]:
    """Block section B (paper §2.4, Fig. 3)."""
    E = len(data)
    last = memoryview(data)[-1] if E else None
    return [spec.section_header(b"B", user_string, style),
            spec.count_entry(b"E", E, style),
            data,
            spec.pad_data(E, last, style)]


def iov_array(user_string: bytes, data: BytesLike, N: int, E: int,
              style: str = spec.UNIX) -> List[BytesLike]:
    """Fixed-size array section A (paper §2.5, Fig. 4)."""
    if len(data) != N * E:
        raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                        f"{len(data)} bytes != N*E = {N * E}")
    n = N * E
    last = memoryview(data)[-1] if n else None
    return [spec.section_header(b"A", user_string, style),
            spec.count_entry(b"N", N, style),
            spec.count_entry(b"E", E, style),
            data,
            spec.pad_data(n, last, style)]


def iov_varray(user_string: bytes, elements: Sequence[BytesLike],
               style: str = spec.UNIX) -> List[BytesLike]:
    """Variable-size array section V (paper §2.6, Fig. 5).

    The N per-element 'E' entries are emitted as ONE buffer (vectorized
    generation); element payloads are passed through by reference.
    """
    N = len(elements)
    sizes = list(map(len, elements))
    parts: List[BytesLike] = [spec.section_header(b"V", user_string, style),
                              spec.count_entry(b"N", N, style),
                              spec.count_entries(b"E", sizes, style,
                                                 trusted_ints=True)]
    payload = list(filter(len, elements))
    parts += payload
    last = memoryview(payload[-1])[-1] if payload else None
    parts.append(spec.pad_data(sum(sizes), last, style))
    return parts


def _join(parts: Sequence[BytesLike]) -> bytes:
    return b"".join(parts)  # bytes.join accepts any buffer objects


def encode_inline(user_string: bytes, data: bytes,
                  style: str = spec.UNIX) -> bytes:
    out = _join(iov_inline(user_string, data, style))
    assert len(out) == spec.INLINE_SECTION_BYTES
    return out


def encode_block(user_string: bytes, data: bytes,
                 style: str = spec.UNIX) -> bytes:
    out = _join(iov_block(user_string, data, style))
    assert len(out) == spec.block_section_bytes(len(data))
    return out


def encode_array(user_string: bytes, data: bytes, N: int, E: int,
                 style: str = spec.UNIX) -> bytes:
    out = _join(iov_array(user_string, data, N, E, style))
    assert len(out) == spec.array_section_bytes(N, E)
    return out


def encode_varray(user_string: bytes, elements: Sequence[bytes],
                  style: str = spec.UNIX) -> bytes:
    out = _join(iov_varray(user_string, elements, style))
    assert len(out) == spec.varray_section_bytes(
        len(elements), sum(map(len, elements)))
    return out


def encode_file(vendor: bytes, user_string: bytes, sections: Sequence[bytes],
                style: str = spec.UNIX) -> bytes:
    """A complete file: header F followed by pre-encoded sections, no gaps."""
    return spec.file_header(vendor, user_string, style) + b"".join(sections)
