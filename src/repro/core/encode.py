"""Serial (in-memory) section encoders — the byte oracle for the format.

These functions produce the complete on-disk bytes of each section from the
*global* data.  They define serial-equivalence: the parallel writer must
produce byte-identical output for any partition.  Tests compare the parallel
writer against these oracles, and the parallel writer itself reuses them for
rank-0-owned metadata.
"""
from __future__ import annotations

from typing import Sequence

from repro.core import spec
from repro.core.errors import ScdaError, ScdaErrorCode


def encode_inline(user_string: bytes, data: bytes, style: str = spec.UNIX) -> bytes:
    """Inline section I (paper §2.3, Fig. 2): exactly 32 unpadded data bytes."""
    if len(data) != spec.INLINE_DATA_BYTES:
        raise ScdaError(ScdaErrorCode.ARG_INLINE_SIZE, f"{len(data)} bytes")
    out = spec.section_header(b"I", user_string, style) + data
    assert len(out) == spec.INLINE_SECTION_BYTES
    return out


def encode_block(user_string: bytes, data: bytes, style: str = spec.UNIX) -> bytes:
    """Block section B (paper §2.4, Fig. 3)."""
    E = len(data)
    out = (spec.section_header(b"B", user_string, style)
           + spec.count_entry(b"E", E, style)
           + data
           + spec.pad_data(E, data[-1] if E else None, style))
    assert len(out) == spec.block_section_bytes(E)
    return out


def encode_array(user_string: bytes, data: bytes, N: int, E: int,
                 style: str = spec.UNIX) -> bytes:
    """Fixed-size array section A (paper §2.5, Fig. 4)."""
    if len(data) != N * E:
        raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                        f"{len(data)} bytes != N*E = {N * E}")
    n = N * E
    out = (spec.section_header(b"A", user_string, style)
           + spec.count_entry(b"N", N, style)
           + spec.count_entry(b"E", E, style)
           + data
           + spec.pad_data(n, data[-1] if n else None, style))
    assert len(out) == spec.array_section_bytes(N, E)
    return out


def encode_varray(user_string: bytes, elements: Sequence[bytes],
                  style: str = spec.UNIX) -> bytes:
    """Variable-size array section V (paper §2.6, Fig. 5)."""
    N = len(elements)
    sizes = [len(e) for e in elements]
    data = b"".join(elements)
    n = len(data)
    parts = [spec.section_header(b"V", user_string, style),
             spec.count_entry(b"N", N, style)]
    parts += [spec.count_entry(b"E", s, style) for s in sizes]
    parts.append(data)
    parts.append(spec.pad_data(n, data[-1] if n else None, style))
    out = b"".join(parts)
    assert len(out) == spec.varray_section_bytes(N, n)
    return out


def encode_file(vendor: bytes, user_string: bytes, sections: Sequence[bytes],
                style: str = spec.UNIX) -> bytes:
    """A complete file: header F followed by pre-encoded sections, no gaps."""
    return spec.file_header(vendor, user_string, style) + b"".join(sections)
