"""Seekable section index — the random-access layer over the scda stream.

The paper motivates scda for "generic and flexible archival and
checkpoint/restart" with selective access (§1), but the on-disk stream has
no record table: locating section i requires walking all i-1 predecessors.
:class:`ScdaIndex` is that record table, produced by ONE header-only scan
(no payload bytes are touched; varray extents come from the count-entry
tables).  With it, :meth:`repro.core.reader.ScdaReader.seek_section` jumps
any rank straight to any section and the existing windowed/element reads
work unchanged — the format becomes an archive instead of a tape.

The index is cacheable as a ``.scdax`` sidecar which is itself a valid
scda file (an I section with a cheap staleness probe plus a §3.2-encoded
B section holding the entry table as JSON), so ``scdatool`` and foreign
readers can inspect it with the ordinary format tools.  A sidecar is never
trusted blindly: loading verifies the target's file size, and every seek
re-reads the section's on-disk 64-byte header and compares it against the
entry (see :meth:`ScdaReader.seek_section`), so a stale index can fail
loudly but can never return wrong bytes silently.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Dict, List, Optional, Tuple

from repro.core import codec, spec
from repro.core.comm import Communicator
from repro.core.errors import ScdaError, ScdaErrorCode

#: Sidecar naming convention: ``<file>.scdax`` next to ``<file>``.
SIDECAR_SUFFIX = ".scdax"
#: File-header user string identifying a sidecar.
SIDECAR_USER_STRING = b"scdax 00"
#: Section user strings inside the sidecar.
SIDECAR_TARGET_USER = b"scdax target"
SIDECAR_ENTRIES_USER = b"scdax entries"
#: Sidecar JSON schema version.
INDEX_FORMAT = "repro-scdax"
INDEX_VERSION = 1

#: kind → (on-disk letter of the section's FIRST physical header, fixed
#: user string for encoded kinds or None = the entry's own user string).
_RAW_HEADER: Dict[str, Tuple[bytes, Optional[bytes]]] = {
    "I": (b"I", None), "B": (b"B", None),
    "A": (b"A", None), "V": (b"V", None),
    "zB": (b"I", codec.MAGIC_BLOCK),
    "zA": (b"I", codec.MAGIC_ARRAY),
    "zV": (b"A", codec.MAGIC_VARRAY),
}

_ENTRY_FIELDS = ("kind", "N", "E", "decoded", "start", "end", "data_start",
                 "entries_start", "v_entries_start", "v_data_start",
                 "raw_E", "payload_bytes")


@dataclasses.dataclass(frozen=True)
class IndexEntry:
    """One logical section's type, geometry, and absolute file offsets.

    A §3-encoded section (kind ``zB``/``zA``/``zV``) spans two physical
    sections on disk but is ONE logical entry here, mirroring what
    ``read_section_header(decode=True)`` reports.  ``payload_bytes`` is the
    on-disk data byte count (compressed size for encoded kinds); logical
    sizes live in ``N``/``E`` exactly as in :class:`SectionHeader`.
    """
    kind: str            # 'I'|'B'|'A'|'V'|'zB'|'zA'|'zV' (physical layout)
    type: str            # logical type letter, as SectionHeader.type
    user_string: bytes
    N: int
    E: int
    decoded: bool
    start: int           # absolute offset of the (first) section header
    end: int             # absolute offset just past the final pad byte
    data_start: int = 0
    entries_start: int = 0
    v_entries_start: int = 0
    v_data_start: int = 0
    raw_E: int = 0
    payload_bytes: int = 0
    #: CRC32 of the section's *decoded logical payload* (inline data,
    #: block/array data bytes, varray elements concatenated — after §3
    #: decoding for encoded kinds), recorded by ``scdatool index
    #: --checksums``.  None when never computed; excluded from equality
    #: so a checksummed sidecar still deep-verifies against a fresh
    #: (checksum-free) scan.  Re-encoding preserves it, exactly as
    #: ``scdatool diff`` compares logically.
    crc32: Optional[int] = dataclasses.field(default=None, compare=False)

    def header(self):
        from repro.core.reader import SectionHeader
        return SectionHeader(self.type, self.user_string, N=self.N,
                             E=self.E, decoded=self.decoded)

    def raw_header(self) -> Tuple[bytes, bytes]:
        """(letter, user string) of the on-disk header at ``start``."""
        letter, fixed_user = _RAW_HEADER[self.kind]
        return letter, self.user_string if fixed_user is None else fixed_user

    def to_pending(self):
        """The reader cursor state a forward walk would have produced."""
        from repro.core.reader import _Pending
        return _Pending(self.kind, self.header(),
                        data_start=self.data_start,
                        entries_start=self.entries_start,
                        v_entries_start=self.v_entries_start,
                        v_data_start=self.v_data_start,
                        raw_E=self.raw_E)


@dataclasses.dataclass
class ScdaIndex:
    """The complete section table of one scda file."""
    path: str
    file_size: int
    scda_version: int
    vendor: bytes
    user_string: bytes
    entries: List[IndexEntry]

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries)

    # -- construction ---------------------------------------------------------
    @classmethod
    def build(cls, source,
              comm: Optional[Communicator] = None) -> "ScdaIndex":
        """One header-only scan of ``source`` (a path or an open reader).

        Rank-local (every rank parses the identical bytes, §A.5.1's
        standard pattern), so no communicator is required; one may be
        passed for a collective open.
        """
        from repro.core.reader import ScdaReader, fopen_read
        if isinstance(source, ScdaReader):
            return cls._build_from(source)
        with fopen_read(comm, source) as r:
            return cls._build_from(r)

    @classmethod
    def _build_from(cls, r) -> "ScdaIndex":
        r._backend.advise(0, r._file_size, "sequential")
        r._pending = None
        r.cursor = spec.FILE_HEADER_BYTES
        return cls(path=r.path, file_size=r._file_size,
                   scda_version=r.version, vendor=r.vendor,
                   user_string=r.user_string, entries=cls._scan_entries(r))

    @classmethod
    def build_prefix(cls, source,
                     comm: Optional[Communicator] = None) -> "ScdaIndex":
        """Index the longest valid *section prefix* of a damaged archive.

        Like :meth:`build`, but a group-1 (corrupt-contents) error stops
        the scan at the last clean section boundary instead of raising —
        the salvage primitive behind tolerant restores and ``scdatool
        repair``.  The result's ``file_size`` is the prefix end, i.e. the
        exact truncation point that would make the file fsck-clean; a
        corrupt *file header* (no valid prefix at all) still raises, as
        do group-2 file-system errors.
        """
        from repro.core.reader import ScdaReader, fopen_read
        if isinstance(source, ScdaReader):
            return cls._build_prefix_from(source)
        with fopen_read(comm, source) as r:
            return cls._build_prefix_from(r)

    @classmethod
    def _build_prefix_from(cls, r) -> "ScdaIndex":
        r._backend.advise(0, r._file_size, "sequential")
        r._pending = None
        r.cursor = spec.FILE_HEADER_BYTES
        entries: List[IndexEntry] = []
        try:
            cls._scan_entries(r, out=entries)
        except ScdaError as e:
            if e.group != 1:
                raise
        end = entries[-1].end if entries else spec.FILE_HEADER_BYTES
        return cls(path=r.path, file_size=end,
                   scda_version=r.version, vendor=r.vendor,
                   user_string=r.user_string, entries=entries)

    @staticmethod
    def _scan_entries(r, out: Optional[List[IndexEntry]] = None
                      ) -> List[IndexEntry]:
        """Header-only walk from the reader's current cursor to EOF.

        With ``out`` the entries accumulate into the caller's list, so a
        scan that raises mid-file still leaves every section completed
        *before* the failure visible (the prefix-salvage path).
        """
        entries: List[IndexEntry] = [] if out is None else out
        while not r.at_eof:
            start = r.cursor
            hdr = r.read_section_header(decode=True)
            p = r._pending
            r.skip_data()  # records p.total_bytes, advances the cursor
            entries.append(IndexEntry(
                kind=p.kind, type=hdr.type, user_string=hdr.user_string,
                N=hdr.N, E=hdr.E, decoded=hdr.decoded,
                start=start, end=r.cursor,
                data_start=p.data_start, entries_start=p.entries_start,
                v_entries_start=p.v_entries_start,
                v_data_start=p.v_data_start, raw_E=p.raw_E,
                payload_bytes=p.total_bytes or 0))
        return entries

    # -- incremental refresh (the mode-'a' append path) -----------------------
    def staleness(self) -> str:
        """Cheap size-probe classification of this index vs. the file now.

        ``"fresh"`` — sizes match (per-seek header checks still guard
        same-size rewrites, as always); ``"grew"`` — the file gained
        bytes, so :meth:`extend` can scan just the appended suffix;
        ``"rewritten"`` — the file shrank or vanished, only a full
        rebuild can describe it.
        """
        try:
            size = os.stat(self.path).st_size
        except OSError:
            return "rewritten"
        if size == self.file_size:
            return "fresh"
        return "grew" if size > self.file_size else "rewritten"

    def extend(self, source=None) -> "ScdaIndex":
        """Refresh this index against the file as it stands now.

        The incremental mirror of :meth:`build` for appendable archives:
        a file that merely *grew* (mode-'a' appends, journal flushes) is
        scanned only over the appended suffix — the existing entries
        describe bytes that did not move — after re-verifying the last
        indexed section's on-disk header, so a rewrite that happens to be
        larger can never smuggle stale offsets through.  A shrunk,
        rewritten, or header-changed file falls back to a full rebuild.
        Returns ``self`` unchanged when the file did not change size, a
        new :class:`ScdaIndex` otherwise; existing entries (checksums
        included) are preserved across a suffix scan.  Raises the
        reader's CORRUPT_* errors if the appended suffix is invalid.
        """
        from repro.core.reader import ScdaReader, fopen_read
        if source is None or not isinstance(source, ScdaReader):
            with fopen_read(None, source or self.path) as r:
                return self._extend_from(r)
        return self._extend_from(source)

    def _extend_from(self, r) -> "ScdaIndex":
        if (r._file_size < self.file_size
                or r.version != self.scda_version
                or r.vendor != self.vendor
                or r.user_string != self.user_string):
            return ScdaIndex._build_from(r)
        if self.entries:
            last = self.entries[-1]
            try:
                r.verify_index_entry(len(self.entries) - 1, last)
            except ScdaError:
                return ScdaIndex._build_from(r)
        if r._file_size == self.file_size:
            return self
        r._pending = None
        r.cursor = self.entries[-1].end if self.entries \
            else spec.FILE_HEADER_BYTES
        suffix = self._scan_entries(r)
        out = dataclasses.replace(self, file_size=r._file_size,
                                  entries=self.entries + suffix)
        return out

    # -- lookup ---------------------------------------------------------------
    def find(self, user_string: bytes, occurrence: int = 0) -> int:
        """Index of the ``occurrence``-th section with ``user_string``, or -1.

        O(1) after the first call: a user-string table is built lazily so
        per-leaf lookups during a lazy restore stay O(leaves), not
        O(leaves × sections).
        """
        by = getattr(self, "_by_user", None)
        if by is None:
            by = {}
            for i, e in enumerate(self.entries):
                by.setdefault(e.user_string, []).append(i)
            self._by_user = by
        hits = by.get(user_string, ())
        return hits[occurrence] if 0 <= occurrence < len(hits) else -1

    # -- verification ---------------------------------------------------------
    def verify(self, deep: bool = False) -> None:
        """Check this index still describes the file at ``path``.

        Shallow (default): the target's size must match — any append,
        truncation, or rewrite-through-rename changes it in practice, and
        per-seek header re-reads catch same-size rewrites.  ``deep``
        rebuilds the index from the file and requires identical entries.
        Raises :class:`ScdaError` (CORRUPT group) on any mismatch.
        """
        try:
            size = os.stat(self.path).st_size
        except OSError as e:
            raise ScdaError(ScdaErrorCode.FS_READ,
                            f"{self.path}: {e}") from e
        if size != self.file_size:
            how = "grew (extend can re-scan the suffix)" if \
                size > self.file_size else "was rewritten or truncated"
            raise ScdaError(ScdaErrorCode.CORRUPT_TRUNCATED,
                            f"stale index: file is {size} bytes, index "
                            f"recorded {self.file_size} — the file {how}")
        if deep:
            fresh = ScdaIndex.build(self.path)
            if fresh.entries != self.entries:
                raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                                "stale index: section table does not match "
                                "a fresh scan")

    # -- payload checksums (the verify-without-a-reference manifest) ----------
    @staticmethod
    def _section_crc(r, i: int) -> int:
        """CRC32 of section ``i``'s decoded logical payload.

        Raw A sections stream through windowed reads, so a terabyte raw
        leaf checksums in bounded memory; encoded kinds (zA/V/zV) run
        the full decode chain — a checksum match therefore also proves
        the §3 framing, base64 geometry, and zlib adler32 of every
        payload byte it covers — at the cost of materializing each
        section's decoded elements while it is checksummed.
        """
        hdr = r.seek_section(i)
        crc = 0
        if hdr.type == "I":
            crc = zlib.crc32(r.read_inline_data())
        elif hdr.type == "B":
            crc = zlib.crc32(r.read_block_data())
        elif hdr.type == "A" and not hdr.decoded:
            # Raw A sections can be huge (checkpoint leaves are E=1 with
            # N in the millions); windowed reads keep memory bounded
            # instead of materializing one buffer per element.
            step = max(1, (1 << 20) // max(1, hdr.E))
            for start in range(0, hdr.N, step):
                n = min(step, hdr.N - start)
                crc = zlib.crc32(r.read_array_windows([(start, n)],
                                                      hdr.E)[0], crc)
            r.skip_data()
        elif hdr.type == "A":
            for chunk in r.read_array_data([hdr.N]):
                crc = zlib.crc32(chunk, crc)
        else:  # V
            sizes = r.read_varray_sizes([hdr.N])
            for chunk in r.read_varray_data([hdr.N], sizes):
                crc = zlib.crc32(chunk, crc)
        return crc

    def with_checksums(self, reader=None,
                       only_missing: bool = False) -> "ScdaIndex":
        """A copy of this index with every entry's ``crc32`` computed.

        ``scdatool index --checksums`` writes the result as the sidecar:
        a checksum manifest that lets ``scdatool verify`` validate the
        archive later without a reference copy (ROADMAP open item).

        ``only_missing`` re-checksums nothing that already has a CRC —
        after :meth:`extend` only the appended sections lack one, so an
        incremental sidecar refresh costs one decode pass over the
        *suffix*, not the archive.
        """
        from repro.core.reader import fopen_read
        if reader is None:
            with fopen_read(None, self.path) as r:
                return self.with_checksums(r, only_missing=only_missing)
        reader.set_index(self)
        entries = [e if only_missing and e.crc32 is not None
                   else dataclasses.replace(
                       e, crc32=self._section_crc(reader, i))
                   for i, e in enumerate(self.entries)]
        return dataclasses.replace(self, entries=entries)

    def has_checksums(self) -> bool:
        """True when every entry carries a recorded payload ``crc32`` —
        the precondition for ``scdatool verify`` to fully cover a file."""
        return all(e.crc32 is not None for e in self.entries)

    def verify_checksums(self, reader=None) -> List[str]:
        """Re-read every payload and compare against the recorded CRCs.

        Returns a list of human-readable problems (empty = verified).
        Entries without a recorded ``crc32`` are reported — an archive
        "verifies" only if every section is actually covered.  Decode
        failures (corrupt §3 framing, truncation) are reported per
        section rather than raised, so one rotten leaf doesn't hide the
        state of the rest.
        """
        from repro.core.reader import fopen_read
        if reader is None:
            with fopen_read(None, self.path) as r:
                return self.verify_checksums(r)
        problems: List[str] = []
        reader.set_index(self)
        for i, e in enumerate(self.entries):
            name = e.user_string.decode("latin-1")
            if e.crc32 is None:
                problems.append(f"section {i} ({name!r}): no checksum "
                                f"recorded (re-run scdatool index "
                                f"--checksums)")
                continue
            try:
                got = self._section_crc(reader, i)
            except ScdaError as err:
                problems.append(f"section {i} ({name!r}): unreadable: "
                                f"{err}")
                continue
            if got != e.crc32:
                problems.append(f"section {i} ({name!r}): payload CRC32 "
                                f"{got:#010x} != recorded {e.crc32:#010x}")
        return problems

    def check_checksums(self, reader=None) -> None:
        """Like :meth:`verify_checksums`, but raising — the
        verify-on-restore path (``restore(..., verify=True)``).

        The first mismatch raises CORRUPT_CHECKSUM carrying the exact
        starting byte offset of the failing section's payload
        (``ScdaError.offset``); a section without a recorded CRC raises
        ARG_SEQUENCE pointing at ``scdatool index --checksums``, since a
        "verified" restore that silently skipped sections would be a
        lie.
        """
        from repro.core.reader import fopen_read
        if reader is None:
            with fopen_read(None, self.path) as r:
                self.check_checksums(r)
                return
        reader.set_index(self)
        for i, e in enumerate(self.entries):
            name = e.user_string.decode("latin-1")
            if e.crc32 is None:
                raise ScdaError(
                    ScdaErrorCode.ARG_SEQUENCE,
                    f"{self.path}: section {i} ({name!r}) has no "
                    f"recorded checksum — run scdatool index "
                    f"--checksums first")
            got = self._section_crc(reader, i)
            if got != e.crc32:
                raise ScdaError(
                    ScdaErrorCode.CORRUPT_CHECKSUM,
                    f"{self.path}: section {i} ({name!r}): payload "
                    f"CRC32 {got:#010x} != recorded {e.crc32:#010x}",
                    offset=e.data_start)

    # -- sidecar (.scdax — itself a valid scda file) --------------------------
    def sidecar_path(self, sidecar: Optional[str] = None) -> str:
        return sidecar or self.path + SIDECAR_SUFFIX

    def _target_probe(self) -> bytes:
        text = f"size {self.file_size:>25}\n"
        return text.encode("ascii").ljust(spec.INLINE_DATA_BYTES)[
            :spec.INLINE_DATA_BYTES]

    def to_json(self) -> bytes:
        doc = {
            "format": INDEX_FORMAT,
            "version": INDEX_VERSION,
            "target": {
                "size": self.file_size,
                "scda_version": self.scda_version,
                "vendor": self.vendor.decode("latin-1"),
                "user_string": self.user_string.decode("latin-1"),
            },
            "sections": [
                {"type": e.type,
                 "user_string": e.user_string.decode("latin-1"),
                 **{f: getattr(e, f) for f in _ENTRY_FIELDS},
                 # backward-compatible extra key: absent when not computed,
                 # ignored by readers that predate it
                 **({"crc32": e.crc32} if e.crc32 is not None else {})}
                for e in self.entries
            ],
        }
        return json.dumps(doc, indent=1, sort_keys=True).encode("ascii")

    @classmethod
    def from_json(cls, raw: bytes, path: str) -> "ScdaIndex":
        try:
            doc = json.loads(raw.decode("ascii"))
        except (ValueError, UnicodeDecodeError) as e:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"sidecar JSON: {e}") from e
        if doc.get("format") != INDEX_FORMAT:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"not a scdax document: {doc.get('format')!r}")
        if doc.get("version") != INDEX_VERSION:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"unsupported scdax version {doc.get('version')}")
        try:
            t = doc["target"]
            entries = [
                IndexEntry(type=s["type"],
                           user_string=s["user_string"].encode("latin-1"),
                           crc32=s.get("crc32"),
                           **{f: s[f] for f in _ENTRY_FIELDS})
                for s in doc["sections"]
            ]
            return cls(path=path, file_size=int(t["size"]),
                       scda_version=int(t["scda_version"]),
                       vendor=t["vendor"].encode("latin-1"),
                       user_string=t["user_string"].encode("latin-1"),
                       entries=entries)
        except (KeyError, TypeError, ValueError) as e:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"malformed scdax document: {e}") from e

    def write_sidecar(self, sidecar: Optional[str] = None) -> str:
        """Atomically write the ``.scdax`` sidecar; returns its path."""
        from repro.core.writer import fopen_write
        sp = self.sidecar_path(sidecar)
        tmp = sp + ".tmp"
        with fopen_write(None, tmp, user_string=SIDECAR_USER_STRING,
                         sync=True) as f:
            f.write_inline(SIDECAR_TARGET_USER, self._target_probe())
            f.write_block(SIDECAR_ENTRIES_USER, self.to_json(), encode=True)
        # Durable rename: a stale sidecar is only *detected* (staleness
        # probe) — a resurrected half-renamed one must never be possible.
        from repro.core.io_backend import replace_durable
        replace_durable(tmp, sp)
        return sp

    @classmethod
    def write_sidecars(cls, paths: List[str],
                       comm: Optional[Communicator] = None,
                       strict: bool = False) -> List[str]:
        """Build and atomically write sidecars for several related
        archives — a sharded checkpoint commits its N shard files and
        manifest together, and wants all their indexes refreshed as one
        post-commit step.  Best-effort by default (an unwritable
        directory or a torn file skips that sidecar and moves on, like
        the manager's post-commit behavior); ``strict`` re-raises
        instead.  Returns the sidecar paths actually written."""
        written: List[str] = []
        for p in paths:
            try:
                written.append(cls.build(p, comm).write_sidecar())
            except (ScdaError, OSError):
                if strict:
                    raise
        return written

    @classmethod
    def load_sidecar(cls, path: str, sidecar: Optional[str] = None,
                     verify: bool = True) -> "ScdaIndex":
        """Load ``<path>.scdax`` and (by default) verify it against the file."""
        from repro.core.reader import fopen_read
        sp = sidecar or path + SIDECAR_SUFFIX
        with fopen_read(None, sp) as r:
            if r.user_string != SIDECAR_USER_STRING:
                raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                                f"{sp}: not a scdax sidecar "
                                f"({r.user_string!r})")
            hdr = r.read_section_header()
            if hdr.type != "I" or hdr.user_string != SIDECAR_TARGET_USER:
                raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                                f"{sp}: missing target probe section")
            r.read_inline_data()
            hdr = r.read_section_header()
            if hdr.type != "B" or hdr.user_string != SIDECAR_ENTRIES_USER:
                raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                                f"{sp}: missing entries section")
            idx = cls.from_json(r.read_block_data(), path)
        if verify:
            idx.verify()
        return idx

    @classmethod
    def refresh_sidecar(cls, path: str, sidecar: Optional[str] = None,
                        checksums: Optional[bool] = None) \
            -> Optional["ScdaIndex"]:
        """Incrementally refresh ``path``'s sidecar after an append.

        Returns the refreshed index, or None when no sidecar exists (an
        archive that never had one keeps not having one — readers scan).
        A sidecar stale because the file *grew* is extended by a suffix
        scan; a rewritten file gets a full rebuild; the replacement write
        is atomic (temp + rename), so concurrent readers only ever see a
        complete sidecar.  ``checksums=None`` preserves the manifest
        property: if the old sidecar recorded payload CRCs, the appended
        sections are checksummed too (suffix-only decode pass), so
        ``scdatool verify`` keeps covering the whole file.
        """
        sp = sidecar or path + SIDECAR_SUFFIX
        if not os.path.exists(sp):
            return None
        old = cls.load_sidecar(path, sidecar, verify=False)
        idx = old.extend()
        want_crcs = checksums if checksums is not None \
            else (bool(old.entries) and old.has_checksums())
        if want_crcs and not idx.has_checksums():
            idx = idx.with_checksums(only_missing=True)
        idx.write_sidecar(sidecar)
        return idx

    @classmethod
    def cached(cls, path: str, comm: Optional[Communicator] = None,
               write: bool = True,
               sidecar: Optional[str] = None) -> "ScdaIndex":
        """The standard entry point: sidecar if fresh, else scan (and cache).

        A sidecar stale only because the file grew (mode-'a' appends) is
        extended with a suffix-only scan; a missing, rewritten, or
        corrupt sidecar falls back to a fresh header-only scan.  With
        ``write``, rank 0 then refreshes the sidecar best-effort (an
        unwritable directory never fails the read path).
        """
        try:
            return cls.load_sidecar(path, sidecar)
        except (ScdaError, OSError):
            pass
        idx = None
        try:
            # Suffix-scan fast path for grown files; extend() degrades to
            # a full rebuild for rewritten ones all by itself.
            idx = cls.load_sidecar(path, sidecar, verify=False).extend()
        except (ScdaError, OSError):
            idx = None
        if idx is None:
            idx = cls.build(path)
        if write and (comm is None or comm.rank == 0):
            try:
                idx.write_sidecar(sidecar)
            except (ScdaError, OSError):
                pass
        return idx
