"""Error taxonomy for the scda format library (paper §A.6).

The paper mandates that file errors never crash a simulation: every API call
reports an error code that the caller can react to.  In Python we raise
:class:`ScdaError` carrying an :class:`ScdaErrorCode`; the training loop
catches these and keeps running (fault tolerance).  ``ferror_string`` mirrors
``scda_ferror_string`` for code→string translation.

Three groups of checked runtime errors (paper §A.6):
  (1) corrupt file contents,
  (2) file system errors,
  (3) semantically invalid input parameters or call sequence.
"""
from __future__ import annotations

import enum
import errno as _errno


class ScdaErrorCode(enum.IntEnum):
    SUCCESS = 0

    # -- group 1: corrupt file contents ------------------------------------
    CORRUPT_MAGIC = 101          # bad magic bytes / unsupported version
    CORRUPT_PADDING = 102        # '-' or '=' padding malformed
    CORRUPT_COUNT = 103          # count entry not a valid decimal
    CORRUPT_SECTION_TYPE = 104   # section letter not in {I,B,A,V}
    CORRUPT_TRUNCATED = 105      # file ends mid-section
    CORRUPT_ENCODING = 106       # §3 compression convention violated
    CORRUPT_CHECKSUM = 107       # adler32 / size mismatch on inflate

    # -- group 2: file system errors ----------------------------------------
    FS_OPEN = 201
    FS_READ = 202
    FS_WRITE = 203
    FS_CLOSE = 204

    # -- group 3: invalid parameters / call sequence ------------------------
    ARG_USER_STRING = 301        # user string exceeds 58 bytes
    ARG_VENDOR_STRING = 302      # vendor string exceeds 20 bytes
    ARG_COUNT_RANGE = 303        # count negative or > 26 decimal digits
    ARG_INLINE_SIZE = 304        # inline data not exactly 32 bytes
    ARG_PARTITION = 305          # partition counts inconsistent / non-collective
    ARG_MODE = 306               # bad open mode
    ARG_SEQUENCE = 307           # reading functions improperly composed
    ARG_DATA_SIZE = 308          # local data does not match declared sizes


_ERROR_STRINGS = {
    ScdaErrorCode.SUCCESS: "success",
    ScdaErrorCode.CORRUPT_MAGIC: "corrupt file: bad magic bytes or unsupported scda version",
    ScdaErrorCode.CORRUPT_PADDING: "corrupt file: malformed padding",
    ScdaErrorCode.CORRUPT_COUNT: "corrupt file: malformed count entry",
    ScdaErrorCode.CORRUPT_SECTION_TYPE: "corrupt file: unknown section type",
    ScdaErrorCode.CORRUPT_TRUNCATED: "corrupt file: unexpected end of file",
    ScdaErrorCode.CORRUPT_ENCODING: "corrupt file: compression convention violated",
    ScdaErrorCode.CORRUPT_CHECKSUM: "corrupt file: checksum or size mismatch on decompression",
    ScdaErrorCode.FS_OPEN: "file system: cannot open file",
    ScdaErrorCode.FS_READ: "file system: read failed",
    ScdaErrorCode.FS_WRITE: "file system: write failed",
    ScdaErrorCode.FS_CLOSE: "file system: close failed",
    ScdaErrorCode.ARG_USER_STRING: "invalid argument: user string exceeds 58 bytes",
    ScdaErrorCode.ARG_VENDOR_STRING: "invalid argument: vendor string exceeds 20 bytes",
    ScdaErrorCode.ARG_COUNT_RANGE: "invalid argument: count out of 26-decimal-digit range",
    ScdaErrorCode.ARG_INLINE_SIZE: "invalid argument: inline data must be exactly 32 bytes",
    ScdaErrorCode.ARG_PARTITION: "invalid argument: inconsistent partition",
    ScdaErrorCode.ARG_MODE: "invalid argument: bad file open mode",
    ScdaErrorCode.ARG_SEQUENCE: "invalid argument: improper call sequence",
    ScdaErrorCode.ARG_DATA_SIZE: "invalid argument: local data size mismatch",
}


class ScdaError(Exception):
    """Exception carrying an scda error code (paper §A.6).

    ``offset``, when known, is the absolute file offset of the first byte
    that failed validation — ``scdatool fsck`` and the mode-'a' tail
    validation surface it so "trailing garbage" findings point at the
    exact boundary instead of just the enclosing section.
    """

    def __init__(self, code: ScdaErrorCode, detail: str = "",
                 offset: "int | None" = None):
        self.code = ScdaErrorCode(code)
        self.detail = detail
        self.offset = offset
        msg = ferror_string(self.code)
        if detail:
            msg = f"{msg}: {detail}"
        super().__init__(msg)

    def at(self, offset: int) -> "ScdaError":
        """Attach ``offset`` if none is recorded yet (callers lower in the
        stack know the tighter position; never overwrite it)."""
        if self.offset is None:
            self.offset = offset
        return self

    @property
    def group(self) -> int:
        """Error group per paper §A.6: 1 corrupt, 2 file system, 3 usage."""
        return int(self.code) // 100


def ferror_string(code: int) -> str:
    """Translate an error code to a string (paper §A.6.1, non-collective)."""
    try:
        return _ERROR_STRINGS[ScdaErrorCode(code)]
    except (ValueError, KeyError):
        return f"unknown scda error code {code}"


#: Errno values the backend treats as transient and retries (bounded by
#: ``REPRO_SCDA_RETRIES``) instead of aborting: an interrupted syscall
#: and a would-block return are scheduling noise, not file damage.
TRANSIENT_ERRNOS = frozenset({
    _errno.EINTR, _errno.EAGAIN,
    getattr(_errno, "EWOULDBLOCK", _errno.EAGAIN),
})


def os_error_detail(path: str, offset: "int | None", e: OSError,
                    retries: int = 0) -> str:
    """The detail string for a group-2 error wrapping ``e``.

    Uniform across the backend's read/write paths: the failing
    ``path@offset``, the OS error, how many transient retries were burned
    before giving up, and — loudest of all — an explicit marker for
    ENOSPC, the one errno whose cleanup contract (tmp sweep, no visible
    checkpoint) callers must be able to trust.
    """
    loc = f"{path}@{offset}" if offset is not None else path
    msg = f"{loc}: {e}"
    if retries:
        msg += f" (gave up after {retries} transient retries)"
    if getattr(e, "errno", None) == _errno.ENOSPC:
        msg += (" — NO SPACE LEFT ON DEVICE; aborting this save cleanly"
                " (tmp files are swept, no partial checkpoint becomes"
                " visible)")
    return msg
