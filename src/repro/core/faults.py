"""Deterministic fault injection + syscall recording for the I/O stack.

The paper's §A.6 mandate — "file errors should never crash the
simulation" — is only worth anything if the error paths actually run.
This module is the single choke point every mutating syscall of the scda
stack flows through (:class:`~repro.core.io_backend.FileBackend` routes
``pwrite``/``pwritev``/``pread``/``preadv``/``fsync``/``ftruncate``
here; the checkpoint commit/rename helpers route ``replace`` and
directory fsync), which buys two capabilities for free everywhere at
once:

* **Deterministic fault injection.**  A :class:`FaultPlan` describes
  errno faults (EIO/ENOSPC/EINTR/EAGAIN), short and zero-progress
  ``pwritev``/``preadv`` completions, torn multi-fragment writes cut at
  a chosen fragment boundary, and hard crash-points at the Nth matching
  syscall (:class:`SimulatedCrash` — a ``BaseException``, so it rips
  through the taxonomy exactly like power loss would).  Scheduling is
  fully deterministic: per-rule call counters (``nth``/``count``) or a
  seeded Bernoulli stream (``p``/``seed``), never wall clock.  Plans
  activate three ways:

  - process-wide from the environment: ``REPRO_SCDA_FAULTS=<spec>``
    (works under ``scdatool`` and examples, no code changes);
  - process-wide from tests: :func:`install` / :func:`inject`;
  - scoped to ONE file: :func:`FaultBackend` — a ``FileBackend`` whose
    own calls (background writeback/prefetch jobs included, since those
    re-enter the backend's methods) see a private plan.

* **Op-log recording** (:func:`record`): every successful write, fsync,
  truncate, rename, and directory fsync is appended to an :class:`OpLog`
  with its actual bytes — the raw material for power-cut replay
  (``tests/helpers/crashsim.py``), which re-materializes every crash
  prefix of a commit with un-fsynced effects dropped or torn.

Spec grammar (``REPRO_SCDA_FAULTS`` and everything above)::

    spec  := rule (';' rule)*
    rule  := op (':' field)*
    op    := pwrite | pwritev | pread | preadv | fsync | fsync_dir
           | truncate | replace | open | '*'          (any op)
    field := errno=<name|int>      raise OSError(errno) instead
           | short=<K>             complete only K bytes (write or read)
           | zero                  zero-progress completion (reads: EOF)
           | torn=<F>              pwritev: land fragments [0,F), then crash
           | crash                 SimulatedCrash instead of the op
           | missing               the call sees ENOENT (file "lost")
           | unlink                really unlink the file, then proceed
           | nth=<N>               fire on the Nth matching call (default 1)
           | count=<K>             keep firing for K calls (-1 = forever)
           | p=<float> seed=<S>    seeded per-call Bernoulli instead of nth
           | path=<substr>         only calls whose path contains substr

    REPRO_SCDA_FAULTS="pwritev:errno=ENOSPC:nth=3:path=step_"
    REPRO_SCDA_FAULTS="*:crash:nth=40;preadv:short=100:nth=2"

Exactly one action per rule; the first rule that fires wins.  No faults
configured means near-zero overhead: one ``is None`` check per syscall.
"""
from __future__ import annotations

import dataclasses
import errno as _errno
import os
import random
import threading
from typing import Callable, List, Optional, Sequence

from repro.core import trace as _trace

__all__ = [
    "SimulatedCrash", "FaultRule", "FaultPlan", "FaultInjector",
    "FaultBackend", "OpLog", "Op", "install", "uninstall", "inject",
    "record", "active",
]

#: Every op name a rule may target (also the recorder's vocabulary).
OPS = ("open", "pwrite", "pwritev", "pread", "preadv", "fsync",
       "fsync_dir", "truncate", "replace")


class SimulatedCrash(BaseException):
    """An injected hard crash-point (simulated power cut / SIGKILL).

    Deliberately a ``BaseException``: nothing in the scda error taxonomy
    may catch and convert it — it must rip through ``save()`` exactly
    like the process dying would, leaving whatever bytes the prior
    syscalls landed.
    """

    def __init__(self, op: str, path: str, detail: str = ""):
        self.op = op
        self.path = path
        super().__init__(
            f"simulated crash at {op} on {path!r}"
            + (f": {detail}" if detail else ""))


_ACTIONS = ("errno", "short", "zero", "torn", "crash", "missing", "unlink")


@dataclasses.dataclass
class FaultRule:
    """One parsed rule of a fault plan (see the module spec grammar)."""
    op: str                        # an OPS name or "*"
    kind: str                      # one of _ACTIONS
    errno_: int = 0                # for kind == "errno"
    n: int = 0                     # short byte count / torn fragment index
    nth: int = 1                   # 1-based first matching call that fires
    count: int = 1                 # consecutive firings (-1 = forever)
    p: float = 0.0                 # Bernoulli rate (overrides nth/count)
    seed: int = 0                  # Bernoulli stream seed
    path: str = ""                 # substring filter on the target path
    # runtime state (not part of the parsed spec)
    _seen: int = 0
    _rng: Optional[random.Random] = None

    def matches(self, op: str, path: str) -> bool:
        return (self.op in ("*", op)) and (not self.path
                                           or self.path in path)

    def fires(self) -> bool:
        """Count this matching call; True if the rule injects on it."""
        self._seen += 1
        if self.p > 0.0:
            if self._rng is None:
                self._rng = random.Random(self.seed)
            return self._rng.random() < self.p
        if self._seen < self.nth:
            return False
        return self.count < 0 or self._seen < self.nth + self.count


def _parse_errno(value: str) -> int:
    if value.isdigit():
        return int(value)
    code = getattr(_errno, value.upper(), None)
    if not isinstance(code, int):
        raise ValueError(f"unknown errno name {value!r}")
    return code


class FaultPlan:
    """An ordered list of :class:`FaultRule` parsed from a spec string."""

    def __init__(self, rules: Sequence[FaultRule]):
        self.rules = list(rules)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        rules: List[FaultRule] = []
        for raw in spec.split(";"):
            raw = raw.strip()
            if not raw:
                continue
            fields = raw.split(":")
            op = fields[0].strip()
            if op not in OPS and op != "*":
                raise ValueError(f"fault rule {raw!r}: unknown op {op!r}")
            kw: dict = {}
            for f in fields[1:]:
                key, _, val = f.strip().partition("=")
                if key == "errno":
                    kw["kind"], kw["errno_"] = "errno", _parse_errno(val)
                elif key in ("short", "torn"):
                    kw["kind"], kw["n"] = key, int(val)
                elif key in ("zero", "crash", "missing", "unlink"):
                    kw["kind"] = key
                elif key == "nth":
                    kw["nth"] = max(1, int(val))
                elif key == "count":
                    kw["count"] = int(val)
                elif key == "p":
                    kw["p"] = float(val)
                elif key == "seed":
                    kw["seed"] = int(val)
                elif key == "path":
                    kw["path"] = val
                else:
                    raise ValueError(f"fault rule {raw!r}: "
                                     f"unknown field {f!r}")
            if kw.get("kind") not in _ACTIONS:
                raise ValueError(f"fault rule {raw!r}: no action "
                                 f"(one of {', '.join(_ACTIONS)})")
            rules.append(FaultRule(op=op, **kw))
        return cls(rules)


class FaultInjector:
    """Stateful evaluator of a :class:`FaultPlan` (thread-safe counters)."""

    def __init__(self, plan):
        if isinstance(plan, str):
            plan = FaultPlan.parse(plan)
        self.plan = plan
        self._lock = threading.Lock()
        #: every injected fault, for test assertions: (op, path, kind)
        self.injected: List[tuple] = []

    def decide(self, op: str, path: str) -> Optional[FaultRule]:
        """The first rule firing on this call, or None (counts the call
        against every matching rule either way — deterministic across
        rule order)."""
        with self._lock:
            hit = None
            for r in self.plan.rules:
                if r.matches(op, path) and r.fires() and hit is None:
                    hit = r
            if hit is not None:
                self.injected.append((op, path, hit.kind))
            return hit


# -- op-log recording (power-cut replay's raw material) -----------------------

@dataclasses.dataclass
class Op:
    """One successful syscall, as the replay harness needs it."""
    op: str                        # an OPS name
    path: str
    offset: int = 0                # pwrite: position of ``data``
    data: bytes = b""              # pwrite: the bytes actually written
    n: int = 0                     # truncate: new length; open: flags
    dst: str = ""                  # replace: destination path

    def __repr__(self) -> str:  # keep test failure output readable
        extra = f" +{len(self.data)}B@{self.offset}" if self.data else ""
        dst = f" -> {self.dst}" if self.dst else ""
        return f"<{self.op} {self.path}{extra}{dst}>"


class OpLog:
    """Thread-safe append-only list of :class:`Op` (background writeback
    jobs record from their worker threads; every op is appended at
    completion time, so happens-before edges in the code — drain before
    fsync, fsync before rename — are preserved in log order)."""

    def __init__(self) -> None:
        self.ops: List[Op] = []
        self._lock = threading.Lock()

    def append(self, op: Op) -> None:
        with self._lock:
            self.ops.append(op)

    def __len__(self) -> int:
        with self._lock:
            return len(self.ops)

    def __iter__(self):
        with self._lock:
            return iter(list(self.ops))


# -- activation ---------------------------------------------------------------

_state_lock = threading.Lock()
_installed: Optional[FaultInjector] = None
_recorder: Optional[OpLog] = None
# REPRO_SCDA_FAULTS cache: (raw spec string, injector) — the injector is
# reused while the string is unchanged so nth/count counters accumulate
# across calls, and re-parsed the moment a test flips the variable.
_env_cache: tuple = ("", None)


def install(plan) -> FaultInjector:
    """Install a process-wide fault plan (spec string or FaultPlan);
    returns the injector (``.injected`` is the assertion hook)."""
    global _installed
    inj = plan if isinstance(plan, FaultInjector) else FaultInjector(plan)
    with _state_lock:
        _installed = inj
    return inj


def uninstall() -> None:
    global _installed
    with _state_lock:
        _installed = None


class inject:
    """``with faults.inject("pwrite:errno=EIO"): ...`` — scoped install."""

    def __init__(self, plan):
        self.injector = plan if isinstance(plan, FaultInjector) \
            else FaultInjector(plan)

    def __enter__(self) -> FaultInjector:
        install(self.injector)
        return self.injector

    def __exit__(self, *exc) -> None:
        uninstall()


class record:
    """``with faults.record() as log: ...`` — capture every mutating
    syscall into an :class:`OpLog` (one recorder at a time)."""

    def __init__(self) -> None:
        self.log = OpLog()

    def __enter__(self) -> OpLog:
        global _recorder
        with _state_lock:
            _recorder = self.log
        return self.log

    def __exit__(self, *exc) -> None:
        global _recorder
        with _state_lock:
            _recorder = None


def _env_injector() -> Optional[FaultInjector]:
    global _env_cache
    spec = os.environ.get("REPRO_SCDA_FAULTS", "")
    if not spec:
        return None
    with _state_lock:
        if _env_cache[0] != spec:
            try:
                _env_cache = (spec, FaultInjector(spec))
            except ValueError:
                _env_cache = (spec, None)  # malformed spec: inert
        return _env_cache[1]


def active(inj: Optional[FaultInjector] = None) -> Optional[FaultInjector]:
    """The injector governing the current call: an explicitly scoped one
    (a :func:`FaultBackend`'s), else the installed one, else the
    environment's."""
    if inj is not None:
        return inj
    if _installed is not None:
        return _installed
    return _env_injector()


def _quiet() -> bool:
    return _installed is None and _recorder is None \
        and not os.environ.get("REPRO_SCDA_FAULTS")


def _decide(op: str, path: str, inj: Optional[FaultInjector]) \
        -> Optional[FaultRule]:
    cur = active(inj)
    return cur.decide(op, path) if cur is not None else None


def _apply_simple(act: Optional[FaultRule], op: str, path: str) \
        -> Optional[FaultRule]:
    """Raise for errno/crash actions; hand short/zero/torn back to the
    per-op wrapper (they change the completion, not the outcome)."""
    if act is None:
        return None
    c = _trace.collector()
    if c is not None:
        c.event(f"fault.{act.kind}", "io", op=op, path=path)
    if act.kind == "errno":
        raise OSError(act.errno_, os.strerror(act.errno_), path)
    if act.kind == "crash":
        raise SimulatedCrash(op, path)
    if act.kind == "missing":
        # Whole-file loss as this op sees it: ENOENT, file "gone".
        raise OSError(_errno.ENOENT, os.strerror(_errno.ENOENT), path)
    if act.kind == "unlink":
        # Whole-file loss for real: the dirent goes away; already-open
        # fds keep working on the orphaned inode (POSIX), later opens
        # fail naturally — exactly what losing a shard file looks like.
        try:
            os.unlink(path)
        except OSError:
            pass
        return None
    return act


def _record(op: Op) -> None:
    rec = _recorder
    if rec is not None:
        rec.append(op)


# -- instrumented syscalls ----------------------------------------------------
# Each wrapper: decide → maybe inject → real call → record → return.  The
# fast path (no injector, no recorder, no trace collector) is two function
# calls + a few global reads on top of the raw syscall; telemetry spans
# (op kind, path, offset, bytes, latency) are emitted only when
# ``trace.collector()`` is live, around the real syscall (injected early
# completions show up as ``fault.*`` events instead).

def os_open(path: str, flags: int, mode: int = 0o644,
            inj: Optional[FaultInjector] = None) -> int:
    c = _trace.collector()
    t0 = c.now() if c is not None else 0
    if not _quiet() or inj is not None:
        _apply_simple(_decide("open", path, inj), "open", path)
        fd = os.open(path, flags, mode)
        if flags & os.O_WRONLY or flags & os.O_RDWR:
            _record(Op("open", path, n=flags))
    else:
        fd = os.open(path, flags, mode)
    if c is not None:
        c.io_op("open", path, 0, 0, t0)
    return fd


def os_pwrite(fd: int, view, offset: int, path: str = "",
              inj: Optional[FaultInjector] = None) -> int:
    act = _apply_simple(_decide("pwrite", path, inj), "pwrite", path) \
        if (not _quiet() or inj is not None) else None
    if act is not None:
        if act.kind == "zero":
            return 0
        if act.kind in ("short", "torn"):
            view = view[:max(0, act.n)]
            if not len(view):
                return 0
    c = _trace.collector()
    t0 = c.now() if c is not None else 0
    n = os.pwrite(fd, view, offset)
    if c is not None:
        c.io_op("pwrite", path, offset, n, t0)
    if _recorder is not None:
        _record(Op("pwrite", path, offset=offset, data=bytes(view[:n])))
    return n


def os_pwritev(fd: int, views: Sequence, offset: int, path: str = "",
               inj: Optional[FaultInjector] = None) -> int:
    act = _apply_simple(_decide("pwritev", path, inj), "pwritev", path) \
        if (not _quiet() or inj is not None) else None
    if act is not None:
        if act.kind == "zero":
            return 0
        if act.kind == "torn":
            # Land fragments [0, F) for real, then die: the torn
            # multi-fragment write, cut exactly at a fragment boundary.
            cut = max(0, act.n)
            done = 0
            for v in views[:cut]:
                while done < len(v):
                    w = os.pwrite(fd, v[done:], offset + done)
                    done += w
                if _recorder is not None:
                    _record(Op("pwrite", path, offset=offset,
                               data=bytes(v)))
                offset += len(v)
                done = 0
            raise SimulatedCrash("pwritev", path,
                                 f"torn write cut at fragment {cut}")
        if act.kind == "short":
            # A short vectored completion of exactly K bytes: trim the
            # iovec list so the bytes on disk match the reported count.
            budget, trimmed = max(0, act.n), []
            for v in views:
                if budget <= 0:
                    break
                take = v[:budget] if len(v) > budget else v
                trimmed.append(take)
                budget -= len(take)
            if not trimmed:
                return 0
            views = trimmed
    if not hasattr(os, "pwritev"):  # pragma: no cover - exotic hosts
        n = 0
        for v in views:
            n += os_pwrite(fd, v, offset + n, path=path)
        return n
    c = _trace.collector()
    t0 = c.now() if c is not None else 0
    n = os.pwritev(fd, views, offset)
    if c is not None:
        c.io_op("pwritev", path, offset, n, t0)
    if _recorder is not None and n > 0:
        joined = b"".join(bytes(v) for v in views)
        _record(Op("pwritev", path, offset=offset, data=joined[:n]))
    return n


def os_pread(fd: int, n: int, offset: int, path: str = "",
             inj: Optional[FaultInjector] = None) -> bytes:
    if not _quiet() or inj is not None:
        act = _apply_simple(_decide("pread", path, inj), "pread", path)
        if act is not None:
            if act.kind == "zero":
                return b""
            if act.kind in ("short", "torn"):
                n = min(n, max(0, act.n))
                if n == 0:
                    return b""
    c = _trace.collector()
    if c is None:
        return os.pread(fd, n, offset)
    t0 = c.now()
    data = os.pread(fd, n, offset)
    c.io_op("pread", path, offset, len(data), t0)
    return data


def os_preadv(fd: int, views: Sequence, offset: int, path: str = "",
              inj: Optional[FaultInjector] = None) -> int:
    if not _quiet() or inj is not None:
        act = _apply_simple(_decide("preadv", path, inj), "preadv", path)
        if act is not None:
            if act.kind == "zero":
                return 0
            if act.kind in ("short", "torn"):
                budget, trimmed = max(0, act.n), []
                for v in views:
                    if budget <= 0:
                        break
                    take = v[:budget] if len(v) > budget else v
                    trimmed.append(take)
                    budget -= len(take)
                if not trimmed:
                    return 0
                views = trimmed
    if not hasattr(os, "preadv"):  # pragma: no cover - exotic hosts
        got = 0
        for v in views:
            data = os.pread(fd, len(v), offset + got)
            v[:len(data)] = data
            got += len(data)
            if len(data) < len(v):
                break
        return got
    c = _trace.collector()
    if c is None:
        return os.preadv(fd, views, offset)
    t0 = c.now()
    n = os.preadv(fd, views, offset)
    c.io_op("preadv", path, offset, n, t0)
    return n


def os_fsync(fd: int, path: str = "",
             inj: Optional[FaultInjector] = None) -> None:
    c = _trace.collector()
    t0 = c.now() if c is not None else 0
    if not _quiet() or inj is not None:
        _apply_simple(_decide("fsync", path, inj), "fsync", path)
        os.fsync(fd)
        _record(Op("fsync", path))
    else:
        os.fsync(fd)
    if c is not None:
        c.io_op("fsync", path, 0, 0, t0)


def os_ftruncate(fd: int, length: int, path: str = "",
                 inj: Optional[FaultInjector] = None) -> None:
    c = _trace.collector()
    t0 = c.now() if c is not None else 0
    if not _quiet() or inj is not None:
        _apply_simple(_decide("truncate", path, inj), "truncate", path)
        os.ftruncate(fd, length)
        _record(Op("truncate", path, n=length))
    else:
        os.ftruncate(fd, length)
    if c is not None:
        c.io_op("truncate", path, length, 0, t0)


def os_replace(src: str, dst: str,
               inj: Optional[FaultInjector] = None) -> None:
    c = _trace.collector()
    t0 = c.now() if c is not None else 0
    if not _quiet() or inj is not None:
        _apply_simple(_decide("replace", dst, inj), "replace", dst)
        os.replace(src, dst)
        _record(Op("replace", src, dst=dst))
    else:
        os.replace(src, dst)
    if c is not None:
        c.io_op("replace", dst, 0, 0, t0)


def os_fsync_dir(path: str,
                 inj: Optional[FaultInjector] = None) -> None:
    """fsync a DIRECTORY — what makes a rename durable.  POSIX: the
    rename itself only mutates the in-memory dirent; power loss before
    the directory inode reaches disk can undo an "atomic commit"."""
    c = _trace.collector()
    t0 = c.now() if c is not None else 0
    if not _quiet() or inj is not None:
        _apply_simple(_decide("fsync_dir", path, inj), "fsync_dir", path)
    fd = os.open(path or ".", os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)
    _record(Op("fsync_dir", path))
    if c is not None:
        c.io_op("fsync_dir", path, 0, 0, t0)


# -- the test-facing backend shim ---------------------------------------------

def FaultBackend(path: str, mode: str, create: bool, plan,
                 readahead: Optional[int] = None):
    """A :class:`~repro.core.io_backend.FileBackend` whose syscalls run
    under a private fault plan — scoped to this one file, unlike
    :func:`install`.  Background writeback and prefetch jobs re-enter the
    backend's own methods, so they see the same plan from their worker
    threads (the injector's counters are thread-safe).

    A factory rather than a subclass: the backend carries its injector in
    ``_inj``, which every instrumented syscall wrapper receives — the
    import dependency stays one-way (io_backend → faults).
    """
    from repro.core.io_backend import FileBackend
    backend = FileBackend(path, mode, create, readahead)
    backend._inj = plan if isinstance(plan, FaultInjector) \
        else FaultInjector(plan)
    return backend
