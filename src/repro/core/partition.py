"""Partition arithmetic for parallel array I/O (paper §A.1).

A partition of N global elements over P ranks is the vector (N_q)_{<P} of
per-rank counts with offsets C_p = Σ_{q<p} N_q, C_0 = 0, C_P = N (eq. 11).
For variable element sizes (E_i)_{<N}, per-rank byte counts are
S_p = Σ_{C_p ≤ i < C_{p+1}} E_i (eq. 12); fixed size E gives S_p = N_p·E
(eq. 13).

The fundamental assumption (paper §A.1): each element is owned by exactly
one rank and ownership is monotone by rank — i.e. contiguous index ranges.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.core.errors import ScdaError, ScdaErrorCode


def offsets(counts: Sequence[int]) -> List[int]:
    """Exclusive prefix sums (C_q)_{≤P}: offsets[p] = C_p, offsets[P] = N."""
    out = [0]
    for c in counts:
        if c < 0:
            raise ScdaError(ScdaErrorCode.ARG_PARTITION, f"negative count {c}")
        out.append(out[-1] + c)
    return out


def validate(counts: Sequence[int], N: int) -> None:
    """Check Σ N_q == N (paper §A.5: 'must satisfy')."""
    total = sum(counts)
    if total != N:
        raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                        f"partition sums to {total}, expected {N}")
    if any(c < 0 for c in counts):
        raise ScdaError(ScdaErrorCode.ARG_PARTITION, "negative count")


def uniform(N: int, P: int) -> List[int]:
    """The canonical balanced partition: ⌈N/P⌉ for the first N mod P ranks."""
    base, rem = divmod(N, P)
    return [base + (1 if p < rem else 0) for p in range(P)]


def byte_range(counts: Sequence[int], E: int, rank: int) -> Tuple[int, int]:
    """(byte offset, byte length) of ``rank``'s slice of a fixed-size array."""
    offs = offsets(counts)
    return offs[rank] * E, counts[rank] * E


def var_byte_ranges(counts: Sequence[int],
                    local_sizes: Sequence[int],
                    per_rank_bytes: Sequence[int],
                    rank: int) -> Tuple[int, int]:
    """(byte offset, byte length) of ``rank``'s slice of a varray.

    ``per_rank_bytes`` is (S_q)_{<P} — collective, as in the paper's
    ``scda_fwrite_varray`` signature ("we leave eventual allgather-type
    operations to the caller").
    """
    if sum(local_sizes) != per_rank_bytes[rank]:
        raise ScdaError(ScdaErrorCode.ARG_DATA_SIZE,
                        f"local sizes sum {sum(local_sizes)} != "
                        f"S_p {per_rank_bytes[rank]}")
    start = sum(per_rank_bytes[:rank])
    return start, per_rank_bytes[rank]


def last_nonempty_rank(counts_bytes: Sequence[int]) -> int:
    """The rank owning the final data byte (writes the '=' padding), or -1."""
    for p in range(len(counts_bytes) - 1, -1, -1):
        if counts_bytes[p] > 0:
            return p
    return -1


def repartition_ranges(old_counts: Sequence[int], new_counts: Sequence[int],
                       rank: int) -> List[Tuple[int, int, int]]:
    """Overlaps of ``rank``'s new range with old ranks (for elastic restart).

    Returns [(old_rank, start_elem, n_elems), ...] covering the new range.
    Not needed for file reading (any partition reads directly) but useful for
    in-memory redistribution bookkeeping.
    """
    new_offs = offsets(new_counts)
    lo, hi = new_offs[rank], new_offs[rank + 1]
    old_offs = offsets(old_counts)
    out: List[Tuple[int, int, int]] = []
    for q in range(len(old_counts)):
        a, b = old_offs[q], old_offs[q + 1]
        s, e = max(lo, a), min(hi, b)
        if s < e:
            out.append((q, s, e - s))
    return out
