"""Parallel scda reader (paper §A.5).

The file is consumed one section at a time: ``read_section_header`` first
(optionally interpreting the §3 compression convention, Table 2), then the
matching data call with a *reading partition chosen freely* — independence
of the writing partition is the point of the format.

Every rank parses section metadata from its own positioned reads of the
(identical) file bytes, which synchronizes collective outputs without
message traffic; only variable-size bookkeeping (per-rank byte sums) uses an
allgather.  Headers are tiny, so O(P) redundant metadata reads are the
standard scalable pattern on parallel file systems.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.core import codec, partition, spec
from repro.core.comm import Communicator, SerialComm
from repro.core.errors import ScdaError, ScdaErrorCode
from repro.core.io_backend import FileBackend


@dataclasses.dataclass
class SectionHeader:
    """Logical header returned by :meth:`ScdaReader.read_section_header`.

    ``type`` ∈ {'I','B','A','V'}; for decoded sections the *logical* type and
    sizes are reported (paper Table 2): e.g. a compressed fixed-size array
    reads back as type 'A' with E = the uncompressed element size.
    """
    type: str
    user_string: bytes
    N: int = 0
    E: int = 0
    decoded: bool = False


@dataclasses.dataclass
class _Pending:
    """Cursor state between the header call and the data call(s)."""
    kind: str                   # 'I' | 'B' | 'A' | 'V' | 'zB' | 'zA' | 'zV'
    header: Optional[SectionHeader] = None
    data_start: int = 0         # raw payload start
    entries_start: int = 0      # V: E_i entries;  zV: U entries of the A
    v_entries_start: int = 0    # zA/zV: E_i entries of the carrier V
    v_data_start: int = 0       # zA/zV: compressed payload start
    raw_E: int = 0              # zB: compressed block size
    sizes_read: bool = False
    total_bytes: Optional[int] = None  # V/zX: Σ data bytes once known


class ScdaReader:
    """File context for mode 'r' (§A.3); forward-only cursor."""

    def __init__(self, comm: Optional[Communicator], path: str,
                 backend: Optional[FileBackend] = None) -> None:
        self.comm = comm or SerialComm()
        self.path = path
        # ``backend`` lets a caller substitute a synthetic byte source —
        # the degraded-mode reconstructor in repro.checkpoint.redundancy
        # reads a lost shard's bytes out of surviving shards + parity.
        self._backend = (backend if backend is not None
                         else FileBackend(path, "r", create=False))
        self._closed = False
        self._pending: Optional[_Pending] = None
        self._index = None  # lazy ScdaIndex (see repro.core.index)
        header = spec.parse_file_header(
            self._backend.pread(0, spec.FILE_HEADER_BYTES))
        self.version = header.version
        self.vendor = header.vendor
        self.user_string = header.user_string
        self.cursor = spec.FILE_HEADER_BYTES
        self._file_size = self._backend.size()

    def __enter__(self) -> "ScdaReader":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    @property
    def at_eof(self) -> bool:
        return self._pending is None and self.cursor >= self._file_size

    # -- parse helpers carrying exact offsets ---------------------------------
    def _header_at(self, off: int):
        """Parse the 64-byte section header at ``off``; a parse failure
        carries the exact byte offset (``ScdaError.offset``) so fsck and
        mode-'a' tail validation can point at the failing byte."""
        try:
            return spec.parse_section_header(
                self._backend.pread(off, spec.SECTION_HEADER_BYTES))
        except ScdaError as e:
            raise e.at(off)

    def _entry_at(self, off: int, letter: bytes) -> int:
        """Parse the 32-byte count entry at ``off``, offset-attributed."""
        try:
            return spec.parse_count_entry(
                self._backend.pread(off, spec.COUNT_ENTRY_BYTES), letter)
        except ScdaError as e:
            raise e.at(off)

    # -- section header (§A.5.1) --------------------------------------------
    def read_section_header(self, decode: bool = True) -> SectionHeader:
        self._check_open()
        if self._pending is not None:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            "previous section's data not consumed")
        if self.at_eof:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE, "at end of file")
        letter, user = self._header_at(self.cursor)
        t = letter.decode("ascii")
        if letter not in spec.SECTION_TYPES:
            raise ScdaError(ScdaErrorCode.CORRUPT_SECTION_TYPE, repr(letter),
                            offset=self.cursor)
        if decode and letter == b"I" and user in (codec.MAGIC_BLOCK,
                                                  codec.MAGIC_ARRAY):
            return self._begin_decoded_inline(user)
        if decode and letter == b"A" and user == codec.MAGIC_VARRAY:
            return self._begin_decoded_varray()
        return self._begin_raw(t, user)

    def _begin_raw(self, t: str, user: bytes) -> SectionHeader:
        cur = self.cursor + spec.SECTION_HEADER_BYTES
        if t == "I":
            hdr = SectionHeader("I", user)
            self._pending = _Pending("I", hdr, data_start=cur)
        elif t == "B":
            E = self._entry_at(cur, b"E")
            hdr = SectionHeader("B", user, E=E)
            self._pending = _Pending(
                "B", hdr, data_start=cur + spec.COUNT_ENTRY_BYTES)
        elif t == "A":
            N = self._entry_at(cur, b"N")
            E = self._entry_at(cur + spec.COUNT_ENTRY_BYTES, b"E")
            hdr = SectionHeader("A", user, N=N, E=E)
            self._pending = _Pending(
                "A", hdr, data_start=cur + 2 * spec.COUNT_ENTRY_BYTES)
        else:  # V
            N = self._entry_at(cur, b"N")
            hdr = SectionHeader("V", user, N=N)
            entries = cur + spec.COUNT_ENTRY_BYTES
            self._pending = _Pending(
                "V", hdr, entries_start=entries,
                data_start=entries + N * spec.COUNT_ENTRY_BYTES)
        return self._pending.header

    def _begin_decoded_inline(self, magic: bytes) -> SectionHeader:
        """§3.2/§3.3 — I(magic, U) followed by B or V with the true header."""
        udata = self._backend.pread(
            self.cursor + spec.SECTION_HEADER_BYTES, spec.INLINE_DATA_BYTES)
        U = codec.parse_uncompressed_size_entry(udata)
        second = self.cursor + spec.INLINE_SECTION_BYTES
        letter, user = self._header_at(second)
        cur = second + spec.SECTION_HEADER_BYTES
        if magic == codec.MAGIC_BLOCK:
            if letter != b"B":
                raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                                f"expected B after {magic!r}, got {letter!r}",
                                offset=second)
            cE = self._entry_at(cur, b"E")
            hdr = SectionHeader("B", user, E=U, decoded=True)
            self._pending = _Pending(
                "zB", hdr, data_start=cur + spec.COUNT_ENTRY_BYTES, raw_E=cE)
        else:  # MAGIC_ARRAY → logical fixed-size array carried by a V
            if letter != b"V":
                raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                                f"expected V after {magic!r}, got {letter!r}",
                                offset=second)
            N = self._entry_at(cur, b"N")
            hdr = SectionHeader("A", user, N=N, E=U, decoded=True)
            entries = cur + spec.COUNT_ENTRY_BYTES
            self._pending = _Pending(
                "zA", hdr, v_entries_start=entries,
                v_data_start=entries + N * spec.COUNT_ENTRY_BYTES)
        return self._pending.header

    def _begin_decoded_varray(self) -> SectionHeader:
        """§3.4 — A(magic, N, 32, U-entries) followed by the carrier V."""
        cur = self.cursor + spec.SECTION_HEADER_BYTES
        N = self._entry_at(cur, b"N")
        E = self._entry_at(cur + spec.COUNT_ENTRY_BYTES, b"E")
        if E != spec.COUNT_ENTRY_BYTES:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"U-entry array has E={E}, expected 32",
                            offset=cur + spec.COUNT_ENTRY_BYTES)
        u_entries = cur + 2 * spec.COUNT_ENTRY_BYTES
        second = u_entries + spec.padded_data_bytes(
            N * spec.COUNT_ENTRY_BYTES)
        letter, user = self._header_at(second)
        if letter != b"V":
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"expected V after U-entry array, got {letter!r}",
                            offset=second)
        vcur = second + spec.SECTION_HEADER_BYTES
        vN = self._entry_at(vcur, b"N")
        if vN != N:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"carrier V has N={vN}, metadata says {N}",
                            offset=vcur)
        hdr = SectionHeader("V", user, N=N, decoded=True)
        v_entries = vcur + spec.COUNT_ENTRY_BYTES
        self._pending = _Pending(
            "zV", hdr, entries_start=u_entries,
            v_entries_start=v_entries,
            v_data_start=v_entries + N * spec.COUNT_ENTRY_BYTES)
        return self._pending.header

    # -- random access (§1: selective access; the PR-2 index layer) -----------
    def index(self, rebuild: bool = False):
        """The file's :class:`~repro.core.index.ScdaIndex`, built lazily.

        Building is one header-only scan (rank-local; every rank sees the
        identical bytes, so no collective traffic is needed).  Pass a
        pre-built/sidecar-loaded index via :meth:`set_index` to skip even
        that.  The cursor and any pending section are preserved (also when
        the build fails on a corrupt file), so calling this mid-walk is
        safe and seek-after-browse behaves the same with or without a
        cached index.
        """
        if self._index is None or rebuild:
            from repro.core.index import ScdaIndex
            saved_cursor, saved_pending = self.cursor, self._pending
            self._pending = None
            try:
                self._index = ScdaIndex.build(self)
            finally:
                self.cursor, self._pending = saved_cursor, saved_pending
        return self._index

    def set_index(self, index) -> None:
        """Adopt a pre-built index (e.g. loaded from a ``.scdax`` sidecar)."""
        self._index = index

    def seek_section(self, i: int, check: bool = True) -> SectionHeader:
        """Jump straight to logical section ``i`` (random access).

        Positions the cursor on the section and installs the same pending
        state a forward :meth:`read_section_header` walk would have produced,
        without replaying the file — any data call (windowed/element reads
        included) works afterwards.  Discards any currently pending section.

        ``check`` re-reads the 64-byte on-disk section header and verifies
        it against the index entry, so a stale sidecar can never silently
        return wrong bytes.  Non-collective: any rank may seek freely, but
        collective data calls still require all ranks on the same section.
        """
        self._check_open()
        idx = self.index()
        entries = idx.entries
        if not 0 <= i < len(entries):
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"section {i} outside [0, {len(entries)})")
        e = entries[i]
        if check:
            # Seek-aware readahead: a jump outside the current window
            # drops and re-fits it at the target, so the header check
            # below and the metadata reads that follow are warm.  Skipped
            # for check=False, which promises an I/O-free seek.
            self._backend.refit_readahead(e.start)
            self.verify_index_entry(i, e)
        self._backend.advise(e.start, e.end - e.start, "willneed")
        self.cursor = e.start
        self._pending = e.to_pending()
        return self._pending.header

    def verify_index_entry(self, i: int, entry=None) -> None:
        """Re-read section ``i``'s on-disk 64-byte header and require it to
        match the index entry — the per-use staleness check every
        index-driven access path (seek, batch read, restore engine) runs
        so a stale sidecar can never silently return wrong bytes."""
        e = self.index().entries[i] if entry is None else entry
        raw_letter, raw_user = e.raw_header()
        letter, user = spec.parse_section_header(
            self._backend.pread(e.start, spec.SECTION_HEADER_BYTES))
        if letter != raw_letter or user != raw_user:
            raise ScdaError(
                ScdaErrorCode.CORRUPT_ENCODING,
                f"index entry {i} does not match the file at offset "
                f"{e.start}: expected {raw_letter!r} {raw_user!r}, "
                f"found {letter!r} {user!r} (stale index?)")

    def open_section(self, user_string: bytes, occurrence: int = 0,
                     check: bool = True) -> SectionHeader:
        """Seek to the ``occurrence``-th section whose user string matches."""
        i = self.index().find(user_string, occurrence)
        if i < 0:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"no section with user string {user_string!r} "
                            f"(occurrence {occurrence})")
        return self.seek_section(i, check=check)

    # -- data reads (§A.5.2–A.5.6) -------------------------------------------
    def read_inline_data(self, root: Optional[int] = None) -> Optional[bytes]:
        """§A.5.2.  ``root=None`` returns the bytes on every rank."""
        p = self._require("I")
        out: Optional[bytes] = None
        if root is None or self.comm.rank == root:
            out = self._backend.pread(p.data_start, spec.INLINE_DATA_BYTES)
        self._finish(p.data_start + spec.INLINE_DATA_BYTES)
        return out

    def read_block_data(self, N: Optional[int] = None,
                        root: Optional[int] = None) -> Optional[bytes]:
        """§A.5.3.  ``N`` must match the header if given (call-consistency)."""
        p = self._require("B", "zB")
        hdr = p.header
        if N is not None and N != hdr.E:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"N={N} inconsistent with header E={hdr.E}")
        raw_len = p.raw_E if p.kind == "zB" else hdr.E
        out: Optional[bytes] = None
        if root is None or self.comm.rank == root:
            raw = self._backend.pread(p.data_start, raw_len)
            if p.kind == "zB":
                raw = codec.decompress(raw)
                if len(raw) != hdr.E:
                    raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                                    f"block inflated to {len(raw)}, "
                                    f"metadata says {hdr.E}")
            out = raw
        self._finish(p.data_start + spec.padded_data_bytes(raw_len))
        return out

    def skip_data(self) -> None:
        """Advance past the current section without touching its payload.

        Enables the paper's "query function [that] reads all file section
        headers but skips the data bytes" (§A.5.1).
        """
        p = self._pending
        if p is None:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE, "no pending section")
        if p.kind == "I":
            p.total_bytes = spec.INLINE_DATA_BYTES
            end = p.data_start + spec.INLINE_DATA_BYTES
        elif p.kind == "B":
            p.total_bytes = p.header.E
            end = p.data_start + spec.padded_data_bytes(p.header.E)
        elif p.kind == "zB":
            p.total_bytes = p.raw_E
            end = p.data_start + spec.padded_data_bytes(p.raw_E)
        elif p.kind == "A":
            p.total_bytes = p.header.N * p.header.E
            end = p.data_start + spec.padded_data_bytes(p.total_bytes)
        else:  # V, zA, zV — must sum the carrier's element sizes
            N = p.header.N
            entries_start = (p.entries_start if p.kind == "V"
                             else p.v_entries_start)
            data_start = (p.data_start if p.kind == "V" else p.v_data_start)
            total = self._sum_entries(entries_start, N)
            p.total_bytes = total
            end = data_start + spec.padded_data_bytes(total)
        self._finish(end)

    def read_array_data(self, counts: Sequence[int], E: Optional[int] = None,
                        indirect: bool = False) -> Optional[List[bytes]]:
        """§A.5.4 — each rank receives its N_p elements of E bytes.

        Returns a list of element buffers (the ``indirect`` view); callers
        wanting one flat buffer join them.  Works for both raw 'A' sections
        and §3.3-encoded ones (transparent decompression).
        """
        p = self._require("A", "zA")
        hdr = p.header
        partition.validate(counts, hdr.N)
        if E is not None and E != hdr.E:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"E={E} inconsistent with header E={hdr.E}")
        rank = self.comm.rank
        if p.kind == "A":
            off, length = partition.byte_range(counts, hdr.E, rank)
            flat = self._backend.pread(p.data_start + off, length) \
                if length else b""
            out = [flat[i * hdr.E:(i + 1) * hdr.E]
                   for i in range(counts[rank])]
            self._finish(p.data_start
                         + spec.padded_data_bytes(hdr.N * hdr.E))
            return out
        # zA: compressed elements ride a V section; all elements must
        # inflate to exactly E bytes.
        elements, end = self._read_v_elements(
            counts, p.v_entries_start, p.v_data_start, hdr.N)
        out = []
        for e in elements:
            raw = codec.decompress(e)
            if len(raw) != hdr.E:
                raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                                f"element inflated to {len(raw)}, "
                                f"expected E={hdr.E}")
            out.append(raw)
        self._finish(end)
        return out

    def read_array_windows(self, windows: Sequence, E: int) -> List[bytes]:
        """Selective random access: read arbitrary element ranges.

        ``windows`` = [(elem_start, n_elems), ...].  Raw 'A' sections only —
        this is the selective-access capability §1 motivates; the checkpoint
        layer uses it to assemble arbitrary target shards.  Does not advance
        the cursor (call :meth:`skip_data` when done with the section).
        """
        p = self._pending
        if p is None or p.kind != "A":
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            "windowed reads need a pending raw A section")
        if E != p.header.E:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE, "E mismatch")
        out = []
        for start, n in windows:
            if start < 0 or start + n > p.header.N:
                raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                                "window outside array")
            out.append(self._backend.pread(p.data_start + start * E, n * E))
        return out

    def read_varray_elements(self, indices: Sequence[int]) -> List[bytes]:
        """Selective random access to individual varray elements (§1).

        Works on raw 'V' and decoded 'zV' sections; decompresses decoded
        elements transparently.  Reads the size-entry table rank-locally to
        locate elements, then preads exactly the requested payloads.  Does
        not advance the cursor — finish the section with :meth:`skip_data`
        (or a full data read).
        """
        p = self._pending
        if p is None or p.kind not in ("V", "zV"):
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            "element reads need a pending V section")
        N = p.header.N
        for i in indices:
            if not 0 <= i < N:
                raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                                f"element {i} outside [0, {N})")
        if p.kind == "V":
            entries_start, data_start = p.entries_start, p.data_start
        else:
            entries_start, data_start = p.v_entries_start, p.v_data_start
        sizes = self._parse_entries(entries_start, 0, N, b"E")
        offs = partition.offsets(sizes)
        out = []
        for i in indices:
            raw = self._backend.pread(data_start + offs[i], sizes[i]) \
                if sizes[i] else b""
            if p.kind == "zV":
                expect = self._parse_entries(p.entries_start, i, 1, b"U")[0]
                raw = codec.decompress(raw)
                if len(raw) != expect:
                    raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                                    f"element {i} inflated to {len(raw)}, "
                                    f"U-entry says {expect}")
            out.append(raw)
        return out

    def read_batch(self, requests: Sequence, prefetch_bytes: Optional[int]
                   = None):
        """Batched, pipelined element reads across sections (§1 selective
        access at archive scale — the overlapped restore engine's API).

        ``requests``: sequence of ``(section_index, windows)`` where
        ``windows`` is a list of ``(elem_start, n_elems)`` element windows.
        Supported section kinds: fixed arrays ('A', 'zA') and varrays
        ('V', 'zV'); §3-encoded elements are transparently inflated (on
        the codec thread pool when the pipeline is live).

        Returns an iterator of ``(request_pos, results)`` yielded as each
        request completes — requests are processed in FILE-OFFSET order,
        not argument order, so disk consumption sweeps forward while
        decompression overlaps on the pool.  ``results``: for 'A'/'zA' one
        buffer per window (elements joined); for 'V'/'zV' one ``bytes``
        per element, in window order.

        ``prefetch_bytes=None`` uses ``REPRO_SCDA_PREFETCH`` (default
        4 MiB); ``0`` disables the background pipeline and reads serially
        in the given order — byte-identical either way.  Non-collective
        and cursor-neutral: any rank may batch-read any sections without
        disturbing a pending section or the forward walk; every section's
        on-disk header is re-checked against the index, as in
        :meth:`seek_section`.
        """
        from repro.core.io_backend import prefetch_window
        from repro.core.pipeline import ReadItem, run_pipeline
        self._check_open()
        if prefetch_bytes is None:
            prefetch_bytes = prefetch_window()
        entries = self.index().entries
        checked = set()
        requests = [(sec, list(windows)) for sec, windows in requests]
        # One count-entry parse per (section, letter), to the furthest
        # element any request touches — windowed callers (scdatool diff
        # walks a section in ~1 MiB slices) would otherwise re-parse a
        # growing prefix per window, quadratic in section size.
        max_upto: dict = {}
        for sec, windows in requests:
            upto = max((s + n for s, n in windows), default=0)
            max_upto[sec] = max(max_upto.get(sec, 0), upto)
        tables: dict = {}  # (section, letter) -> parsed count entries

        def _table(sec, start, letter):
            key = (sec, letter)
            if key not in tables:
                tables[key] = self._parse_entries(start, 0, max_upto[sec],
                                                  letter)
            return tables[key]

        items: List = []
        posts: dict = {}
        for pos, (sec, windows) in enumerate(requests):
            if not 0 <= sec < len(entries):
                raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                                f"section {sec} outside [0, {len(entries)})")
            e = entries[sec]
            if sec not in checked:
                self.verify_index_entry(sec, e)
                checked.add(sec)
            for s, n in windows:
                if s < 0 or n < 0 or s + n > e.N:
                    raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                                    f"window ({s}, {n}) outside section "
                                    f"{sec}'s [0, {e.N})")
            flat = [i for s, n in windows for i in range(s, s + n)]
            if e.kind == "A":
                extents = [(e.data_start + s * e.E, n * e.E)
                           for s, n in windows]
                items.append(ReadItem(pos, extents))
                posts[pos] = ("windows", None)
            elif e.kind == "zA":
                csizes = _table(sec, e.v_entries_start, b"E")
                offs = partition.offsets(csizes)
                extents = [(e.v_data_start + offs[i], csizes[i])
                           for i in flat]
                items.append(ReadItem(pos, extents, inflate=True,
                                      expected_sizes=[e.E] * len(flat)))
                posts[pos] = ("join", [n for _, n in windows])
            elif e.kind == "V":
                sizes = _table(sec, e.entries_start, b"E")
                offs = partition.offsets(sizes)
                extents = [(e.data_start + offs[s],
                            offs[s + n] - offs[s]) for s, n in windows]
                items.append(ReadItem(pos, extents))
                posts[pos] = ("split", [sizes[s:s + n] for s, n in windows])
            elif e.kind == "zV":
                csizes = _table(sec, e.v_entries_start, b"E")
                usizes = _table(sec, e.entries_start, b"U")
                offs = partition.offsets(csizes)
                extents = [(e.v_data_start + offs[i], csizes[i])
                           for i in flat]
                items.append(ReadItem(pos, extents, inflate=True,
                                      expected_sizes=[usizes[i]
                                                      for i in flat]))
                posts[pos] = ("elements", None)
            else:
                raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                                f"read_batch needs an array or varray "
                                f"section; section {sec} is {e.kind!r}")
        items.sort(key=lambda it: it.start())

        def _assemble():
            for key, res in run_pipeline(self._backend, items,
                                         prefetch_bytes):
                mode, meta = posts[key]
                if mode == "join":
                    out, it = [], iter(res)
                    for n in meta:
                        out.append(b"".join(
                            next(it) for _ in range(n)))
                elif mode == "split":
                    out = []
                    for buf, sizes in zip(res, meta):
                        view, p = memoryview(buf), 0
                        for s in sizes:
                            out.append(bytes(view[p:p + s]))
                            p += s
                else:  # "windows" / "elements" — engine results verbatim
                    out = [bytes(b) if not isinstance(b, bytes) else b
                           for b in res]
                yield key, out
        return _assemble()

    def read_varray_sizes(self, counts: Sequence[int]) -> List[int]:
        """§A.5.5 — this rank's (E_i); for decoded sections these are the
        *uncompressed* sizes (from the §3.4 U-entry array)."""
        p = self._require("V", "zV", keep=True)
        partition.validate(counts, p.header.N)
        offs = partition.offsets(counts)
        rank = self.comm.rank
        if p.kind == "V":
            sizes = self._parse_entries(
                p.entries_start, offs[rank], counts[rank], b"E")
        else:  # zV — uncompressed sizes live in the metadata A section
            sizes = self._parse_entries(
                p.entries_start, offs[rank], counts[rank], b"U")
        p.sizes_read = True
        return sizes

    def read_varray_data(self, counts: Sequence[int],
                         local_sizes: Sequence[int],
                         per_rank_bytes: Optional[Sequence[int]] = None,
                         indirect: bool = False) -> List[bytes]:
        """§A.5.6 — this rank's elements under the reading partition."""
        p = self._require("V", "zV")
        hdr = p.header
        partition.validate(counts, hdr.N)
        if len(local_sizes) != counts[self.comm.rank]:
            raise ScdaError(ScdaErrorCode.ARG_PARTITION,
                            "local_sizes length != N_p")
        if p.kind == "V":
            if per_rank_bytes is None:
                per_rank_bytes = self.comm.allgather(sum(local_sizes))
            off, length = partition.var_byte_ranges(
                counts, local_sizes, per_rank_bytes, self.comm.rank)
            flat = self._backend.pread(p.data_start + off, length) \
                if length else b""
            out, pos = [], 0
            for s in local_sizes:
                out.append(flat[pos:pos + s])
                pos += s
            total = sum(per_rank_bytes)
            self._finish(p.data_start + spec.padded_data_bytes(total))
            return out
        # zV: read compressed elements from the carrier V, inflate, check
        # against the uncompressed sizes the caller got from
        # read_varray_sizes.
        elements, end = self._read_v_elements(
            counts, p.v_entries_start, p.v_data_start, hdr.N)
        out = []
        for e, expect in zip(elements, local_sizes):
            raw = codec.decompress(e)
            if len(raw) != expect:
                raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                                f"element inflated to {len(raw)}, "
                                f"U-entry says {expect}")
            out.append(raw)
        self._finish(end)
        return out

    # -- internals -------------------------------------------------------------
    def _read_v_elements(self, counts, entries_start, data_start, N):
        """Read this rank's compressed elements of a carrier V section."""
        offs = partition.offsets(counts)
        rank = self.comm.rank
        csizes = self._parse_entries(
            entries_start, offs[rank], counts[rank], b"E")
        local_total = sum(csizes)
        per_rank = self.comm.allgather(local_total)
        start = sum(per_rank[:rank])
        flat = self._backend.pread(data_start + start, local_total) \
            if local_total else b""
        out, pos = [], 0
        for s in csizes:
            out.append(flat[pos:pos + s])
            pos += s
        total = sum(per_rank)
        return out, data_start + spec.padded_data_bytes(total)

    def _parse_entries(self, entries_start: int, first: int, n: int,
                       letter: Optional[bytes]) -> List[int]:
        """One buffered read + vectorized batch parse of n count entries.

        A malformed entry's error carries the exact 32-byte-entry offset:
        the batch parser reports only that *some* entry failed, so the
        scalar oracle re-locates the first bad one.
        """
        if n == 0:
            return []
        start = entries_start + first * spec.COUNT_ENTRY_BYTES
        raw = self._backend.pread(start, n * spec.COUNT_ENTRY_BYTES)
        try:
            return spec.parse_count_entries(raw, letter, n)
        except ScdaError as e:
            if e.offset is None:
                for i in range(n):
                    entry = raw[i * spec.COUNT_ENTRY_BYTES:
                                (i + 1) * spec.COUNT_ENTRY_BYTES]
                    try:
                        spec.parse_count_entry(
                            entry, entry[0:1] if letter is None else letter)
                    except ScdaError:
                        e.offset = start + i * spec.COUNT_ENTRY_BYTES
                        break
            raise

    def _sum_entries(self, entries_start: int, N: int,
                     chunk: int = 8192) -> int:
        """Rank-local sum of all N count entries (for skip paths)."""
        total = 0
        for first in range(0, N, chunk):
            n = min(chunk, N - first)
            # letter=None: accept each entry's own letter, as the lenient
            # skip path always has.
            total += sum(self._parse_entries(entries_start, first, n, None))
        return total

    def _require(self, *kinds: str, keep: bool = False) -> _Pending:
        self._check_open()
        p = self._pending
        if p is None or p.kind not in kinds:
            have = "none" if p is None else p.kind
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"expected pending {kinds}, have {have}")
        if p.kind in ("V", "zV") and not keep and not p.sizes_read:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            "read_varray_sizes must precede varray data")
        return p

    def _finish(self, new_cursor: int) -> None:
        if new_cursor > self._file_size:
            raise ScdaError(ScdaErrorCode.CORRUPT_TRUNCATED,
                            f"section extends to {new_cursor}, file is "
                            f"{self._file_size} bytes",
                            offset=self._file_size)
        self.cursor = new_cursor
        self._pending = None

    def _check_open(self) -> None:
        if self._closed:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE, "reader is closed")

    def close(self) -> None:
        if self._closed:
            return
        self._backend.close()
        self._closed = True


def fopen_read(comm: Optional[Communicator], path: str) -> ScdaReader:
    """``scda_fopen(..., 'r')`` — collective open for reading."""
    return ScdaReader(comm, path)


def scan_sections(path: str, decode: bool = True,
                  comm: Optional[Communicator] = None) -> List[SectionHeader]:
    """Walk every section header, skipping payloads.

    Collective over ``comm`` when one is passed (each rank performs the
    identical rank-local metadata walk, as in §A.5.1); defaults to serial.
    """
    headers: List[SectionHeader] = []
    with fopen_read(comm or SerialComm(), path) as r:
        while not r.at_eof:
            headers.append(r.read_section_header(decode=decode))
            r.skip_data()
    return headers
