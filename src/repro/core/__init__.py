"""scda — the paper's primary contribution: a minimal, serial-equivalent
format for parallel I/O (Griesbach & Burstedde, 2023).

Public API (mirrors the paper's Appendix A, pythonically):

    from repro.core import fopen_write, fopen_read, SerialComm, ThreadComm

    with fopen_write(comm, path, user_string=b"ckpt") as f:
        f.write_inline(b"step", step_bytes32)
        f.write_block(b"manifest", manifest_json, encode=True)
        f.write_array(b"weights", local_bytes, counts, elem_size)

    with fopen_read(comm, path) as r:
        hdr = r.read_section_header(decode=True)
        data = r.read_array_data(my_new_partition, hdr.E)

The format layer (spec/encode/codec) is pure bytes; parallelism enters only
through the Communicator + positioned-I/O backend, exactly as in the paper
where the format is defined independently of MPI.
"""
from repro.core.errors import ScdaError, ScdaErrorCode, ferror_string
from repro.core import spec, encode, codec, partition, pipeline
from repro.core.comm import (Communicator, SerialComm, ThreadComm,
                             JaxProcessComm, run_ranks)
from repro.core.io_backend import FileBackend
from repro.core.writer import (ScdaWriter, fopen_write, fopen_append,
                               DEFAULT_VENDOR)
from repro.core.reader import (ScdaReader, SectionHeader, fopen_read,
                               scan_sections)
from repro.core.index import IndexEntry, ScdaIndex

__all__ = [
    "ScdaError", "ScdaErrorCode", "ferror_string",
    "spec", "encode", "codec", "partition", "pipeline",
    "Communicator", "SerialComm", "ThreadComm", "JaxProcessComm",
    "run_ranks", "FileBackend",
    "ScdaWriter", "fopen_write", "fopen_append", "DEFAULT_VENDOR",
    "ScdaReader", "SectionHeader", "fopen_read", "scan_sections",
    "IndexEntry", "ScdaIndex",
]
