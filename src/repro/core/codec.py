"""Per-element transparent compression convention (paper §3).

Two-stage algorithm (§3.1), applied to a block's data or to each array
element independently:

  stage 1:  8-byte big-endian uncompressed size ‖ b'z' ‖ RFC1950/1951
            deflate stream (any legal level; we default to zlib level 9,
            the paper's recommendation of "zlib's best compression").
  stage 2:  base64, broken into lines of 76 code bytes + a 2-byte break
            ("=\n" Unix, "\r\n" MIME), including after the final short line.

On reading, the length is known from file context; base64-decode, read the
size from the first 8 bytes, check the 'z' tag at byte 9, inflate, and verify
the three redundant checks (§3.1): the adler32 inside zlib, the size match,
and the 'z' marker.

Convention magic user strings (§3.2–3.4), version (00)₁₆:
  block        : I("B compressed scda 00", U-entry) ; B(user, compressed)
  fixed array  : I("A compressed scda 00", U-entry) ; V(user, N, compressed…)
  var. array   : A("V compressed scda 00", N, 32, U-entries) ; V(user, N, …)

Fast-path implementation (byte-identical to the reference algorithm):

* compress/decompress run zlib via streaming ``compressobj`` /
  ``decompressobj`` in bounded chunks and accept any buffer view (no
  up-front ``bytes()`` copy of the payload);
* stage-2 line breaking / unbreaking is vectorized with a numpy reshape
  instead of a Python loop over 76-byte lines;
* :func:`compress_elements` fans independent elements out over a thread
  pool (zlib releases the GIL) once the payload is large enough;
  ``REPRO_CODEC_THREADS`` tunes the width, ``1`` disables.
* The read side mirrors it: :func:`decompress_elements` inflates a batch
  of independent streams over the same pool, and
  :func:`submit_decompress_batch` hands a slice of streams to the pool as
  one future so the overlapped restore engine
  (:mod:`repro.core.pipeline`) can inflate chunk k while chunk k+1 is
  still in flight from disk.
* The write side mirrors the mirror: :func:`submit_compress_batch`
  deflates a slice of payloads as one pool job (stage 1 only — pure
  zlib, GIL released for the whole call) and the caller finishes with
  :func:`encode_stage2` (base64 + line breaks, brief GIL-held numpy) on
  its own thread, so the overlapped save engine can deflate leaf k+1
  while leaf k's ``pwritev`` is in flight.
"""
from __future__ import annotations

import base64
import binascii
import os as _os
import struct
import threading as _threading
import zlib
from typing import List, Optional, Sequence, Union

try:
    import numpy as _np
except ImportError:  # pragma: no cover - numpy is a hard dep elsewhere
    _np = None

from repro.core import spec
from repro.core import trace as _trace
from repro.core.errors import ScdaError, ScdaErrorCode

BytesLike = Union[bytes, bytearray, memoryview]

#: Magic user strings identifying the compression convention (§3.2).
MAGIC_BLOCK = b"B compressed scda 00"
MAGIC_ARRAY = b"A compressed scda 00"
MAGIC_VARRAY = b"V compressed scda 00"
MAGIC_BY_TYPE = {b"B": MAGIC_BLOCK, b"A": MAGIC_ARRAY, b"V": MAGIC_VARRAY}

_B64_LINE = 76
_LINE_BREAK = {spec.UNIX: b"=\n", spec.MIME: b"\r\n"}

#: zlib level.  The paper recommends Z_BEST_COMPRESSION (9); §Perf
#: checkpoint-I/O iteration CK2 measured level 6 at 12x the deflate
#: throughput of level 9 at IDENTICAL ratio on checkpoint-like payloads
#: (level 9 burns its time on the incompressible half), so the library
#: default is 6 (REPRO_ZLIB_LEVEL overrides; 9 reproduces the paper's
#: recommendation, 0 is legal for zlib-free writers).
DEFAULT_LEVEL = int(_os.environ.get("REPRO_ZLIB_LEVEL", "6"))

#: Streaming chunk size for the compressobj/decompressobj loops.
_ZLIB_CHUNK = 1 << 20

#: Below this many encoded bytes the numpy reshape costs more than the loop.
_NP_MIN_BYTES = 1 << 10

#: Thread-pool policy for compress_elements: worth it only past real work.
_POOL_MIN_ELEMENTS = 4
_POOL_MIN_BYTES = 1 << 20
def _default_pool_width() -> int:
    return int(_os.environ.get("REPRO_CODEC_THREADS", "0")) \
        or min(8, _os.cpu_count() or 1)


_POOL_THREADS = _default_pool_width()
_pool = None
_pool_lock = _threading.Lock()


def _deflate(view: memoryview, level: int) -> List[bytes]:
    c = zlib.compressobj(level)
    parts = [c.compress(view[i:i + _ZLIB_CHUNK])
             for i in range(0, len(view), _ZLIB_CHUNK)]
    parts.append(c.flush())
    return parts


def _break_lines(encoded: bytes, style: str) -> bytes:
    """Split base64 output into 76-byte lines, each followed by the 2-byte
    break; "the same two bytes are added after the last line of encoding if
    it is short of 76 bytes" — a full final line already has its break, so
    an exact multiple of 76 ends with exactly one break."""
    brk = _LINE_BREAK[style]
    L = len(encoded)
    if L == 0:  # zero-byte stage1 cannot happen (≥ 9 bytes), but be safe
        return brk
    full, rem = divmod(L, _B64_LINE)
    if _np is None or L < _NP_MIN_BYTES:
        lines: List[bytes] = []
        for i in range(0, L, _B64_LINE):
            lines.append(encoded[i:i + _B64_LINE])
            lines.append(brk)
        return b"".join(lines)
    out = _np.empty((full, _B64_LINE + 2), _np.uint8)
    out[:, :_B64_LINE] = _np.frombuffer(
        encoded, _np.uint8, full * _B64_LINE).reshape(full, _B64_LINE)
    out[:, _B64_LINE] = brk[0]
    out[:, _B64_LINE + 1] = brk[1]
    head = out.tobytes()
    if rem:
        return head + encoded[full * _B64_LINE:] + brk
    return head


def _unbreak_lines(stream: bytes) -> bytes:
    """Strip the 2 break bytes after each ≤76-byte line (geometry only —
    the break bytes are "arbitrary" per §3.1, so their value is not
    validated)."""
    L = len(stream)
    step = _B64_LINE + 2
    nfull, rem = divmod(L, step)
    if rem == 0:
        if _np is None or L < _NP_MIN_BYTES:
            return b"".join(stream[i:i + _B64_LINE]
                            for i in range(0, L, step))
        return _np.frombuffer(stream, _np.uint8).reshape(
            nfull, step)[:, :_B64_LINE].tobytes()
    if rem < 3:  # a chunk must hold ≥ 1 code byte + 2 break bytes
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "truncated base64 line")
    tail = stream[nfull * step:L - 2]
    if nfull == 0:
        return tail
    if _np is None or L < _NP_MIN_BYTES:
        return b"".join(stream[i:i + _B64_LINE]
                        for i in range(0, nfull * step, step)) + tail
    head = _np.frombuffer(stream, _np.uint8, nfull * step).reshape(
        nfull, step)[:, :_B64_LINE].tobytes()
    return head + tail


def _fast_stage1(stream: bytes) -> Optional[bytes]:
    """One-pass stage-2 decode for streams with exact line geometry and a
    STANDARD break pair ("=\\n" or "\\r\\n") after every line.

    ``binascii.a2b_base64`` in lenient mode skips both standard break
    pairs in-stream (each falls on a 4-char quad boundary, where a
    padding or invalid byte is a no-op), so verifying the geometry up
    front — one vectorized check of the two break columns — lets us
    decode in a single pass without first copying the code bytes out.
    Returns None for anything unusual (odd geometry, exotic break bytes,
    lenient decoder complaints): the caller then runs the reference
    unbreak-then-strict-decode path, whose errors remain canonical.
    """
    L = len(stream)
    step = _B64_LINE + 2
    nfull, rem = divmod(L, step)
    if nfull == 0 or (rem != 0 and rem < 3):
        return None
    arr = _np.frombuffer(stream, _np.uint8, nfull * step).reshape(
        nfull, step)
    b0, b1 = arr[:, _B64_LINE], arr[:, _B64_LINE + 1]
    for brk in (_LINE_BREAK[spec.UNIX], _LINE_BREAK[spec.MIME]):
        if (b0 == brk[0]).all() and (b1 == brk[1]).all():
            if rem and stream[L - 2:] != brk:
                return None
            break
    else:
        return None
    try:
        # a2b_base64 takes any bytes-like buffer — no copy for the
        # zero-copy memoryviews the prefetch cache serves.
        return binascii.a2b_base64(stream)
    except (binascii.Error, ValueError):
        return None  # strict path reports the canonical error


def deflate_stage1(data: BytesLike, level: int = DEFAULT_LEVEL) -> bytes:
    """Stage 1 of §3.1: 8-byte big-endian size ‖ ``'z'`` ‖ deflate stream.

    Pure zlib after the 9-byte header — releases the GIL for the whole
    deflate, which is why :func:`submit_compress_batch` jobs run exactly
    this and nothing else."""
    view = memoryview(data)
    if view.format != "B" or view.ndim != 1:
        view = view.cast("B")
    stage1_parts = [struct.pack(">Q", len(view)) + b"z"]
    stage1_parts += _deflate(view, level)
    return b"".join(stage1_parts)


def encode_stage2(stage1: BytesLike, style: str = spec.UNIX) -> bytes:
    """Stage 2 of §3.1: base64 with 76-byte lines + 2-byte breaks."""
    encoded = base64.b64encode(stage1)
    return _break_lines(encoded, style)


def compress(data: BytesLike, style: str = spec.UNIX,
             level: int = DEFAULT_LEVEL) -> bytes:
    """Apply the two-stage §3.1 algorithm to one data item."""
    return encode_stage2(deflate_stage1(data, level), style)


def _parse_stage2(stream: bytes, fast: bool = False):
    """Stage-2 + stage-1-header decode: ``(usize, deflate_body_view)``.

    Splitting the decode from :func:`_inflate_checked` lets the
    overlapped restore engine keep pool jobs as single long
    GIL-releasing inflate calls.  ``fast`` additionally routes the
    base64 decode through :func:`_fast_stage1` (single-pass lenient
    decode, byte-identical, strict fallback) — used by the batch/pool
    entry points; ``decompress`` itself stays on the reference path,
    which is the serial oracle and the canonical error reporter.
    """
    if len(stream) < 2:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"stage-2 stream only {len(stream)} bytes")
    stage1 = None
    if fast and _np is not None and len(stream) >= _NP_MIN_BYTES:
        stage1 = _fast_stage1(stream)
    if stage1 is None:
        # reference path wants bytes (views arrive from the zero-copy
        # prefetch cache and would not concatenate with bytes below)
        if not isinstance(stream, bytes):
            stream = bytes(stream)
        code = _unbreak_lines(stream)
        try:
            stage1 = base64.b64decode(code, validate=True)
        except Exception as e:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"base64 decode failed: {e}") from e
    if len(stage1) < 9:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"stage-1 stream only {len(stage1)} bytes")
    head = stage1[:9]
    (usize,) = struct.unpack(">Q", head[:8])
    if head[8:9] != b"z":
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"missing 'z' marker, got {head[8:9]!r}")
    return usize, memoryview(stage1)[9:]


def _inflate_checked(usize: int, body) -> bytes:
    """Inflate a stage-1 body and enforce the three redundant §3.1 checks
    (adler32 inside zlib, size match, 'z' already checked by the parse).
    Pure zlib — releases the GIL for the whole inflate."""
    d = zlib.decompressobj()
    try:
        parts = [d.decompress(body)]
        parts.append(d.flush())  # adler32 verified inside zlib at stream end
    except zlib.error as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM, str(e)) from e
    if not d.eof:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        "incomplete or truncated deflate stream")
    raw = parts[0] if not parts[1] else b"".join(parts)
    if len(raw) != usize:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"inflated {len(raw)} bytes, header says {usize}")
    return raw


def _inflate_canonical(usize: int, body, stream: BytesLike) -> bytes:
    """:func:`_inflate_checked`, but any failure defers to the serial
    oracle.  The fast lenient base64 decode accepts some corrupted
    streams the strict decoder rejects (``a2b_base64`` silently *skips*
    bytes outside the alphabet), so a bad stream can sail through the
    parse and only blow up at inflate — as CORRUPT_CHECKSUM, where the
    reference path reports CORRUPT_ENCODING.  Re-running ``decompress``
    on the original stream makes the serial path the sole authority on
    both the outcome and the error; the retry only ever runs on corrupt
    archives, so the happy path pays nothing.
    """
    try:
        return _inflate_checked(usize, body)
    except ScdaError:
        return decompress(stream)


def decompress(stream: bytes) -> bytes:
    """Invert :func:`compress`; enforce the three redundant checks (§3.1).

    The stage-2 stream has exact structure: zero or more chunks of 76 code
    bytes + 2 break bytes, with the final chunk allowed to be shorter
    (r code bytes + 2 break bytes, 0 < r ≤ 76).
    """
    usize, body = _parse_stage2(stream)
    return _inflate_checked(usize, body)


def _get_pool():
    global _pool
    if _pool is None:
        with _pool_lock:  # every ThreadComm rank may race the first use
            if _pool is None:
                from concurrent.futures import ThreadPoolExecutor
                _pool = ThreadPoolExecutor(max_workers=_POOL_THREADS,
                                           thread_name_prefix="scda-codec")
    return _pool


def compress_elements(elements: Sequence[BytesLike],
                      style: str = spec.UNIX,
                      level: int = DEFAULT_LEVEL) -> List[bytes]:
    """Per-element compression for array sections (§3.3/§3.4).

    Elements are independent deflate streams, so they parallelize
    perfectly; zlib releases the GIL, so a thread pool gives real
    speedup.  Small batches stay serial (pool dispatch costs more).
    """
    if (_POOL_THREADS > 1 and len(elements) >= _POOL_MIN_ELEMENTS
            and sum(map(len, elements)) >= _POOL_MIN_BYTES):
        return list(_get_pool().map(
            lambda e: compress(e, style, level), elements))
    return [compress(e, style, level) for e in elements]


def decompress_elements(streams: Sequence[BytesLike],
                        expected_sizes: Optional[Sequence[int]] = None) \
        -> List[bytes]:
    """Per-element decompression for array sections (§3.3/§3.4).

    The read mirror of :func:`compress_elements`: independent deflate
    streams inflate in parallel on the shared pool (zlib releases the
    GIL); small batches stay serial.  ``expected_sizes`` optionally
    enforces each element's uncompressed size (the U-entry check every
    serial read path performs), raising CORRUPT_CHECKSUM on mismatch.
    """
    if (_POOL_THREADS > 1 and len(streams) >= _POOL_MIN_ELEMENTS
            and sum(map(len, streams)) >= _POOL_MIN_BYTES):
        # fast decode here on the calling thread, long GIL-free inflates
        # on the pool — the split that actually scales (see
        # submit_decompress_batch)
        parsed = [_parse_stage2(s, fast=True) for s in streams]
        out = list(_get_pool().map(
            lambda t: _inflate_canonical(t[0][0], t[0][1], t[1]),
            zip(parsed, streams)))
    else:
        out = [decompress(s) for s in streams]
    if expected_sizes is not None:
        for i, (raw, expect) in enumerate(zip(out, expected_sizes)):
            if len(raw) != expect:
                raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                                f"element {i} inflated to {len(raw)}, "
                                f"U-entry says {expect}")
    return out


def submit_decompress_batch(streams: Sequence[BytesLike],
                            expected_sizes: Optional[Sequence[int]] = None):
    """Decode + inflate a batch of streams in ONE pool job; returns a
    Future resolving to the list of raw payloads.

    Work splits for GIL hygiene, measured every other way on the restore
    bench: the stage-2 decode (fast single-pass, GIL-held but brief)
    runs HERE on the submitting thread in long uninterrupted stretches,
    and the pool job is back-to-back GIL-releasing inflates.  Per-chunk
    futures, decode-in-job, and a numpy GIL-free decode all measured
    slower — worker wakeups and short GIL slices make the threads fight
    for the lock instead of overlapping.  Parse errors raise
    synchronously; inflate errors (and ``expected_sizes`` mismatches)
    surface on ``result()`` — all exactly the :class:`ScdaError` the
    serial :func:`decompress` would raise.
    """
    parsed = [_parse_stage2(s, fast=True) for s in streams]

    def _job() -> List[bytes]:
        out = []
        for j, (usize, body) in enumerate(parsed):
            raw = _inflate_canonical(usize, body, streams[j])
            if expected_sizes is not None and len(raw) != expected_sizes[j]:
                raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                                f"element inflated to {len(raw)}, "
                                f"U-entry says {expected_sizes[j]}")
            out.append(raw)
        return out

    c = _trace.collector()
    if c is not None:
        inner = _job
        nbytes = sum(map(len, streams))

        def _job() -> List[bytes]:  # noqa: F811 - traced worker-side span
            with c.span("inflate", "codec",
                        elements=len(parsed), bytes=nbytes):
                return inner()

    return _get_pool().submit(_job)


def submit_compress_batch(payloads: Sequence[BytesLike],
                          level: int = DEFAULT_LEVEL):
    """Deflate a batch of payloads in ONE pool job; returns a Future
    resolving to the list of stage-1 bodies (size header + 'z' + deflate
    stream).

    The write mirror of :func:`submit_decompress_batch`, with the same
    GIL discipline inverted: the pool job is back-to-back GIL-releasing
    deflates and nothing else; the submitting thread finishes each body
    with :func:`encode_stage2` (base64 + numpy line breaking — brief,
    GIL-held) when the future resolves, so worker wakeups never fight
    the caller for the lock.  ``encode_stage2(fut.result()[j], style)``
    is byte-identical to ``compress(payloads[j], style, level)`` by
    construction — :func:`compress` is those two calls.
    """
    views = [memoryview(p) for p in payloads]  # pin callers' buffers

    def _job() -> List[bytes]:
        return [deflate_stage1(v, level) for v in views]

    c = _trace.collector()
    if c is not None:
        inner = _job
        nbytes = sum(v.nbytes for v in views)

        def _job() -> List[bytes]:  # noqa: F811 - traced worker-side span
            with c.span("deflate", "codec",
                        elements=len(views), bytes=nbytes):
                return inner()

    return _get_pool().submit(_job)


def submit_task(fn, *args):
    """Run ``fn(*args)`` on the shared codec pool; returns the Future.

    Used by the overlapped save engine for its device→host snapshot
    lookahead (one leaf ahead — a double buffer, not a fan-out), so the
    rare non-codec job rides the existing pool instead of paying for a
    dedicated thread."""
    return _get_pool().submit(fn, *args)


def pool_width() -> int:
    """The codec pool's thread count (the engine sizes its in-flight
    inflate queue from this)."""
    return _POOL_THREADS


def set_pool_width(n: Optional[int]) -> int:
    """Override the pool-dispatch width at runtime; returns the previous
    value.  ``None`` re-reads ``REPRO_CODEC_THREADS``/cpu count.

    Bench/test hook (the runtime twin of the env knob): ``1`` makes
    every ``*_elements`` call run inline on the caller — the fully
    serial codec the save/restore benchmarks use as their baseline.  An
    already-created pool keeps its workers; only dispatch policy
    changes.
    """
    global _POOL_THREADS
    prev = _POOL_THREADS
    if n is None:
        n = _default_pool_width()
    _POOL_THREADS = max(1, int(n))
    return prev


def uncompressed_size_entry(u: int, style: str = spec.UNIX) -> bytes:
    """The 32-byte 'U' entry of Fig. 6 / Fig. 7."""
    return spec.count_entry(b"U", u, style)


def parse_uncompressed_size_entry(entry: bytes) -> int:
    return spec.parse_count_entry(entry, b"U")
