"""Per-element transparent compression convention (paper §3).

Two-stage algorithm (§3.1), applied to a block's data or to each array
element independently:

  stage 1:  8-byte big-endian uncompressed size ‖ b'z' ‖ RFC1950/1951
            deflate stream (any legal level; we default to zlib level 9,
            the paper's recommendation of "zlib's best compression").
  stage 2:  base64, broken into lines of 76 code bytes + a 2-byte break
            ("=\n" Unix, "\r\n" MIME), including after the final short line.

On reading, the length is known from file context; base64-decode, read the
size from the first 8 bytes, check the 'z' tag at byte 9, inflate, and verify
the three redundant checks (§3.1): the adler32 inside zlib, the size match,
and the 'z' marker.

Convention magic user strings (§3.2–3.4), version (00)₁₆:
  block        : I("B compressed scda 00", U-entry) ; B(user, compressed)
  fixed array  : I("A compressed scda 00", U-entry) ; V(user, N, compressed…)
  var. array   : A("V compressed scda 00", N, 32, U-entries) ; V(user, N, …)

Fast-path implementation (byte-identical to the reference algorithm):

* compress/decompress run zlib via streaming ``compressobj`` /
  ``decompressobj`` in bounded chunks and accept any buffer view (no
  up-front ``bytes()`` copy of the payload);
* stage-2 line breaking / unbreaking is vectorized with a numpy reshape
  instead of a Python loop over 76-byte lines;
* :func:`compress_elements` fans independent elements out over a thread
  pool (zlib releases the GIL) once the payload is large enough;
  ``REPRO_CODEC_THREADS`` tunes the width, ``1`` disables.
"""
from __future__ import annotations

import base64
import os as _os
import struct
import threading as _threading
import zlib
from typing import List, Optional, Sequence, Union

try:
    import numpy as _np
except ImportError:  # pragma: no cover - numpy is a hard dep elsewhere
    _np = None

from repro.core import spec
from repro.core.errors import ScdaError, ScdaErrorCode

BytesLike = Union[bytes, bytearray, memoryview]

#: Magic user strings identifying the compression convention (§3.2).
MAGIC_BLOCK = b"B compressed scda 00"
MAGIC_ARRAY = b"A compressed scda 00"
MAGIC_VARRAY = b"V compressed scda 00"
MAGIC_BY_TYPE = {b"B": MAGIC_BLOCK, b"A": MAGIC_ARRAY, b"V": MAGIC_VARRAY}

_B64_LINE = 76
_LINE_BREAK = {spec.UNIX: b"=\n", spec.MIME: b"\r\n"}

#: zlib level.  The paper recommends Z_BEST_COMPRESSION (9); §Perf
#: checkpoint-I/O iteration CK2 measured level 6 at 12x the deflate
#: throughput of level 9 at IDENTICAL ratio on checkpoint-like payloads
#: (level 9 burns its time on the incompressible half), so the library
#: default is 6 (REPRO_ZLIB_LEVEL overrides; 9 reproduces the paper's
#: recommendation, 0 is legal for zlib-free writers).
DEFAULT_LEVEL = int(_os.environ.get("REPRO_ZLIB_LEVEL", "6"))

#: Streaming chunk size for the compressobj/decompressobj loops.
_ZLIB_CHUNK = 1 << 20

#: Below this many encoded bytes the numpy reshape costs more than the loop.
_NP_MIN_BYTES = 1 << 10

#: Thread-pool policy for compress_elements: worth it only past real work.
_POOL_MIN_ELEMENTS = 4
_POOL_MIN_BYTES = 1 << 20
_POOL_THREADS = int(_os.environ.get("REPRO_CODEC_THREADS", "0")) \
    or min(8, _os.cpu_count() or 1)
_pool = None
_pool_lock = _threading.Lock()


def _deflate(view: memoryview, level: int) -> List[bytes]:
    c = zlib.compressobj(level)
    parts = [c.compress(view[i:i + _ZLIB_CHUNK])
             for i in range(0, len(view), _ZLIB_CHUNK)]
    parts.append(c.flush())
    return parts


def _break_lines(encoded: bytes, style: str) -> bytes:
    """Split base64 output into 76-byte lines, each followed by the 2-byte
    break; "the same two bytes are added after the last line of encoding if
    it is short of 76 bytes" — a full final line already has its break, so
    an exact multiple of 76 ends with exactly one break."""
    brk = _LINE_BREAK[style]
    L = len(encoded)
    if L == 0:  # zero-byte stage1 cannot happen (≥ 9 bytes), but be safe
        return brk
    full, rem = divmod(L, _B64_LINE)
    if _np is None or L < _NP_MIN_BYTES:
        lines: List[bytes] = []
        for i in range(0, L, _B64_LINE):
            lines.append(encoded[i:i + _B64_LINE])
            lines.append(brk)
        return b"".join(lines)
    out = _np.empty((full, _B64_LINE + 2), _np.uint8)
    out[:, :_B64_LINE] = _np.frombuffer(
        encoded, _np.uint8, full * _B64_LINE).reshape(full, _B64_LINE)
    out[:, _B64_LINE] = brk[0]
    out[:, _B64_LINE + 1] = brk[1]
    head = out.tobytes()
    if rem:
        return head + encoded[full * _B64_LINE:] + brk
    return head


def _unbreak_lines(stream: bytes) -> bytes:
    """Strip the 2 break bytes after each ≤76-byte line (geometry only —
    the break bytes are "arbitrary" per §3.1, so their value is not
    validated)."""
    L = len(stream)
    step = _B64_LINE + 2
    nfull, rem = divmod(L, step)
    if rem == 0:
        if _np is None or L < _NP_MIN_BYTES:
            return b"".join(stream[i:i + _B64_LINE]
                            for i in range(0, L, step))
        return _np.frombuffer(stream, _np.uint8).reshape(
            nfull, step)[:, :_B64_LINE].tobytes()
    if rem < 3:  # a chunk must hold ≥ 1 code byte + 2 break bytes
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "truncated base64 line")
    tail = stream[nfull * step:L - 2]
    if nfull == 0:
        return tail
    if _np is None or L < _NP_MIN_BYTES:
        return b"".join(stream[i:i + _B64_LINE]
                        for i in range(0, nfull * step, step)) + tail
    head = _np.frombuffer(stream, _np.uint8, nfull * step).reshape(
        nfull, step)[:, :_B64_LINE].tobytes()
    return head + tail


def compress(data: BytesLike, style: str = spec.UNIX,
             level: int = DEFAULT_LEVEL) -> bytes:
    """Apply the two-stage §3.1 algorithm to one data item."""
    view = memoryview(data)
    if view.format != "B" or view.ndim != 1:
        view = view.cast("B")
    stage1_parts = [struct.pack(">Q", len(view)) + b"z"]
    stage1_parts += _deflate(view, level)
    encoded = base64.b64encode(b"".join(stage1_parts))
    return _break_lines(encoded, style)


def decompress(stream: bytes) -> bytes:
    """Invert :func:`compress`; enforce the three redundant checks (§3.1).

    The stage-2 stream has exact structure: zero or more chunks of 76 code
    bytes + 2 break bytes, with the final chunk allowed to be shorter
    (r code bytes + 2 break bytes, 0 < r ≤ 76).
    """
    if len(stream) < 2:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"stage-2 stream only {len(stream)} bytes")
    code = _unbreak_lines(stream)
    try:
        stage1 = base64.b64decode(code, validate=True)
    except Exception as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"base64 decode failed: {e}") from e
    if len(stage1) < 9:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"stage-1 stream only {len(stage1)} bytes")
    (usize,) = struct.unpack(">Q", stage1[:8])
    if stage1[8:9] != b"z":
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"missing 'z' marker, got {stage1[8:9]!r}")
    body = memoryview(stage1)[9:]
    d = zlib.decompressobj()
    try:
        parts = [d.decompress(body[i:i + _ZLIB_CHUNK])
                 for i in range(0, len(body), _ZLIB_CHUNK)]
        parts.append(d.flush())  # adler32 verified inside zlib at stream end
    except zlib.error as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM, str(e)) from e
    if not d.eof:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        "incomplete or truncated deflate stream")
    raw = b"".join(parts)
    if len(raw) != usize:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"inflated {len(raw)} bytes, header says {usize}")
    return raw


def _get_pool():
    global _pool
    if _pool is None:
        with _pool_lock:  # every ThreadComm rank may race the first use
            if _pool is None:
                from concurrent.futures import ThreadPoolExecutor
                _pool = ThreadPoolExecutor(max_workers=_POOL_THREADS,
                                           thread_name_prefix="scda-codec")
    return _pool


def compress_elements(elements: Sequence[BytesLike],
                      style: str = spec.UNIX,
                      level: int = DEFAULT_LEVEL) -> List[bytes]:
    """Per-element compression for array sections (§3.3/§3.4).

    Elements are independent deflate streams, so they parallelize
    perfectly; zlib releases the GIL, so a thread pool gives real
    speedup.  Small batches stay serial (pool dispatch costs more).
    """
    if (_POOL_THREADS > 1 and len(elements) >= _POOL_MIN_ELEMENTS
            and sum(map(len, elements)) >= _POOL_MIN_BYTES):
        return list(_get_pool().map(
            lambda e: compress(e, style, level), elements))
    return [compress(e, style, level) for e in elements]


def uncompressed_size_entry(u: int, style: str = spec.UNIX) -> bytes:
    """The 32-byte 'U' entry of Fig. 6 / Fig. 7."""
    return spec.count_entry(b"U", u, style)


def parse_uncompressed_size_entry(entry: bytes) -> int:
    return spec.parse_count_entry(entry, b"U")
