"""Per-element transparent compression convention (paper §3).

Two-stage algorithm (§3.1), applied to a block's data or to each array
element independently:

  stage 1:  8-byte big-endian uncompressed size ‖ b'z' ‖ RFC1950/1951
            deflate stream (any legal level; we default to zlib level 9,
            the paper's recommendation of "zlib's best compression").
  stage 2:  base64, broken into lines of 76 code bytes + a 2-byte break
            ("=\n" Unix, "\r\n" MIME), including after the final short line.

On reading, the length is known from file context; base64-decode, read the
size from the first 8 bytes, check the 'z' tag at byte 9, inflate, and verify
the three redundant checks (§3.1): the adler32 inside zlib, the size match,
and the 'z' marker.

Convention magic user strings (§3.2–3.4), version (00)₁₆:
  block        : I("B compressed scda 00", U-entry) ; B(user, compressed)
  fixed array  : I("A compressed scda 00", U-entry) ; V(user, N, compressed…)
  var. array   : A("V compressed scda 00", N, 32, U-entries) ; V(user, N, …)
"""
from __future__ import annotations

import base64
import struct
import zlib
from typing import List, Sequence

from repro.core import spec
from repro.core.errors import ScdaError, ScdaErrorCode

#: Magic user strings identifying the compression convention (§3.2).
MAGIC_BLOCK = b"B compressed scda 00"
MAGIC_ARRAY = b"A compressed scda 00"
MAGIC_VARRAY = b"V compressed scda 00"
MAGIC_BY_TYPE = {b"B": MAGIC_BLOCK, b"A": MAGIC_ARRAY, b"V": MAGIC_VARRAY}

_B64_LINE = 76
_LINE_BREAK = {spec.UNIX: b"=\n", spec.MIME: b"\r\n"}

#: zlib level.  The paper recommends Z_BEST_COMPRESSION (9); §Perf
#: checkpoint-I/O iteration CK2 measured level 6 at 12x the deflate
#: throughput of level 9 at IDENTICAL ratio on checkpoint-like payloads
#: (level 9 burns its time on the incompressible half), so the library
#: default is 6 (REPRO_ZLIB_LEVEL overrides; 9 reproduces the paper's
#: recommendation, 0 is legal for zlib-free writers).
import os as _os
DEFAULT_LEVEL = int(_os.environ.get("REPRO_ZLIB_LEVEL", "6"))


def compress(data: bytes, style: str = spec.UNIX,
             level: int = DEFAULT_LEVEL) -> bytes:
    """Apply the two-stage §3.1 algorithm to one data item."""
    stage1 = struct.pack(">Q", len(data)) + b"z" + zlib.compress(data, level)
    encoded = base64.b64encode(stage1)
    brk = _LINE_BREAK[style]
    lines: List[bytes] = []
    for i in range(0, len(encoded), _B64_LINE):
        lines.append(encoded[i:i + _B64_LINE])
        lines.append(brk)
    if not encoded:  # zero-byte stage1 cannot happen (≥ 9 bytes), but be safe
        lines.append(brk)
    # "The same two bytes are added after the last line of encoding if it is
    # short of 76 bytes." — a full final line already got its break above; an
    # exact multiple of 76 therefore ends with exactly one break.
    return b"".join(lines)


def decompress(stream: bytes) -> bytes:
    """Invert :func:`compress`; enforce the three redundant checks (§3.1).

    The stage-2 stream has exact structure: zero or more chunks of 76 code
    bytes + 2 break bytes, with the final chunk allowed to be shorter
    (r code bytes + 2 break bytes, 0 < r ≤ 76).  The 2 break bytes are
    "arbitrary" per §3.1, so we validate only the geometry, not their value.
    """
    if len(stream) < 2:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"stage-2 stream only {len(stream)} bytes")
    code = bytearray()
    i, L = 0, len(stream)
    while i < L:
        chunk = stream[i:i + _B64_LINE + 2]
        if len(chunk) < 3:  # a chunk must hold ≥ 1 code byte + 2 break bytes
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            "truncated base64 line")
        code += chunk[:-2]
        i += len(chunk)
    try:
        stage1 = base64.b64decode(bytes(code), validate=True)
    except Exception as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"base64 decode failed: {e}") from e
    if len(stage1) < 9:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"stage-1 stream only {len(stage1)} bytes")
    (usize,) = struct.unpack(">Q", stage1[:8])
    if stage1[8:9] != b"z":
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        f"missing 'z' marker, got {stage1[8:9]!r}")
    try:
        raw = zlib.decompress(stage1[9:])  # adler32 verified inside zlib
    except zlib.error as e:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM, str(e)) from e
    if len(raw) != usize:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"inflated {len(raw)} bytes, header says {usize}")
    return raw


def compress_elements(elements: Sequence[bytes], style: str = spec.UNIX,
                      level: int = DEFAULT_LEVEL) -> List[bytes]:
    """Per-element compression for array sections (§3.3/§3.4)."""
    return [compress(e, style, level) for e in elements]


def uncompressed_size_entry(u: int, style: str = spec.UNIX) -> bytes:
    """The 32-byte 'U' entry of Fig. 6 / Fig. 7."""
    return spec.count_entry(b"U", u, style)


def parse_uncompressed_size_entry(entry: bytes) -> int:
    return spec.parse_count_entry(entry, b"U")
