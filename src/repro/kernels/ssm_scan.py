"""Selective-scan (Mamba recurrence) Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: instead of a warp-parallel
recurrence, the SSM state h (d_block × N) lives in VMEM scratch and
persists across a *sequential* chunk grid dimension — HBM traffic is one
read of (decay, inc, C) and one write of y, while the recurrence itself
runs at VMEM/VREG speed.  The channel dimension is tiled (d_block) so the
working set fits VMEM; channels are embarrassingly parallel, which is also
the axis the model shards with TP.

    h_t = decay_t ⊙ h_{t-1} + inc_t        (d_block, N) per step
    y_t = Σ_n h_t[:, n] · C_t[n]

Grid: (batch, d_blocks, chunks) — chunks innermost & sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _ssm_kernel(decay_ref, inc_ref, c_ref, y_ref, h_ref, *, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        h = decay_ref[0, t] * h + inc_ref[0, t]          # (bd, N)
        y_ref[0, t] = jnp.sum(h * c_ref[0, t][None, :], axis=-1)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def ssm_scan_kernel(decay, inc, C, *, chunk: int = 128,
                    d_block: int = 256, interpret: bool = False):
    """decay/inc: (B, S, d, N) f32; C: (B, S, N) f32 → y: (B, S, d).

    The recurrence runs in f32 regardless of input dtype (state stability);
    S must divide by ``chunk`` (pad upstream), d by ``d_block`` (clamped).
    """
    B, S, d, N = decay.shape
    chunk = min(chunk, S)
    d_block = min(d_block, d)
    assert S % chunk == 0, (S, chunk)
    assert d % d_block == 0, (d, d_block)
    nc = S // chunk
    nd = d // d_block

    return pl.pallas_call(
        functools.partial(_ssm_kernel, chunk=chunk),
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block, N),
                         lambda b, dblk, c: (b, c, dblk, 0)),
            pl.BlockSpec((1, chunk, d_block, N),
                         lambda b, dblk, c: (b, c, dblk, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, dblk, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block),
                               lambda b, dblk, c: (b, c, dblk)),
        out_shape=jax.ShapeDtypeStruct((B, S, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_block, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(decay.astype(jnp.float32), inc.astype(jnp.float32),
      C.astype(jnp.float32))
