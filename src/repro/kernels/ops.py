"""Jitted public wrappers for the Pallas kernels.

``use_pallas`` selects the kernel (TPU) or the pure-XLA fallback (CPU and
the dry-run path, whose HLO mirrors the same chunked access pattern).  On
CPU the kernels run with interpret=True — that is how the test suite
validates them against the ``ref`` oracles.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.ssm_scan import ssm_scan_kernel


def default_backend_is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool = False, interpret: bool = True,
                    block_q: int = 128, block_k: int = 128):
    """(B, H, S, D) attention; kernel or oracle path, identical semantics."""
    if use_pallas:
        return flash_attention_kernel(q, k, v, causal=causal, window=window,
                                      block_q=block_q, block_k=block_k,
                                      interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


@partial(jax.jit, static_argnames=("chunk", "d_block", "use_pallas",
                                   "interpret"))
def ssm_scan(decay, inc, C, *, chunk: int = 128, d_block: int = 256,
             use_pallas: bool = False, interpret: bool = True):
    """(B, S, d, N) selective scan; kernel or oracle path."""
    if use_pallas:
        return ssm_scan_kernel(decay, inc, C, chunk=chunk, d_block=d_block,
                               interpret=interpret)
    return ref.ssm_scan_ref(decay, inc, C)
