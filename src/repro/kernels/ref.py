"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive softmax attention; q: (B, H, Sq, D); k/v: (B, Hkv, Skv, D)."""
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window > 0:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)  # rows with no valid key → all-zero output
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ssm_scan_ref(decay, inc, C):
    """Sequential SSM recurrence; decay/inc: (B,S,d,N); C: (B,S,N)."""
    decay = decay.astype(jnp.float32)
    inc = inc.astype(jnp.float32)
    C = C.astype(jnp.float32)
    B, S, d, N = decay.shape

    def step(h, xs):
        dec, ic, c = xs
        h = dec * h + ic
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    h0 = jnp.zeros((B, d, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (jnp.moveaxis(decay, 1, 0),
                                    jnp.moveaxis(inc, 1, 0),
                                    jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)  # (B, S, d)
