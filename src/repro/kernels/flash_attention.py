"""Flash attention Pallas TPU kernel.

TPU adaptation of the flash-attention access pattern: q blocks stay
resident in VMEM while k/v stream through in MXU-aligned (block_k × d)
tiles; online-softmax statistics (m, l) and the f32 accumulator live in
VMEM scratch that persists across the sequential kv grid dimension.  GQA is
handled in the k/v index_map (kv head = q head // group) — no repeated kv
in HBM at all, improving on the XLA fallback path which repeats per chunk.

Grid: (batch, q_heads, q_blocks, kv_blocks), kv innermost & sequential.
Block shapes are (1, 1, block_q, d) / (1, 1, block_k, d) — multiples of
128 on the sequence dims for MXU alignment at production sizes; the
interpret-mode tests sweep smaller shapes for correctness.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, kv_len: int, causal: bool,
                  window: int, scale: float):
    i = pl.program_id(2)           # q block
    j = pl.program_id(3)           # kv block (sequential)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                # (block_q, d)
    k = k_ref[0, 0]                # (block_k, d)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (block_q, block_k)

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kv_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = kv_pos < kv_len
    if causal:
        mask &= kv_pos <= q_pos
    if window > 0:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D), H % Hkv == 0."""
    B, H, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_k

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          kv_len=Skv, causal=causal, window=window,
                          scale=scale),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Sq, :]
    return out
