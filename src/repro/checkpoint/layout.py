"""Shard → contiguous-run decomposition.

scda assumes contiguous indexed partitions of the element stream (paper §1:
"we assume nothing but a contiguous indexed partition").  A tensor sharded
over a multi-axis device mesh gives each device a rectangular block that is
generally *not* contiguous in the canonical row-major byte stream; it is,
however, a union of contiguous runs.  We decompose every shard into its runs
and write/read each run as a window of the leaf's A section — the file bytes
stay canonical row-major, hence partition-independent, while every device
performs only positioned I/O on its own data (the paper's `indirect`
addressing, generalized from "list of element pointers" to "list of element
ranges").
"""
from __future__ import annotations

import itertools
import math
from typing import List, Sequence, Tuple

#: (global_byte_offset, local_byte_offset, byte_length)
Run = Tuple[int, int, int]


def _normalize(global_shape: Sequence[int], index) -> Tuple[List[int], List[int]]:
    """Resolve a tuple-of-slices shard index → (starts, extents)."""
    starts, extents = [], []
    for dim, sl in zip(global_shape, index):
        if isinstance(sl, slice):
            start, stop, step = sl.indices(dim)
            if step != 1:
                raise ValueError("strided shard slices are unsupported")
        else:  # integer index (should not occur for jax shards)
            start, stop = int(sl), int(sl) + 1
        starts.append(start)
        extents.append(max(0, stop - start))
    return starts, extents


def shard_runs(global_shape: Sequence[int], index,
               itemsize: int) -> List[Run]:
    """Contiguous row-major runs of the shard ``index`` of a global tensor.

    Returns runs ordered by local (shard-buffer) offset, which for
    rectangular blocks is also global-offset order.
    """
    global_shape = list(global_shape)
    nd = len(global_shape)
    if nd == 0:  # scalar
        return [(0, 0, itemsize)]
    if index is None or len(index) == 0:
        index = tuple(slice(0, d) for d in global_shape)
    starts, extents = _normalize(global_shape, index)
    if any(e == 0 for e in extents) or any(d == 0 for d in global_shape):
        return []
    # Largest full suffix: dims j > k with the shard spanning the whole dim.
    k = nd - 1
    while k >= 0 and starts[k] == 0 and extents[k] == global_shape[k]:
        k -= 1
    if k < 0:  # shard is the whole tensor
        return [(0, 0, math.prod(global_shape) * itemsize)]
    # One run covers dim k's extent times all trailing (full) dims.
    trailing = math.prod(global_shape[k + 1:])
    run_bytes = extents[k] * trailing * itemsize
    # Global row-major element strides.
    strides = [0] * nd
    acc = 1
    for j in range(nd - 1, -1, -1):
        strides[j] = acc
        acc *= global_shape[j]
    runs: List[Run] = []
    local = 0
    for multi in itertools.product(*(range(e) for e in extents[:k])):
        gelem = sum((starts[j] + multi[j]) * strides[j] for j in range(k))
        gelem += starts[k] * strides[k]
        runs.append((gelem * itemsize, local, run_bytes))
        local += run_bytes
    return runs


def runs_cover_exactly(runs_by_owner: Sequence[Sequence[Run]],
                       total_bytes: int) -> bool:
    """Check that the union of all owners' runs tiles [0, total) exactly once.

    Used as a saver-side invariant: after replica deduplication, every byte
    of the canonical stream must have exactly one writer.
    """
    spans = sorted((g, g + n) for owner in runs_by_owner
                   for (g, _, n) in owner)
    pos = 0
    for a, b in spans:
        if a != pos:
            return False
        pos = b
    return pos == total_bytes


def chunks_for_runs(runs: Sequence[Run], chunk_bytes: int) -> List[int]:
    """Sorted indices of the chunks overlapping any of ``runs``.

    The selective-restore primitive for compressed leaves: a shard reads
    (and inflates) only these chunk elements of the leaf's varray, never
    the rest of the archive.
    """
    needed = set()
    for g, _, n in runs:
        if n:
            needed.update(range(g // chunk_bytes,
                                (g + n - 1) // chunk_bytes + 1))
    return sorted(needed)


def chunk_sizes(nbytes: int, chunk_bytes: int) -> List[int]:
    """Deterministic chunking of a leaf's byte stream for §3 compression.

    Sizes depend only on (nbytes, chunk_bytes) — both recorded in the
    manifest — so compressed checkpoints remain partition-independent.
    """
    if nbytes == 0:
        return []
    full, rem = divmod(nbytes, chunk_bytes)
    return [chunk_bytes] * full + ([rem] if rem else [])
