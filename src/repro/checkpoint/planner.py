"""Leaf placements — WHAT bytes a leaf contributes vs WHERE they land.

The checkpoint save path used to weld these together: one loop knew both
how to snapshot a leaf (device→host windows, or deterministic chunking)
and which section layout to emit (whole-file A sections, §3.4 compressed
pairs).  Delta checkpoints add a third layout — a varray holding only the
leaf's *changed* chunks — so the two concerns are split:

* a **placement** object owns one leaf's landing plan: its section user
  string, the payload snapshot callback, and the writer planning
  primitive that turns the payload into absolute-offset fragments;
* :func:`write_placements` is the single emission loop every layout
  shares — the serial byte oracle when ``window <= 0``, the overlapped
  save engine (:func:`repro.core.pipeline.run_write_pipeline`) otherwise.

Byte-identity between the two modes is structural, exactly as before:
each placement's serial write and pipelined plan call the same
:class:`repro.core.writer.ScdaWriter` primitive pair
(``write_array_windows`` / ``plan_array_windows``,
``write_varray`` / ``plan_encoded_varray`` / ``plan_varray``), so adding
a layout means adding a placement class, never touching the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence

from repro.core.pipeline import WriteItem, run_write_pipeline


class LeafPlacement:
    """One leaf's landing plan in the archive being written."""

    user: bytes

    def write_serial(self, f) -> None:
        """Emit the section(s) via the serial byte-oracle writer calls."""
        raise NotImplementedError

    def write_item(self, f, cursor: List[int]) -> WriteItem:
        """The placement as a save-engine item.

        ``cursor`` is the scheduler's shared one-cell cursor: plans run
        strictly in item order (pipeline contract) and each advances the
        cell — the serial writer's cursor discipline, while deflate and
        writeback float free.
        """
        raise NotImplementedError


@dataclasses.dataclass
class WindowPlacement(LeafPlacement):
    """Whole-leaf fixed array: ``A(user, N=nbytes, E=1)`` of this rank's
    canonical-stream windows — the raw full-checkpoint layout, valid
    under any writing partition."""

    user: bytes
    nbytes: int
    snapshot: Callable[[], Sequence]   # -> [(byte_offset, buffer), ...]
    key: Any = None

    def write_serial(self, f) -> None:
        f.write_array_windows(self.user, self.snapshot(),
                              N=self.nbytes, E=1)

    def write_item(self, f, cursor: List[int]) -> WriteItem:
        def plan(windows):
            frags, cursor[0] = f.plan_array_windows(
                self.user, windows, N=self.nbytes, E=1, cursor=cursor[0])
            return frags
        return WriteItem(key=self.key, snapshot=self.snapshot, plan=plan,
                         style=f.style)


@dataclasses.dataclass
class ChunkPlacement(LeafPlacement):
    """Varray of chunk buffers: the §3.4 compressed pair (``deflate``
    on the codec pool) or a raw V section.

    Carries a leaf's chunk *subset* in element order — every chunk for a
    full compressed leaf, only the changed chunks for a delta leaf.
    Single-rank by construction (the writer's varray planners enforce
    it), matching the compressed/delta save restriction.
    """

    user: bytes
    usizes: List[int]                  # uncompressed chunk sizes
    snapshot: Callable[[], Sequence]   # -> [chunk byte buffers]
    compressed: bool
    key: Any = None

    def write_serial(self, f) -> None:
        elements = [bytes(c) for c in self.snapshot()]
        f.write_varray(self.user, elements, [len(elements)],
                       self.usizes, encode=self.compressed)

    def write_item(self, f, cursor: List[int]) -> WriteItem:
        if self.compressed:
            def plan(streams):
                frags, cursor[0] = f.plan_encoded_varray(
                    self.user, self.usizes, streams, cursor[0])
                return frags
            return WriteItem(key=self.key, snapshot=self.snapshot,
                             plan=plan, deflate=True, style=f.style)

        def plan(chunks):
            frags, cursor[0] = f.plan_varray(self.user, chunks, cursor[0])
            return frags
        return WriteItem(key=self.key, snapshot=self.snapshot, plan=plan,
                         style=f.style)


def write_placements(f, placements: Sequence[LeafPlacement],
                     window: int) -> None:
    """Emit ``placements`` in order — serial oracle or overlapped engine.

    The one loop every checkpoint layout (whole-file, compressed,
    delta) funnels through; ``window <= 0`` takes the exact legacy
    serial write order.
    """
    if window > 0 and placements:
        cursor = [f.cursor]
        items = [p.write_item(f, cursor) for p in placements]
        try:
            run_write_pipeline(f._backend, items, window)
        finally:
            # Keep the writer's cursor coherent even on the error path —
            # the context manager's close (barriers included) runs next.
            f.cursor = cursor[0]
        return
    for p in placements:
        p.write_serial(f)
