"""Checkpoint manifest — the self-description layer scda leaves to the user.

scda is deliberately oblivious to variables, dtypes, and endianness (paper
§1: "the definition of variables … may all be specified on top of scda").
This module *is* that layer for JAX pytrees: a JSON document stored in a
block section, naming every leaf (tree path), its shape/dtype/byte order,
and how it is laid out in subsequent array sections.
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

MANIFEST_USER_STRING = b"scda-ckpt manifest"
STATUS_USER_STRING = b"scda-ckpt status"
LEAF_USER_PREFIX = "leaf"
FORMAT_VERSION = 1


def leaf_user_string(i: int) -> bytes:
    """Deterministic user string of the i-th leaf's section.

    The contract the random-access restore path relies on: a leaf's section
    is addressable by name (via the seekable index) without walking the
    archive, so one tensor can be restored without touching the rest.
    """
    return f"{LEAF_USER_PREFIX} {i:06d}".encode("ascii")

_BYTE_ORDER = "<" if sys.byteorder == "little" else ">"


def dtype_name(dtype) -> str:
    return np.dtype(dtype).name


def dtype_from_name(name: str):
    """Inverse of :func:`dtype_name`, covering the ml_dtypes family."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


class LeafSpec(Dict[str, Any]):
    """A dict with the manifest schema for one array leaf."""

    @staticmethod
    def make(name: str, shape: Tuple[int, ...], dtype,
             compressed: bool, chunk_bytes: Optional[int]) -> "LeafSpec":
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        out = LeafSpec(name=name, shape=list(shape),
                       dtype=dtype_name(dtype), nbytes=int(nbytes),
                       byte_order=_BYTE_ORDER, compressed=bool(compressed))
        if compressed:
            out["chunk_bytes"] = int(chunk_bytes)
        return out


def build(step: Optional[int], leaves: List[LeafSpec],
          aux: Dict[str, Any]) -> bytes:
    """Serialize the manifest to JSON bytes (raw ASCII, human-readable —
    in the spirit of the format's human-friendliness goal)."""
    doc = {
        "format": "repro-scda-checkpoint",
        "version": FORMAT_VERSION,
        "step": step,
        "leaves": leaves,
        "aux": aux,   # non-array leaves (python scalars, strings, None)
    }
    return json.dumps(doc, indent=1, sort_keys=True).encode("ascii")


def parse(raw: bytes) -> Dict[str, Any]:
    doc = json.loads(raw.decode("ascii"))
    if doc.get("format") != "repro-scda-checkpoint":
        raise ValueError(f"not a repro checkpoint manifest: "
                         f"{doc.get('format')!r}")
    if doc.get("version") != FORMAT_VERSION:
        raise ValueError(f"unsupported manifest version {doc.get('version')}")
    return doc


def status_inline(step: Optional[int]) -> bytes:
    """A 32-byte human-readable status for the leading inline section."""
    text = f"step {step if step is not None else '-':>20}\n"
    return text.encode("ascii").ljust(32, b" ")[:32]


def parse_status_inline(data: bytes) -> Optional[int]:
    try:
        token = data.decode("ascii").split()[1]
        return None if token == "-" else int(token)
    except (ValueError, IndexError, UnicodeDecodeError):
        return None
