"""Checkpoint manifest — the self-description layer scda leaves to the user.

scda is deliberately oblivious to variables, dtypes, and endianness (paper
§1: "the definition of variables … may all be specified on top of scda").
This module *is* that layer for JAX pytrees: a JSON document stored in a
block section, naming every leaf (tree path), its shape/dtype/byte order,
and how it is laid out in subsequent array sections.
"""
from __future__ import annotations

import hashlib
import json
import sys
import zlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

MANIFEST_USER_STRING = b"scda-ckpt manifest"
STATUS_USER_STRING = b"scda-ckpt status"
LEAF_USER_PREFIX = "leaf"
FORMAT_VERSION = 1

#: The sharded-set manifest (:mod:`repro.checkpoint.sharding`): one small
#: scda file whose block section holds this JSON document instead of a
#: leaf manifest.  Readers tell the two apart by the block's user string,
#: so a sharded manifest can never be misread as a flat checkpoint.
SHARDS_FILE_USER_STRING = b"repro ckpt-shards"
SHARDS_MANIFEST_USER_STRING = b"scda-shards manifest"
SHARDED_FORMAT = "repro-scda-sharded"
SHARDED_VERSION = 1
#: Manifests holding cross-archive chunk references (delta checkpoints).
#: A distinct version so pre-delta readers fail loudly instead of
#: restoring a partial tree from a delta archive they cannot resolve.
DELTA_FORMAT_VERSION = 2
KNOWN_VERSIONS = (FORMAT_VERSION, DELTA_FORMAT_VERSION)

#: Per-chunk content-hash width (SHA-256 prefix, hex).  The 128-bit
#: strong hash alone keys the delta dedup decision — the standard
#: content-addressing assumption (collisions are cryptographically
#: negligible).  The CRC32 travels alongside it as the cheap read-side
#: integrity checksum; a CRC32 collision alone never marks a chunk
#: unchanged, because CRC32 is never consulted for that decision.
#: SHA-256 over blake2b because every x86-64-v3+/ARMv8 host hashes it
#: in hardware — the digest pass is the incremental save's floor cost.
CHUNK_HASH_BYTES = 16


def leaf_user_string(i: int) -> bytes:
    """Deterministic user string of the i-th leaf's section.

    The contract the random-access restore path relies on: a leaf's section
    is addressable by name (via the seekable index) without walking the
    archive, so one tensor can be restored without touching the rest.
    """
    return f"{LEAF_USER_PREFIX} {i:06d}".encode("ascii")

_BYTE_ORDER = "<" if sys.byteorder == "little" else ">"


def dtype_name(dtype) -> str:
    return np.dtype(dtype).name


def dtype_from_name(name: str):
    """Inverse of :func:`dtype_name`, covering the ml_dtypes family."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


class LeafSpec(Dict[str, Any]):
    """A dict with the manifest schema for one array leaf."""

    @staticmethod
    def make(name: str, shape: Tuple[int, ...], dtype,
             compressed: bool, chunk_bytes: Optional[int]) -> "LeafSpec":
        nbytes = int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
        out = LeafSpec(name=name, shape=list(shape),
                       dtype=dtype_name(dtype), nbytes=int(nbytes),
                       byte_order=_BYTE_ORDER, compressed=bool(compressed))
        if compressed:
            out["chunk_bytes"] = int(chunk_bytes)
        return out


def chunk_hash(chunk) -> str:
    """The per-chunk strong content hash: a 128-bit SHA-256 prefix, hex."""
    return hashlib.sha256(chunk).hexdigest()[:2 * CHUNK_HASH_BYTES]


def chunk_digests(view, sizes: Sequence[int]) \
        -> Tuple[List[int], List[str]]:
    """Per-chunk (CRC32, SHA-256-128) digests of a leaf's byte stream.

    Hashes are taken over the UNCOMPRESSED chunk bytes under the same
    deterministic chunking as §3 compression (:func:`layout.chunk_sizes`),
    so raw and compressed archives hash identically and a chunk's identity
    survives a compression-setting change.
    """
    crcs: List[int] = []
    hashes: List[str] = []
    pos = 0
    for s in sizes:
        chunk = view[pos:pos + s]
        crcs.append(zlib.crc32(chunk) & 0xFFFFFFFF)
        hashes.append(chunk_hash(chunk))
        pos += s
    return crcs, hashes


def chunk_strong_hashes(view, sizes: Sequence[int]) -> List[str]:
    """Strong hashes only — the delta save's decision pass.

    An incremental save hashes every byte (that is its floor cost) but
    checksums only what it stores: CRC32s for stored chunks are computed
    by the planner from the bytes in hand, and unchanged chunks inherit
    the base's CRC32 (sound because hash equality means the bytes are
    identical).  Keeping CRC32 out of this pass roughly halves the
    fixed per-save digest cost on hosts with hardware SHA.
    """
    hashes: List[str] = []
    pos = 0
    for s in sizes:
        hashes.append(chunk_hash(view[pos:pos + s]))
        pos += s
    return hashes


def content_id(doc: Dict[str, Any]) -> str:
    """Deterministic identity of a checkpoint's logical content.

    A blake2b over every leaf's name/geometry/chunk-hash table plus the
    aux tree and step — computable both when the archive is written and
    when it is later opened as a delta base, with no random state (saves
    stay byte-deterministic).  A base file that was rewritten in place
    (same name, different content) therefore no longer matches the id its
    dependents recorded, and chained restores refuse it loudly instead of
    assembling silently wrong tensors.
    """
    payload = {
        "step": doc.get("step"),
        "aux": doc.get("aux", {}),
        "leaves": [[l.get("name"), l.get("shape"), l.get("dtype"),
                    l.get("nbytes"), (l.get("chunks") or {}).get("hash")]
                   for l in doc.get("leaves", [])],
    }
    blob = json.dumps(payload, sort_keys=True).encode("ascii")
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


def document(step: Optional[int], leaves: List[LeafSpec],
             aux: Dict[str, Any],
             delta: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """The manifest document as a dict — what :func:`build` serializes
    and :func:`parse` returns, so a writer can hand its caller the exact
    doc a re-read of the fresh archive would produce (the manager caches
    it as the next delta's base).

    ``delta``: the cross-archive reference table of an incremental
    checkpoint (``{"bases": [{"file", "id"}, ...], "depth": k}``); its
    presence bumps the manifest to :data:`DELTA_FORMAT_VERSION`.
    """
    doc = {
        "format": "repro-scda-checkpoint",
        "version": DELTA_FORMAT_VERSION if delta else FORMAT_VERSION,
        "step": step,
        "leaves": leaves,
        "aux": aux,   # non-array leaves (python scalars, strings, None)
    }
    if delta:
        doc["delta"] = delta
    return doc


def build(step: Optional[int], leaves: List[LeafSpec],
          aux: Dict[str, Any],
          delta: Optional[Dict[str, Any]] = None) -> bytes:
    """Serialize the manifest to JSON bytes (raw ASCII, human-readable —
    in the spirit of the format's human-friendliness goal)."""
    return json.dumps(document(step, leaves, aux, delta),
                      indent=1, sort_keys=True).encode("ascii")


def parse(raw: bytes) -> Dict[str, Any]:
    doc = json.loads(raw.decode("ascii"))
    if doc.get("format") != "repro-scda-checkpoint":
        raise ValueError(f"not a repro checkpoint manifest: "
                         f"{doc.get('format')!r}")
    if doc.get("version") not in KNOWN_VERSIONS:
        raise ValueError(f"unsupported manifest version {doc.get('version')}")
    return doc


def build_sharded(doc: Dict[str, Any]) -> bytes:
    """Serialize a sharded-set manifest document (same human-readable
    JSON discipline as :func:`build`)."""
    return json.dumps(doc, indent=1, sort_keys=True).encode("ascii")


def parse_sharded(raw: bytes) -> Dict[str, Any]:
    doc = json.loads(raw.decode("ascii"))
    if doc.get("format") != SHARDED_FORMAT:
        raise ValueError(f"not a sharded checkpoint manifest: "
                         f"{doc.get('format')!r}")
    if doc.get("version") != SHARDED_VERSION:
        raise ValueError(
            f"unsupported sharded manifest version {doc.get('version')}")
    return doc


def status_inline(step: Optional[int]) -> bytes:
    """A 32-byte human-readable status for the leading inline section."""
    text = f"step {step if step is not None else '-':>20}\n"
    return text.encode("ascii").ljust(32, b" ")[:32]


def parse_status_inline(data: bytes) -> Optional[int]:
    try:
        token = data.decode("ascii").split()[1]
        return None if token == "-" else int(token)
    except (ValueError, IndexError, UnicodeDecodeError):
        return None
