"""Sharded-pytree checkpointing on scda — the framework's core feature.

``save`` writes one scda file whose bytes depend only on the *logical*
train state (leaf values in canonical row-major order), never on the mesh,
process count, or sharding — the paper's serial-equivalence, delivered for
JAX pytrees.  ``restore`` rebuilds the state under *any* target sharding /
mesh ("the file can be read on any number of processes that agree on any
partition"), which is what makes restarts elastic.

File layout:
    F  header (vendor "repro scda-jax 0.1")
    I  "scda-ckpt status"    — human-readable step number
    B  "scda-ckpt manifest"  — JSON: leaf names/shapes/dtypes/layout + aux
    per array leaf, in manifest order:
        raw:        A("leaf NNNNNN", N = nbytes, E = 1)
        compressed: §3.4 convention (A of U-entries + V of deflate chunks),
                    fixed chunking recorded in the manifest
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint import layout, manifest as mf
from repro.core import ScdaError, ScdaErrorCode
from repro.core.comm import Communicator, SerialComm
from repro.core.index import ScdaIndex
from repro.core.reader import ScdaReader, fopen_read
from repro.core.writer import ScdaWriter, fopen_write

DEFAULT_CHUNK_BYTES = 1 << 20  # 1 MiB deflate chunks for encoded leaves


# --------------------------------------------------------------------------
# Tree flattening with stable, human-readable names
# --------------------------------------------------------------------------

def _key_name(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    if isinstance(k, jax.tree_util.FlattenedIndexKey):
        return str(k.key)
    return str(k)


def leaf_name(path) -> str:
    return "/".join(_key_name(k) for k in path) or "."


def flatten_named(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [(leaf_name(p), v) for p, v in flat]
    names = [n for n, _ in named]
    if len(set(names)) != len(names):
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        "pytree leaf names are not unique")
    return named, treedef


def _is_array(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray)) and np.ndim(x) is not None


# --------------------------------------------------------------------------
# Saving
# --------------------------------------------------------------------------

def _byte_view(host: np.ndarray) -> memoryview:
    """A zero-copy byte view of a contiguous array (bf16/f8-safe — the
    ml_dtypes scalar types have no buffer protocol, uint8 views do)."""
    if host.nbytes == 0:
        return memoryview(b"")
    return memoryview(np.ascontiguousarray(host).reshape(-1).view(np.uint8))


def _owned_windows(arr, nbytes: int) -> List[Tuple[int, memoryview]]:
    """This process's deduplicated (byte_offset, buffer) windows of ``arr``.

    For a jax.Array, every addressable shard with replica_id == 0 is owned
    here; across all processes that tiles the canonical stream exactly once.
    numpy arrays are treated as fully owned (callers pass them on rank 0 or
    rely on identical replicated writes, which are byte-identical anyway).

    A 2-D-sharded tensor's shards interleave in the canonical stream;
    ``ScdaWriter.write_array_windows`` sorts the windows and coalesces runs
    that are contiguous *across shards* into single vectored writes.
    """
    windows: List[Tuple[int, memoryview]] = []
    if isinstance(arr, jax.Array):
        for shard in arr.addressable_shards:
            if shard.replica_id != 0:
                continue
            host = np.asarray(shard.data)
            buf = _byte_view(host)
            for goff, loff, length in layout.shard_runs(
                    arr.shape, shard.index, arr.dtype.itemsize):
                windows.append((goff, buf[loff:loff + length]))
    else:
        host = np.asarray(arr)
        if host.nbytes:
            windows.append((0, _byte_view(host)))
    return windows


def save(path: str, tree, *, comm: Optional[Communicator] = None,
         step: Optional[int] = None, compressed: bool = False,
         chunk_bytes: int = DEFAULT_CHUNK_BYTES,
         aux_extra: Optional[Dict[str, Any]] = None) -> None:
    """Write ``tree`` to ``path`` as a serial-equivalent scda checkpoint."""
    comm = comm or SerialComm()
    named, _ = flatten_named(tree)
    leaves: List[mf.LeafSpec] = []
    arrays: List[Any] = []
    aux: Dict[str, Any] = dict(aux_extra or {})
    for name, value in named:
        if _is_array(value):
            leaves.append(mf.LeafSpec.make(
                name, tuple(np.shape(value)), value.dtype,
                compressed, chunk_bytes))
            arrays.append(value)
        else:
            aux[name] = _encode_aux(value)
    if compressed and comm.size > 1:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        "compressed checkpoints require chunk-aligned "
                        "partitions; use comm.size == 1 (async snapshot)")

    # sync=True: checkpoints must be durable before the manager's atomic
    # rename commits them (every rank fsyncs at close).
    with fopen_write(comm, path, user_string=b"repro checkpoint",
                     sync=True) as f:
        f.write_inline(mf.STATUS_USER_STRING, mf.status_inline(step),
                       root=0)
        f.write_block(mf.MANIFEST_USER_STRING,
                      mf.build(step, leaves, aux) if comm.rank == 0 else None,
                      E=None, root=0)
        for i, (spec_, arr) in enumerate(zip(leaves, arrays)):
            user = mf.leaf_user_string(i)
            if compressed:
                _save_leaf_compressed(f, user, arr, spec_, chunk_bytes)
            else:
                windows = _owned_windows(arr, spec_["nbytes"])
                f.write_array_windows(user, windows, N=spec_["nbytes"], E=1)


def _save_leaf_compressed(f: ScdaWriter, user: bytes, arr,
                          spec_: mf.LeafSpec, chunk_bytes: int) -> None:
    flat = _byte_view(np.asarray(arr))
    sizes = layout.chunk_sizes(spec_["nbytes"], chunk_bytes)
    elements, pos = [], 0
    for s in sizes:
        elements.append(bytes(flat[pos:pos + s]))
        pos += s
    f.write_varray(user, elements, [len(sizes)], sizes, encode=True)


def _encode_aux(value) -> Any:
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, (np.integer,)):
        return int(value)
    if isinstance(value, (np.floating,)):
        return float(value)
    raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                    f"unsupported non-array leaf type {type(value)!r}")


# --------------------------------------------------------------------------
# Restoring
# --------------------------------------------------------------------------

def _read_header_sections(r: ScdaReader) -> Dict[str, Any]:
    """Consume the leading status + manifest sections; returns the doc."""
    hdr = r.read_section_header()
    if hdr.type != "I" or hdr.user_string != mf.STATUS_USER_STRING:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "not a repro checkpoint: missing status inline")
    step = mf.parse_status_inline(r.read_inline_data())
    hdr = r.read_section_header()
    if hdr.type != "B" or hdr.user_string != mf.MANIFEST_USER_STRING:
        raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                        "not a repro checkpoint: missing manifest block")
    doc = mf.parse(r.read_block_data())
    if doc.get("step") is None:
        doc["step"] = step
    return doc


def _adopt_sidecar(r: ScdaReader) -> None:
    """Give the reader a ``.scdax`` index if a fresh sidecar exists.

    Purely an optimization: without one, the reader's first seek builds
    the index with a single header-only scan; a stale or unreadable
    sidecar is ignored (and every seek re-checks the on-disk header, so
    even adopting a wrong-but-same-size sidecar cannot corrupt a restore).
    """
    try:
        r.set_index(ScdaIndex.load_sidecar(r.path))
    except (ScdaError, OSError):
        pass


def read_manifest(path: str, comm: Optional[Communicator] = None) \
        -> Dict[str, Any]:
    """Read just the status + manifest (cheap metadata probe)."""
    with fopen_read(comm, path) as r:
        return _read_header_sections(r)


def restore(path: str, like=None, *, comm: Optional[Communicator] = None):
    """Restore a checkpoint.

    ``like``: an abstract pytree of ``jax.ShapeDtypeStruct`` (with optional
    ``.sharding``) or concrete arrays defining the target structure and
    placement.  With ``like=None`` a nested dict of numpy arrays is
    rebuilt from the manifest names.

    With ``like`` given the restore is *lazy*: each wanted leaf's section
    is reached by an index seek (``.scdax`` sidecar when fresh, one
    header-only scan otherwise) and unwanted leaves are never touched —
    restoring one tensor of a terabyte archive reads that tensor, the
    manifest, and nothing else.

    Returns ``(tree, step)``.
    """
    comm = comm or SerialComm()
    with fopen_read(comm, path) as r:
        doc = _read_header_sections(r)
        step = doc.get("step")
        by_name: Dict[str, Any] = {}
        for i, spec_ in enumerate(doc["leaves"]):
            by_name[spec_["name"]] = (i, spec_)

        if like is None:
            # Full restore touches every byte anyway — keep the forward walk.
            out: Dict[str, Any] = {}
            for spec_ in doc["leaves"]:
                hdr = r.read_section_header()
                _check_leaf_header(hdr, spec_)
                out[spec_["name"]] = _read_leaf_full(r, hdr, spec_)
            for name, value in doc["aux"].items():
                out[name] = value
            return _unflatten_names(out), step

        named, treedef = flatten_named(like)
        targets = {n: v for n, v in named}
        missing = [n for n in targets
                   if n not in by_name and n not in doc["aux"]]
        if missing:
            raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                            f"leaves missing from checkpoint: {missing[:5]}"
                            f"{'…' if len(missing) > 5 else ''}")
        _adopt_sidecar(r)
        values: Dict[str, Any] = {}
        for name in targets:
            if name not in by_name:
                continue  # aux leaf
            i, spec_ = by_name[name]
            hdr = r.open_section(mf.leaf_user_string(i))
            _check_leaf_header(hdr, spec_)
            values[name] = _read_leaf_to_target(r, hdr, spec_,
                                                targets[name])
        for name in targets:
            if name in doc["aux"]:
                values[name] = doc["aux"][name]
        leaves_out = [values[n] for n, _ in named]
        return jax.tree_util.tree_unflatten(treedef, leaves_out), step


def restore_leaf(path: str, name: str, like=None, *,
                 comm: Optional[Communicator] = None):
    """Load ONE leaf from a checkpoint without touching the rest.

    The lazy-restore workload §1 motivates: seek straight to the leaf's
    section (sidecar index or one header scan), read only its bytes —
    for compressed leaves only the chunks overlapping the target shards.
    ``like`` optionally gives a target (``jax.ShapeDtypeStruct`` with
    ``.sharding`` or a concrete array) to place the leaf onto; with
    ``like=None`` a numpy array is returned.  Aux (non-array) leaves are
    returned from the manifest directly.
    """
    comm = comm or SerialComm()
    with fopen_read(comm, path) as r:
        doc = _read_header_sections(r)
        for i, spec_ in enumerate(doc["leaves"]):
            if spec_["name"] != name:
                continue
            _adopt_sidecar(r)
            hdr = r.open_section(mf.leaf_user_string(i))
            _check_leaf_header(hdr, spec_)
            if like is None:
                return _read_leaf_full(r, hdr, spec_)
            return _read_leaf_to_target(r, hdr, spec_, like)
        if name in doc["aux"]:
            return doc["aux"][name]
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"leaf {name!r} not in checkpoint")


def _check_leaf_header(hdr, spec_) -> None:
    if spec_["compressed"]:
        if hdr.type != "V" or hdr.N != len(layout.chunk_sizes(
                spec_["nbytes"], spec_["chunk_bytes"])):
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"leaf {spec_['name']}: bad compressed section")
    else:
        if hdr.type != "A" or hdr.N != spec_["nbytes"] or hdr.E != 1:
            raise ScdaError(ScdaErrorCode.CORRUPT_ENCODING,
                            f"leaf {spec_['name']}: bad array section "
                            f"({hdr.type} N={hdr.N} E={hdr.E})")


def _read_leaf_full(r: ScdaReader, hdr, spec_) -> np.ndarray:
    dtype = mf.dtype_from_name(spec_["dtype"])
    if spec_["compressed"]:
        sizes = layout.chunk_sizes(spec_["nbytes"], spec_["chunk_bytes"])
        n = len(sizes)
        raw = b"".join(r.read_varray_elements(list(range(n))))
        r.skip_data()
    else:
        raw = b"".join(r.read_array_windows([(0, spec_["nbytes"])], 1))
        r.skip_data()
    if len(raw) != spec_["nbytes"]:
        raise ScdaError(ScdaErrorCode.CORRUPT_CHECKSUM,
                        f"leaf {spec_['name']}: {len(raw)} bytes, "
                        f"manifest says {spec_['nbytes']}")
    arr = np.frombuffer(raw, dtype=dtype).reshape(spec_["shape"])
    return arr.copy()


def _read_leaf_to_target(r: ScdaReader, hdr, spec_, target):
    """Assemble the leaf under the target's sharding (any mesh)."""
    dtype = mf.dtype_from_name(spec_["dtype"])
    shape = tuple(spec_["shape"])
    t_shape = tuple(getattr(target, "shape", np.shape(target)))
    if tuple(t_shape) != shape:
        raise ScdaError(ScdaErrorCode.ARG_SEQUENCE,
                        f"leaf {spec_['name']}: target shape {t_shape} != "
                        f"checkpoint shape {shape}")
    sharding = getattr(target, "sharding", None)
    if sharding is None:
        return _read_leaf_full(r, hdr, spec_)

    # One host buffer per *distinct* addressable shard extent.
    device_map = sharding.addressable_devices_indices_map(shape)
    shard_arrays: Dict[Tuple, np.ndarray] = {}
    per_device = []
    for device, index in device_map.items():
        key = _index_key(index, shape)
        if key not in shard_arrays:
            shard_arrays[key] = _read_shard(r, spec_, index, shape, dtype)
        per_device.append((device, shard_arrays[key]))
    r.skip_data()
    arrays = [jax.device_put(arr, device) for device, arr in per_device]
    return jax.make_array_from_single_device_arrays(shape, sharding, arrays)


def _index_key(index, shape) -> Tuple:
    out = []
    for sl, dim in zip(index, shape):
        start, stop, _ = sl.indices(dim)
        out.append((start, stop))
    return tuple(out)


def _read_shard(r: ScdaReader, spec_, index, shape, dtype) -> np.ndarray:
    itemsize = np.dtype(dtype).itemsize
    runs = layout.shard_runs(shape, index, itemsize)
    shard_shape = tuple(sl.indices(dim)[1] - sl.indices(dim)[0]
                        for sl, dim in zip(index, shape)) if shape else ()
    buf = bytearray(int(np.prod(shard_shape, dtype=np.int64)) * itemsize
                    if shard_shape else itemsize)
    if spec_["compressed"]:
        _fill_from_chunks(r, spec_, runs, buf)
    else:
        if runs:
            got = r.read_array_windows([(g, n) for g, _, n in runs], 1)
            for (g, loff, n), raw in zip(runs, got):
                buf[loff:loff + n] = raw
    arr = np.frombuffer(bytes(buf), dtype=dtype)
    return arr.reshape(shard_shape)


def _fill_from_chunks(r: ScdaReader, spec_, runs, buf: bytearray) -> None:
    """Selective chunk reads: only chunks overlapping this shard's runs."""
    chunk = spec_["chunk_bytes"]
    needed = layout.chunks_for_runs(runs, chunk)
    if not needed:
        return
    chunks = dict(zip(needed, r.read_varray_elements(needed)))
    for goff, loff, n in runs:
        pos = 0
        while pos < n:
            ci, off = divmod(goff + pos, chunk)
            take = min(n - pos, chunk - off)
            data = chunks[ci]
            buf[loff + pos:loff + pos + take] = data[off:off + take]
            pos += take


def _unflatten_names(flat: Dict[str, Any]):
    """Rebuild a nested dict from 'a/b/c' names (like=None restores)."""
    root: Dict[str, Any] = {}
    for name, value in flat.items():
        parts = name.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value
    return root
